"""Search flight recorder (`repro.obs.recorder`) + timeline plumbing.

Fast tier: recorder lifecycle/LRU semantics, regret-curve math and the
deterministic CLI rendering, the store's `.timeline.json` sidecar
round-trip, the `GET /v1/jobs/<key>/timeline` endpoint (live recorder,
persisted sidecar after a server restart, 404), queue persistence on
resolve, and the `repro-service timeline` CLI.  Slow tier: a real
portfolio run in a child interpreter proving the recorded rungs
reconcile *exactly* with the SSE progress events and the result's
portfolio block, and that fixed seeds render an identical timeline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import urllib.error

import pytest
from test_server import _get_json, _post_json, _server
from test_service import CountingStubEngine

from repro import obs
from repro.core import job_key
from repro.obs.recorder import (
    FlightRecorder,
    regret_curve,
    render_timeline,
)
from repro.service import ResultStore, job_from_spec
from repro.service.queue import resolve_settings
from repro.service.server import _route

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SPEC = {"macro": "tpdcim-macro", "workload": "bert-large",
         "area_budget_mm2": 2.23, "objective": "ee",
         "search": "exhaustive",
         "space": {"mr": [1, 2], "mc": [1, 2], "scr": [1, 4],
                   "is_kb": [2, 16], "os_kb": [2, 16]}}


def _synthetic(key: str = "feedc0de") -> dict:
    """A hand-built schema-1 timeline: 3 rungs converging 10 -> 2."""
    rec = FlightRecorder(capacity=4)
    rec.start(key, method="portfolio", allocator="bandit",
              backends=["sa", "sobol"], devices=1,
              device_map={"sa": "cpu:0", "sobol": "cpu:0"},
              total_evals=512, rungs=2, seed=0)
    rec.event(key, {"phase": "race", "allocator": "bandit", "rung": 0,
                    "best": 10.0, "backend_best": {"sa": 10.0,
                                                   "sobol": 12.0},
                    "pulls": {"sa": 1, "sobol": 1}, "devices": 1,
                    "rewards": {"sa": 0.5, "sobol": 0.1}})
    rec.event(key, {"phase": "race", "allocator": "bandit", "rung": 1,
                    "best": 4.0, "backend_best": {"sa": 4.0,
                                                  "sobol": 11.0},
                    "pulls": {"sa": 2, "sobol": 1}, "devices": 1,
                    "rewards": {"sa": 0.9}, "ucb": {"sa": 1.2,
                                                    "sobol": 0.7},
                    "chosen": "sa"})
    rec.event(key, {"phase": "race", "allocator": "bandit", "rung": 2,
                    "best": 2.0, "backend_best": {"sa": 2.0,
                                                  "sobol": 11.0},
                    "pulls": {"sa": 3, "sobol": 1}, "devices": 1,
                    "rewards": {"sa": 0.4}, "ucb": {"sa": 1.1,
                                                    "sobol": 0.6},
                    "chosen": "sa"})
    rec.event(key, {"phase": "final", "winner": "sa", "best": 2.0,
                    "final": 2.0, "pulls": {"sa": 3, "sobol": 1}})
    rec.annotate(key, dedup_fanout=2)
    rec.finish(key, winner="sa", best=2.0, final=2.0,
               pulls={"sa": 3, "sobol": 1})
    return rec.timeline(key)


# ------------------------------------------------------------------ #
# recorder semantics
# ------------------------------------------------------------------ #
def test_recorder_lifecycle_and_snapshot_isolation():
    rec = FlightRecorder(capacity=8)
    rec.start("k1", method="portfolio", backends=["sa"])
    rec.event("k1", {"phase": "race", "rung": 0, "best": 1.0})
    rec.annotate("k1", dedup_fanout=3)
    rec.finish("k1", winner="sa")
    tl = rec.timeline("k1")
    assert tl["schema"] == 1
    assert tl["key"] == "k1"
    assert tl["provenance"] == {"dedup_fanout": 3}
    assert tl["summary"] == {"winner": "sa"}
    # snapshots are deep copies in both directions
    tl["events"].append({"phase": "bogus"})
    assert len(rec.timeline("k1")["events"]) == 1
    payload = {"phase": "race", "rung": 1, "pulls": {"sa": 1}}
    rec.event("k1", payload)
    payload["pulls"]["sa"] = 99
    assert rec.timeline("k1")["events"][1]["pulls"] == {"sa": 1}
    # unknown keys are no-ops, not errors
    rec.event("ghost", {"phase": "race"})
    rec.annotate("ghost", x=1)
    rec.finish("ghost", winner="?")
    assert rec.timeline("ghost") is None
    # a timeline must round-trip through JSON (store persistence)
    assert json.loads(json.dumps(rec.timeline("k1")))["key"] == "k1"


def test_recorder_lru_eviction_and_env_capacity(monkeypatch):
    rec = FlightRecorder(capacity=2)
    for k in ("a", "b", "c"):
        rec.start(k, method="portfolio")
    assert rec.keys() == ["b", "c"]       # oldest evicted
    rec.start("b", method="portfolio")    # restart refreshes recency
    rec.start("d", method="portfolio")
    assert rec.keys() == ["b", "d"]
    monkeypatch.setenv("CIM_TUNER_TIMELINE_BUFFER", "3")
    assert FlightRecorder().capacity == 3


# ------------------------------------------------------------------ #
# regret curve + rendering
# ------------------------------------------------------------------ #
def test_regret_curve_floor_includes_final_phase():
    tl = _synthetic()
    curve = regret_curve(tl)
    assert [pt["rung"] for pt in curve] == [0, 1, 2]
    assert [pt["pulls"] for pt in curve] == [2, 3, 4]
    # floor is the overall best (2.0), so regret ends at zero
    assert [pt["regret"] for pt in curve] == [8.0, 2.0, 0.0]
    assert regret_curve({"events": []}) == []


def test_render_timeline_deterministic_and_complete():
    tl = _synthetic()
    out = render_timeline(tl)
    assert out == render_timeline(tl)     # pure function of the data
    assert "method    portfolio allocator=bandit devices=1" in out
    assert "backends  sa, sobol" in out
    assert "dedup_fanout=2" in out
    assert "winner    sa best=2 final=2" in out
    # rung table rows: rung / best / chosen / pulls per backend
    assert any(line.split() == ["1", "4", "sa", "2/1"]
               for line in out.splitlines())
    # regret bars shrink to zero; convergence names the zero-regret rung
    assert "converged rung 2 of 3" in out
    # no wall-clock leaks into the rendering (stable under fixed seeds)
    assert str(tl["created_s"]) not in out


# ------------------------------------------------------------------ #
# store sidecar persistence
# ------------------------------------------------------------------ #
def test_store_timeline_sidecar_roundtrip(tmp_path):
    store = ResultStore(str(tmp_path / "store"))
    tl = _synthetic()
    assert store.get_timeline("feedc0de") is None      # miss first
    store.put_timeline("feedc0de", tl)
    assert store.get_timeline("feedc0de") == tl
    # corrupt sidecars degrade to a miss, never an exception
    path = store._timeline_path("feedc0de")
    with open(path, "w") as f:
        f.write("{not json")
    assert store.get_timeline("feedc0de") is None
    # unserializable timelines degrade to a silent no-op
    store.put_timeline("feedc0de", {"bad": object()})
    assert store.get_timeline("feedc0de") is None


# ------------------------------------------------------------------ #
# HTTP endpoint + queue persistence + CLI
# ------------------------------------------------------------------ #
def test_timeline_endpoint_live_store_and_404(tmp_path):
    key = "a1b2c3d4"
    store = ResultStore(str(tmp_path / "store"))
    srv = _server(tmp_path, store=store)
    rec = obs.flight_recorder()
    try:
        rec.start(key, method="portfolio", backends=["sa"])
        rec.finish(key, winner="sa")
        doc = _get_json(f"{srv.url}/v1/jobs/{key}/timeline")
        assert doc["source"] == "live"
        assert doc["timeline"]["summary"] == {"winner": "sa"}
        # once only the sidecar has it, the store serves it
        store.put_timeline(key, rec.timeline(key))
        rec.clear()
        doc = _get_json(f"{srv.url}/v1/jobs/{key}/timeline")
        assert doc["source"] == "store"
        with pytest.raises(urllib.error.HTTPError) as err:
            _get_json(f"{srv.url}/v1/jobs/unknown00/timeline")
        assert err.value.code == 404
    finally:
        rec.clear()
        srv.shutdown()
    assert _route(f"/v1/jobs/{key}/timeline") == "/v1/jobs/{key}/timeline"


def test_queue_persists_timeline_and_restart_serves_it(tmp_path, capsys):
    """The resolve path writes the recorder's timeline into the store,
    so a fresh server over the same store root (recorder empty, warm
    store hit) still serves it -- and the CLI renders it."""
    job, method = job_from_spec(_SPEC)
    key = job_key(job, method, resolve_settings(method))
    rec = obs.flight_recorder()
    store = ResultStore(str(tmp_path / "store"))
    srv = _server(tmp_path, store=store)
    try:
        rec.start(key, method=method, backends=["sa"], allocator="none")
        rec.finish(key, winner="sa", best=1.0, final=1.0)
        out = _post_json(f"{srv.url}/v1/jobs?wait=30", [_SPEC])
        assert out["jobs"][0]["status"] == "done"
        assert out["jobs"][0]["key"] == key
        assert store.get_timeline(key) is not None
    finally:
        rec.clear()
        srv.shutdown()
    # restart: new server + engine over the same store root
    srv2 = _server(tmp_path, engine=CountingStubEngine(),
                   store=ResultStore(str(tmp_path / "store")))
    try:
        doc = _get_json(f"{srv2.url}/v1/jobs/{key}/timeline")
        assert doc["source"] == "store"
        assert doc["timeline"]["summary"]["winner"] == "sa"
        from repro.service.__main__ import main
        assert main(["timeline", key, "--url", srv2.url]) == 0
        assert "winner    sa" in capsys.readouterr().out
        # --json prints the raw timeline
        assert main(["timeline", key, "--url", srv2.url, "--json"]) == 0
        assert json.loads(capsys.readouterr().out)["key"] == key
        # unknown keys exit 2 with a stderr note
        assert main(["timeline", "unknown00", "--url", srv2.url]) == 2
        assert "no timeline" in capsys.readouterr().err
    finally:
        srv2.shutdown()


# ------------------------------------------------------------------ #
# real-engine reconciliation (slow tier)
# ------------------------------------------------------------------ #
# Child interpreter for the same reason as test_obs's progress child: a
# real XLA portfolio run inside the suite process perturbs native
# allocator state enough to corrupt later jitted tests.
_RECONCILE_CHILD = """
import json, sys
from test_service import _job
from repro import obs
from repro.core import ExplorationEngine, job_key
from repro.obs.recorder import render_timeline
from repro.search import PortfolioSettings
from repro.service.queue import resolve_settings

settings = resolve_settings(
    "portfolio", PortfolioSettings(backends=("sobol", "sa"),
                                   total_evals=512, rungs=2))
job = _job(budget=7.91)
key = job_key(job, "portfolio", settings)
got = []
obs.progress_bus().subscribe([key], lambda k, ev: got.append(ev))
eng = ExplorationEngine()
res = eng.run([job], method="portfolio", settings=settings)[0]
tl = obs.flight_recorder().timeline(key)
render_1 = render_timeline(tl)
events_run1 = list(got)
# identical fixed-seed rerun: the recorder restarts the key's timeline
eng.run([job], method="portfolio", settings=settings)
render_2 = render_timeline(obs.flight_recorder().timeline(key))
json.dump({"key": key, "events": events_run1, "timeline": tl,
           "portfolio": res.search["portfolio"],
           "render_1": render_1, "render_2": render_2}, sys.stdout)
"""


@pytest.mark.slow
def test_portfolio_timeline_reconciles_with_sse_and_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c", _RECONCILE_CHILD],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=600)
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    tl, sse = out["timeline"], out["events"]
    assert tl["schema"] == 1 and tl["key"] == out["key"]
    assert tl["method"] == "portfolio" and tl["allocator"] == "bandit"
    assert set(tl["device_map"]) == {"sobol", "sa"}

    # every SSE progress event must appear, in order, as a timeline
    # event agreeing on ALL shared payload fields -- the recorder sees
    # a superset (rewards / ucb / chosen), never a different number
    shared = ("phase", "allocator", "rung", "best", "backend_best",
              "pulls", "devices")
    assert len(tl["events"]) == len(sse)
    for tl_ev, sse_ev in zip(tl["events"], sse):
        for field in shared:
            assert tl_ev.get(field) == sse_ev.get(field), \
                (field, tl_ev, sse_ev)
    races = [ev for ev in tl["events"] if ev["phase"] == "race"]
    assert races and all("rewards" in ev for ev in races)
    assert any("ucb" in ev and "chosen" in ev for ev in races[1:])

    # the final result's portfolio block and the summary must agree
    portfolio = out["portfolio"]
    assert tl["summary"]["winner"] == portfolio["winner"]
    assert tl["summary"]["pulls"] == tl["events"][-1]["pulls"]

    # fixed seeds => byte-identical CLI rendering across reruns
    assert out["render_1"] == out["render_2"]
    assert "winner    " + portfolio["winner"] in out["render_1"]
