"""Continuous-batching scheduler: admission, budget flow, drain.

Engine-level tests drive ``ExplorationEngine.run(..., admit=...)``
directly with scripted admission hooks on a small real design space --
proving the scheduler's core claims bit-for-bit (a rung-admitted job
equals its solo run; budget is conserved under flatline release; the
quiesced path is unchanged).  Queue-level tests use stub engines (no
JAX) so admission wiring, the ``max_batch_jobs`` lane cap, and the
close()-drain contract cannot flake on timing.
"""
from __future__ import annotations

import subprocess
import sys
import threading
import time

import pytest
from test_service import _fake_result, _job

from repro.core import ExplorationEngine, job_key
from repro.search import PortfolioSettings
from repro.search.portfolio import bandit_rounds
from repro.service import JobQueue, QueueConfig, ResultStore

#: small real-engine race: 2 backends x 2 rungs = 4 bandit pulls/job
PS = dict(backends=("sa", "sobol"), total_evals=64, rungs=2)


def _equal_results(a, b) -> None:
    assert a.config.as_tuple() == b.config.as_tuple()
    for k in ("energy_pj", "latency_cycles", "tops_w", "gops", "area_mm2"):
        assert a.metrics[k] == b.metrics[k], k


# ------------------------------------------------------------------ #
# engine-level admission (tentpole: late join at a rung boundary)
# ------------------------------------------------------------------ #
def test_rung_admitted_job_matches_solo_run_bitwise():
    """A job admitted mid-race gets the same answer as running alone:
    per-job bandit state is independent and pull seeds derive only from
    ``(seed, backend, pull index)``, not from when the job joined."""
    eng = ExplorationEngine()
    settings = PortfolioSettings(**PS)
    early, late = _job(budget=2.23), _job(budget=2.24)
    solo_early = eng.run([early], method="portfolio", settings=settings)[0]
    solo_late = eng.run([late], method="portfolio", settings=settings)[0]

    polls = {"n": 0}

    def admit():
        polls["n"] += 1
        if polls["n"] == 3:     # join at the boundary before wave 2
            return [(late, job_key(late, "portfolio", settings))]
        return []

    outs = eng.run([early], method="portfolio", settings=settings,
                   keys=[job_key(early, "portfolio", settings)],
                   admit=admit)
    assert len(outs) == 2, "admitted result must ride behind the batch"
    _equal_results(outs[0], solo_early)
    _equal_results(outs[1], solo_late)
    flow = outs[1].search["budget_flow"]
    assert flow["admitted_wave"] == 2
    assert outs[0].search["budget_flow"]["admitted_wave"] == 0
    assert polls["n"] >= 3, "hook must be polled at every boundary"


def test_admit_requires_single_bandit_portfolio_group():
    """``admit=`` has no rung boundaries to join outside a one-bucket
    bandit portfolio race -- the engine must reject it loudly instead of
    silently stranding admitted jobs."""
    eng = ExplorationEngine()
    with pytest.raises(ValueError, match="admission"):
        eng.run([_job()], method="exhaustive",
                settings=None, admit=lambda: [])
    with pytest.raises(ValueError, match="admission"):
        eng.run([_job()], method="portfolio",
                settings=PortfolioSettings(**PS, allocator="halving"),
                admit=lambda: [])


# ------------------------------------------------------------------ #
# budget flow (tentpole: flatline release + conservation)
# ------------------------------------------------------------------ #
def test_budget_flow_conserves_total_pulls():
    """Released + absorbed + spent must add back up to the configured
    budget: ``sum(race_pulls) + pool_leftover == n_jobs * rounds``."""
    eng = ExplorationEngine()
    # flatline_eps high enough that every adaptive pull "flatlines"
    settings = PortfolioSettings(**PS, flatline_waves=1, flatline_eps=0.5)
    jobs = [_job(budget=2.23), _job(budget=2.24)]
    outs = eng.run(jobs, method="portfolio", settings=settings)
    flows = [r.search["budget_flow"] for r in outs]
    assert all(f["enabled"] for f in flows)
    total = sum(f["race_pulls"] for f in flows) + flows[0]["pool_leftover"]
    assert total == len(jobs) * bandit_rounds(settings)
    assert all(f["pool_leftover"] == flows[0]["pool_leftover"]
               for f in flows)


def test_flatline_release_is_deterministic():
    """Same seed, same jobs -> identical budget-flow trace and identical
    winning configs across runs (reallocation must not break replay)."""
    settings = PortfolioSettings(**PS, flatline_waves=1, flatline_eps=0.5)
    jobs = [_job(budget=2.23), _job(budget=2.24)]
    a = ExplorationEngine().run(jobs, method="portfolio", settings=settings)
    b = ExplorationEngine().run(jobs, method="portfolio", settings=settings)
    for ra, rb in zip(a, b):
        _equal_results(ra, rb)
        assert ra.search["budget_flow"] == rb.search["budget_flow"]


def test_quiesced_continuous_equals_window_bitwise(tmp_path):
    """With no late arrivals the scheduler must be invisible: the same
    two-job batch through a continuous queue and a window queue produces
    bit-identical results (and both match engine defaults)."""
    eng = ExplorationEngine()
    settings = PortfolioSettings(**PS)
    jobs = [_job(budget=2.23), _job(budget=2.24)]
    legs = {}
    for continuous in (True, False):
        q = JobQueue(engine=eng, store=None,
                     config=QueueConfig(batch_window_s=0.2,
                                        continuous=continuous))
        futs = [q.submit(j, method="portfolio", settings=settings)
                for j in jobs]
        legs[continuous] = [f.result(timeout=600) for f in futs]
        q.close()
    for ra, rb in zip(legs[True], legs[False]):
        _equal_results(ra, rb)
        assert ra.search["portfolio"] == rb.search["portfolio"]
        assert ra.search["budget_flow"] == rb.search["budget_flow"]


# ------------------------------------------------------------------ #
# queue-level admission wiring (stub engine, no JAX)
# ------------------------------------------------------------------ #
class WaveStubEngine:
    """Holds its first ``run()`` open, polling ``admit`` like the real
    engine does between waves, until ``release`` is set."""

    def __init__(self):
        self.release = threading.Event()
        self.started = threading.Event()
        self.admitted_keys = []
        self.calls = 0

    def bucket_key(self, job, method=None):
        return ("stub-bucket",)

    def run(self, jobs, method=None, settings=None, sa_settings=None,
            keys=None, admit=None):
        self.calls += 1
        jobs = list(jobs)
        self.started.set()
        if admit is not None:
            deadline = time.monotonic() + 30
            while not self.release.is_set():
                assert time.monotonic() < deadline, "never released"
                for job, key in admit():
                    jobs.append(job)
                    self.admitted_keys.append(key)
                time.sleep(0.005)
        return [_fake_result(j) for j in jobs]


def test_queue_admits_compatible_pending_into_inflight_group(tmp_path):
    eng = WaveStubEngine()
    settings = PortfolioSettings(**PS)
    store = ResultStore(str(tmp_path))
    q = JobQueue(engine=eng, store=store,
                 config=QueueConfig(batch_window_s=0.01))
    try:
        f_a = q.submit(_job(budget=2.23), method="portfolio",
                       settings=settings)
        assert eng.started.wait(10), "first dispatch never started"
        f_b = q.submit(_job(budget=2.24), method="portfolio",
                       settings=settings)
        deadline = time.monotonic() + 10
        while not eng.admitted_keys and time.monotonic() < deadline:
            time.sleep(0.005)
        assert eng.admitted_keys == [f_b.key], "late job never admitted"
        snap = q.stats_snapshot()
        assert snap["scheduler"]["inflight_groups"] == 1
        assert snap["scheduler"]["inflight_group_jobs"] == 2
        eng.release.set()
        assert f_a.result(timeout=30) is not None
        assert f_b.result(timeout=30) is not None
        snap = q.stats_snapshot()
        assert snap["scheduler"]["admitted"] == 1
        assert snap["scheduler"]["admission_checks"] >= 1
        assert snap["queue"]["dispatches"] == 1, \
            "admitted job must not trigger a second engine call"
        assert snap["scheduler"]["inflight_groups"] == 0
        # admitted entries persist exactly like window-dispatched ones
        assert sorted(store.keys()) == sorted([f_a.key, f_b.key])
    finally:
        eng.release.set()
        q.close()


def test_queue_incompatible_pending_waits_for_own_dispatch():
    """A pending job with different settings must NOT join the in-flight
    group -- it dispatches separately once the race drains."""
    eng = WaveStubEngine()
    q = JobQueue(engine=eng, store=None,
                 config=QueueConfig(batch_window_s=0.01))
    try:
        f_a = q.submit(_job(budget=2.23), method="portfolio",
                       settings=PortfolioSettings(**PS))
        assert eng.started.wait(10)
        other = PortfolioSettings(backends=("sa", "sobol"),
                                  total_evals=128, rungs=2)
        f_b = q.submit(_job(budget=2.24), method="portfolio",
                       settings=other)
        time.sleep(0.1)          # give a wrong admission time to happen
        assert eng.admitted_keys == []
        eng.release.set()
        assert f_a.result(timeout=30) is not None
        assert f_b.result(timeout=30) is not None
        snap = q.stats_snapshot()
        assert snap["scheduler"]["admitted"] == 0
        assert snap["queue"]["dispatches"] == 2
    finally:
        eng.release.set()
        q.close()


def test_max_batch_jobs_caps_each_dispatch():
    """``max_batch_jobs`` is a hard lane cap: a bigger backlog dispatches
    as successive bounded batches on the window path."""
    class CountingEngine:
        def __init__(self):
            self.batch_sizes = []

        def bucket_key(self, job, method=None):
            return ("stub-bucket",)

        def run(self, jobs, method=None, settings=None, sa_settings=None,
                keys=None):
            self.batch_sizes.append(len(jobs))
            return [_fake_result(j) for j in jobs]

    eng = CountingEngine()
    q = JobQueue(engine=eng, store=None,
                 config=QueueConfig(batch_window_s=0.2, max_batch_jobs=2,
                                    continuous=False))
    try:
        futs = [q.submit(_job(budget=2.23 + i * 1e-6), method="portfolio",
                         settings=PortfolioSettings(**PS))
                for i in range(5)]
        for f in futs:
            assert f.result(timeout=30) is not None
        assert eng.batch_sizes == [2, 2, 1]
    finally:
        q.close()


# ------------------------------------------------------------------ #
# close() drains instead of stranding (satellite: shutdown fix)
# ------------------------------------------------------------------ #
def test_close_drains_accepted_futures_under_load():
    class SlowStubEngine:
        def bucket_key(self, job, method=None):
            return ("stub-bucket",)

        def run(self, jobs, method=None, settings=None, sa_settings=None,
                keys=None, admit=None):
            time.sleep(0.05)
            jobs = list(jobs)
            if admit is not None:
                for job, _key in admit():
                    jobs.append(job)
            return [_fake_result(j) for j in jobs]

    q = JobQueue(engine=SlowStubEngine(), store=None,
                 config=QueueConfig(batch_window_s=0.02, max_batch_jobs=2))
    futs = [q.submit(_job(budget=2.23 + i * 1e-6), method="portfolio",
                     settings=PortfolioSettings(**PS))
            for i in range(8)]
    q.close()                    # default: full drain
    for f in futs:
        assert f.done(), "close() stranded an accepted future"
        assert f.exception(0) is None
    with pytest.raises(RuntimeError, match="closed"):
        q.submit(_job(), method="portfolio",
                 settings=PortfolioSettings(**PS))


@pytest.mark.slow
def test_poisson_load_test_smoke_exits_zero(tmp_path):
    """The scheduler's whole reason to exist: under Poisson load the
    continuous leg sustains materially more jobs/sec than the window
    leg, and shutdown under load exits cleanly."""
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    env = dict(os.environ,
               PYTHONPATH=os.path.join(root, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.load_test", "--smoke",
         "--min-speedup", "1.2"],
        capture_output=True, text=True, timeout=300, cwd=root, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "speedup" in proc.stdout
