"""Roofline math + calibration-sensitivity tests."""
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import cost_model
from repro.core.calibration import DEFAULT_TECH
from repro.core.ir import bert_large_workload
from repro.core.macro import get_macro
from repro.core.pruning import DesignSpace, candidates_with_bw, enumerate_space
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, analyze_cell, model_flops


def test_model_flops_dense_vs_moe():
    dense = model_flops("yi-6b", "train_4k")
    assert dense == 6.0 * get_arch("yi-6b").params_estimate() * 256 * 4096
    moe_active = model_flops("granite-moe-3b-a800m", "train_4k")
    moe_total = 6.0 * get_arch("granite-moe-3b-a800m").params_estimate() \
        * 256 * 4096
    assert moe_active < 0.5 * moe_total          # top-8/40 with tiny experts
    # decode counts one token per request
    d = model_flops("yi-6b", "decode_32k")
    assert d == 2.0 * get_arch("yi-6b").params_estimate() * 128


def test_analyze_cell_terms():
    rec = {
        "status": "OK", "arch": "yi-6b", "shape": "train_4k",
        "mesh": "16x16",
        "dot_flops_per_device": PEAK_FLOPS,          # 1 s compute
        "hbm_bytes_per_device": HBM_BW * 2.0,        # 2 s memory (hi)
        "hbm_write_bytes_per_device": HBM_BW * 0.5,  # 1 s memory (lo)
        "collectives": {"total_bytes": LINK_BW * 0.5,
                        "bytes": {}, "counts": {}},
    }
    r = analyze_cell(rec)
    assert r["t_compute_s"] == 1.0
    assert r["t_memory_hi_s"] == 2.0
    assert r["t_memory_lo_s"] == 1.0
    assert r["t_collective_s"] == 0.5
    assert r["dominant"] == "memory"
    assert abs(r["roofline_fraction"] - 0.5) < 1e-9


def test_analyze_cell_skips_non_ok():
    assert analyze_cell({"status": "SKIP"}) is None


def test_calibration_ordering_stable_under_energy_scale():
    """Scaling the dominant energy constant re-scales absolute PPA but must
    keep the candidate ordering (the co-exploration's decisions)."""
    macro = get_macro("vanilla-dcim")
    wl = bert_large_workload().merged().as_arrays()
    cands = candidates_with_bw(enumerate_space(DesignSpace(
        mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16), is_kb=(4, 32, 256),
        os_kb=(4, 32))), 256)

    def scores(tech):
        fn = cost_model.make_objective_fn(wl, macro, tech=tech)
        import jax
        return np.asarray(jax.vmap(fn)(jnp.asarray(cands, jnp.float32)))

    base = scores(DEFAULT_TECH)
    pert = scores(dataclasses.replace(
        DEFAULT_TECH, e_ema_pj_bit=DEFAULT_TECH.e_ema_pj_bit * 1.3))
    feas = base < 1e29
    # Spearman rank correlation over feasible candidates
    def ranks(v):
        order = np.argsort(v)
        r = np.empty_like(order, float)
        r[order] = np.arange(len(v))
        return r
    ra, rb = ranks(base[feas]), ranks(pert[feas])
    rho = np.corrcoef(ra, rb)[0, 1]
    assert rho > 0.95, rho
