"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret=True
executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("tiling", ["AF", "PF"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64, 64, 64), (200, 300, 250),
                                   (128, 128, 128), (1, 700, 130),
                                   (257, 129, 255)])
def test_cim_matmul_sweep(tiling, dtype, shape):
    m, k, n = shape
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    got = ops.cim_matmul(a, b, tiling=tiling, interpret=True)
    want = ref.matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 0.2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_cim_matmul_af_pf_psum_width():
    """PF accumulates at output width (dw_psum analogue): in bf16 the AF
    result (f32 VMEM accumulator) is at least as accurate as PF's HBM
    round-trips -- the numeric face of the paper's psum trade-off."""
    m, k, n = 128, 2048, 128
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.bfloat16)
    b = jnp.asarray(RNG.standard_normal((k, n)), jnp.bfloat16)
    exact = np.asarray(ref.matmul_ref(a, b, out_dtype=jnp.float32))
    af = np.asarray(ops.cim_matmul(a, b, tiling="AF", interpret=True),
                    np.float32)
    pf = np.asarray(ops.cim_matmul(a, b, tiling="PF", interpret=True),
                    np.float32)
    err_af = np.abs(af - exact).mean()
    err_pf = np.abs(pf - exact).mean()
    assert err_af <= err_pf + 1e-6


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("shape", [(2, 128, 128, 64), (1, 200, 300, 64),
                                   (3, 129, 257, 128)])
def test_flash_attention_sweep(causal, shape):
    bh, t, s, d = shape
    q = jnp.asarray(RNG.standard_normal((bh, t, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((2, 256, 64)), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2)


def test_strategy_eval_vs_ref_and_explorer():
    from repro.core.ir import bert_large_workload
    from repro.core.macro import get_macro
    from repro.core.pruning import (DesignSpace, candidates_with_bw,
                                    enumerate_space)
    from repro.core import cost_model

    cands = candidates_with_bw(enumerate_space(DesignSpace(
        mr=(1, 2), mc=(1, 2), scr=(1, 4, 16), is_kb=(4, 64),
        os_kb=(4, 64))), 256)
    wl = bert_large_workload().merged().as_arrays()
    macro = get_macro("vanilla-dcim")
    got = np.asarray(ops.strategy_eval(cands, wl, macro, interpret=True))
    want = np.asarray(ref.strategy_eval_ref(cands, wl, macro))
    np.testing.assert_allclose(got, want, rtol=1e-5)
    # and against the explorer's objective function (same math end-to-end)
    fn = cost_model.make_objective_fn(jnp.asarray(wl), macro)
    v0 = float(fn(jnp.asarray(cands[17], jnp.float32)))
    np.testing.assert_allclose(got[17], v0, rtol=1e-5)


@pytest.mark.parametrize("shape", [(1, 64, 32, 8), (2, 100, 48, 16),
                                   (1, 33, 17, 4)])
def test_selective_scan_kernel_sweep(shape):
    b, t, i, s = shape
    xi = jnp.asarray(RNG.standard_normal((b, t, i)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, t, i))) * 0.1,
                     jnp.float32)
    bm = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.float32)
    cm = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.standard_normal((i, s))), jnp.float32)
    h0 = jnp.asarray(RNG.standard_normal((b, i, s)), jnp.float32)
    y, hl = ops.selective_scan(xi, dt, bm, cm, a, h0, ct=16, ci=16,
                               interpret=True)
    y_ref, h_ref = ref.selective_scan_ref(xi, dt, bm, cm, a, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-3)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(h_ref), atol=1e-3)


def test_selective_scan_kernel_bf16():
    b, t, i, s = 1, 64, 32, 8
    xi = jnp.asarray(RNG.standard_normal((b, t, i)), jnp.bfloat16)
    dt = jnp.asarray(np.abs(RNG.standard_normal((b, t, i))) * 0.1,
                     jnp.bfloat16)
    bm = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.bfloat16)
    cm = jnp.asarray(RNG.standard_normal((b, t, s)), jnp.bfloat16)
    a = jnp.asarray(-np.abs(RNG.standard_normal((i, s))), jnp.float32)
    h0 = jnp.zeros((b, i, s), jnp.float32)
    y, _ = ops.selective_scan(xi, dt, bm, cm, a, h0, ct=16, ci=16,
                              interpret=True)
    y_ref, _ = ref.selective_scan_ref(
        xi.astype(jnp.float32), dt.astype(jnp.float32),
        bm.astype(jnp.float32), cm.astype(jnp.float32), a, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref), atol=0.15)
