"""Async DSE service: streaming order, dedup, store semantics, wrappers.

The deterministic streaming/caching tests drive the queue with stub engines
(a counting stub for cache assertions, a blocking stub for order
assertions) so they make no JAX calls and cannot flake on timing; the
end-to-end equivalence tests run the real engine on a small design space.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core import (
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    bert_large_workload,
    co_explore,
    get_macro,
    job_key,
    pareto_explore,
)
from repro.core.engine import ExploreResult
from repro.core.macro import TPDCIM_MACRO
from repro.core.template import AcceleratorConfig
from repro.service import (
    JobQueue,
    QueueConfig,
    ResultStore,
    ServiceClient,
    as_completed,
    deserialize_result,
    serialize_result,
)

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


def _job(objective="ee", budget=2.23, wl=None):
    return ExploreJob(TPDCIM_MACRO, wl or bert_large_workload(), budget,
                      objective=objective, space=SMALL)


def _fake_result(job, tag="x") -> ExploreResult:
    return ExploreResult(
        config=AcceleratorConfig(1, 1, 1, 2, 2),
        macro=job.macro, workload=job.workload.name,
        objective=job.objective, strategy_set=job.strategy_set,
        per_op_strategy={"op0": "IS-W-F"},
        metrics={"tops_w": 1.0, "gops": 1.0, "energy_pj": 1.0,
                 "latency_cycles": 1.0, "latency_s": 1.0, "area_mm2": 1.0},
        search={"method": "stub", "tag": tag},
    )


class CountingStubEngine:
    """Engine double: counts run() invocations, optional per-bucket block.

    ``block_buckets``: bucket keys whose dispatch waits on ``release``
    before returning -- lets tests hold the slow bucket open while
    asserting the fast bucket already streamed out."""

    def __init__(self, block_buckets=(), bucket_of=None):
        self.runs = 0
        self.jobs_seen = []
        self.release = threading.Event()
        self.block_buckets = set(block_buckets)
        self.sa_settings = None
        self._bucket_of = bucket_of or (
            lambda job, method: (len(job.merged_workload().ops),))

    def bucket_key(self, job, method="sa"):
        return self._bucket_of(job, method)

    def run(self, jobs, method="sa", settings=None, sa_settings=None,
            keys=None):
        if self.bucket_key(jobs[0], method) in self.block_buckets:
            assert self.release.wait(30), "blocked bucket never released"
        self.runs += 1
        self.jobs_seen.extend(jobs)
        return [_fake_result(j, tag=f"run{self.runs}") for j in jobs]

    def candidate_values(self, jobs, candidates):
        self.runs += 1
        return [np.arange(len(c), dtype=float) + 1.0 for c in candidates]


# ------------------------------------------------------------------ #
# streaming order (satellite: multi-bucket submission yields the fast
# bucket's results before the slow bucket completes)
# ------------------------------------------------------------------ #
def test_fast_bucket_streams_before_slow_bucket_completes(tmp_path):
    from repro.configs import get_arch
    fast_wl = bert_large_workload()                       # few merged ops
    slow_wl = get_arch("whisper-small").workload(seq=512)  # many ops
    eng = CountingStubEngine()
    slow_bucket = eng.bucket_key(ExploreJob(
        TPDCIM_MACRO, slow_wl, 2.23, space=SMALL), "exhaustive")
    fast_bucket = eng.bucket_key(ExploreJob(
        TPDCIM_MACRO, fast_wl, 2.23, space=SMALL), "exhaustive")
    assert slow_bucket != fast_bucket, "test needs two distinct buckets"
    eng.block_buckets = {slow_bucket}

    q = JobQueue(engine=eng, store=ResultStore(str(tmp_path)),
                 config=QueueConfig(batch_window_s=0.01))
    try:
        f_fast = q.submit(_job(wl=fast_wl), method="exhaustive", priority=1)
        f_slow = q.submit(_job(wl=slow_wl), method="exhaustive")
        # the fast bucket must resolve while the slow bucket is still held
        first = next(as_completed([f_fast, f_slow], timeout=30))
        assert first is f_fast
        assert not f_slow.done(), \
            "slow bucket finished before fast bucket streamed out"
        eng.release.set()
        assert f_slow.result(timeout=30).workload == slow_wl.name
    finally:
        eng.release.set()
        q.close()
    assert eng.runs == 2, "each bucket must dispatch as its own run()"


# ------------------------------------------------------------------ #
# cache semantics (satellite: warm store serves a repeated job without
# invoking the engine -- counting stub)
# ------------------------------------------------------------------ #
def test_warm_store_skips_engine(tmp_path):
    eng = CountingStubEngine()
    store = ResultStore(str(tmp_path))
    with JobQueue(engine=eng, store=store,
                  config=QueueConfig(batch_window_s=0.0)) as q:
        cold = q.submit(_job(), method="exhaustive").result(timeout=30)
    assert eng.runs == 1 and store.stats["puts"] == 1

    eng2 = CountingStubEngine()
    with JobQueue(engine=eng2, store=ResultStore(str(tmp_path))) as q2:
        warm = q2.submit(_job(), method="exhaustive").result(timeout=30)
        assert q2.stats["store_hits"] == 1
    assert eng2.runs == 0, "warm store must serve without engine invocation"
    assert warm.config.as_tuple() == cold.config.as_tuple()
    assert warm.metrics == cold.metrics
    assert warm.search["cache"] == "store"


def test_inflight_dedup_fans_out_single_evaluation(tmp_path):
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(_job(), "exhaustive")}
    q = JobQueue(engine=eng, store=ResultStore(str(tmp_path)),
                 config=QueueConfig(batch_window_s=0.01))
    try:
        futs = [q.submit(_job(), method="exhaustive") for _ in range(4)]
        eng.release.set()
        results = [f.result(timeout=30) for f in futs]
    finally:
        eng.release.set()
        q.close()
    assert eng.runs == 1 and len(eng.jobs_seen) == 1
    assert q.stats["inflight_dedup"] == 3
    for a, b in zip(results, results[1:]):
        assert a.config.as_tuple() == b.config.as_tuple()
        assert a.metrics is not b.metrics, "fan-out must not alias dicts"


def test_store_roundtrip_is_exact(tmp_path):
    job = _job()
    r = _fake_result(job)
    r.metrics["tops_w"] = 3.141592653589793116  # full float64 precision
    store = ResultStore(str(tmp_path))
    key = job_key(job, "exhaustive", None)
    store.put(key, r)
    back = store.get(key)
    assert back is not None
    assert back.metrics["tops_w"] == r.metrics["tops_w"]  # bit-for-bit
    assert back.config == r.config
    assert back.macro == r.macro
    assert back.per_op_strategy == r.per_op_strategy


def test_store_tolerates_corrupt_records(tmp_path):
    store = ResultStore(str(tmp_path))
    key = job_key(_job(), "exhaustive", None)
    store.put(key, _fake_result(_job()))
    path = store._path(key)
    with open(path, "w") as f:
        f.write("{not json\n")
    assert store.get(key) is None                # miss, not crash


def test_serialize_roundtrip_standalone():
    r = _fake_result(_job("th"))
    rec = serialize_result(r)
    back = deserialize_result(rec)
    assert back.objective == "th"
    assert back.config == r.config
    assert back.sa is None


def test_failed_group_rejects_futures(tmp_path):
    class ExplodingEngine(CountingStubEngine):
        def run(self, jobs, method="sa", settings=None, sa_settings=None,
                keys=None):
            raise ValueError("no feasible hardware point under budget")

    with JobQueue(engine=ExplodingEngine(), store=None,
                  config=QueueConfig(batch_window_s=0.0)) as q:
        fut = q.submit(_job(budget=1e-6), method="exhaustive")
        with pytest.raises(ValueError, match="no feasible"):
            fut.result(timeout=30)
        assert fut.exception(timeout=1) is not None


def test_engine_failure_surfaces_job_key_into_every_future():
    """A poisoned engine fails a whole micro-batch bucket; every affected
    future must surface the error tagged with ITS originating job_key
    (message + ``.job_key`` attribute), not a bare shared exception."""
    class PoisonedEngine(CountingStubEngine):
        def run(self, jobs, method="sa", settings=None, sa_settings=None,
                keys=None):
            raise RuntimeError("engine poisoned")

    with JobQueue(engine=PoisonedEngine(), store=None,
                  config=QueueConfig(batch_window_s=0.2)) as q:
        # same canonical job -> in-flight dedup fans the failure out too
        f1 = q.submit(_job("ee"), method="exhaustive")
        f2 = q.submit(_job("ee"), method="exhaustive")
        f3 = q.submit(_job("th"), method="exhaustive")
        excs = [f.exception(timeout=30) for f in (f1, f2, f3)]
    for f, exc in zip((f1, f2, f3), excs):
        assert isinstance(exc, RuntimeError)
        assert "engine poisoned" in str(exc)
        assert f.key[:16] in str(exc), "message must carry the job key"
        assert exc.job_key == f.key
        assert exc.__cause__ is not None
    assert excs[0].job_key != excs[2].job_key
    assert q.stats["failed"] >= 1


def test_worker_survives_unbucketable_entry():
    """An entry whose job can't even be bucketed (malformed design space)
    is rejected individually; the worker thread keeps serving."""
    class PickyEngine(CountingStubEngine):
        def bucket_key(self, job, method="sa"):
            if not job.design_space().mr:
                raise IndexError("empty axis")
            return super().bucket_key(job, method)

    bad = ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                     space=DesignSpace(mr=()))
    with JobQueue(engine=PickyEngine(), store=None,
                  config=QueueConfig(batch_window_s=0.0)) as q:
        fb = q.submit(bad, method="exhaustive")
        assert fb.exception(timeout=30) is not None
        fg = q.submit(_job(), method="exhaustive")
        assert fg.result(timeout=30).workload == "bert-large"


def test_priority_orders_dispatch():
    eng = CountingStubEngine(
        bucket_of=lambda job, method: (job.objective,))  # bucket per obj
    q = JobQueue(engine=eng, store=None,
                 config=QueueConfig(batch_window_s=0.5))
    try:
        # both submissions land inside one micro-batch window; the
        # high-priority job's bucket must dispatch (and resolve) first
        lo = q.submit(_job("ee"), method="exhaustive", priority=0)
        hi = q.submit(_job("th"), method="exhaustive", priority=5)
        first = next(as_completed([lo, hi], timeout=30))
        assert first is hi
    finally:
        q.close()


# ------------------------------------------------------------------ #
# blocking wrappers: service path must equal direct-engine path
# ------------------------------------------------------------------ #
def test_co_explore_service_path_matches_engine_path():
    macro = get_macro("vanilla-dcim")
    wl = bert_large_workload()
    via_service = co_explore(macro, wl, 3.0, objective="ee",
                             method="exhaustive", space=SMALL)
    via_engine = co_explore(macro, wl, 3.0, objective="ee",
                            method="exhaustive", space=SMALL,
                            engine=ExplorationEngine())
    assert via_service.config.as_tuple() == via_engine.config.as_tuple()
    for key in ("energy_pj", "latency_cycles", "tops_w", "gops"):
        assert via_service.metrics[key] == via_engine.metrics[key]


def test_pareto_explore_service_path_matches_engine_path():
    macro = get_macro("vanilla-dcim")
    wl = bert_large_workload()
    via_service = pareto_explore(macro, wl, 3.0, space=SMALL)
    via_engine = pareto_explore(macro, wl, 3.0, space=SMALL,
                                engine=ExplorationEngine())
    assert [(p["config"], p["gops"], p["tops_w"]) for p in via_service] == \
        [(p["config"], p["gops"], p["tops_w"]) for p in via_engine]


def test_service_end_to_end_two_buckets_real_engine(tmp_path):
    """Real-engine streaming: two shape buckets, every result correct, and
    a resubmission is served entirely from the store."""
    from repro.configs import get_arch
    jobs = [
        _job(wl=bert_large_workload()),
        _job(wl=get_arch("whisper-small").workload(seq=512), budget=5.0),
    ]
    svc = ServiceClient(engine=ExplorationEngine(),
                        store=ResultStore(str(tmp_path)))
    try:
        futs = svc.submit_many(jobs, method="exhaustive")
        seen = [f.result(timeout=600) for f in futs]
        assert svc.stats["dispatches"] == 2          # one per shape bucket
        reference = ExplorationEngine().run(jobs, method="exhaustive")
        for got, ref in zip(seen, reference):
            assert got.config.as_tuple() == ref.config.as_tuple()
            assert got.metrics["energy_pj"] == ref.metrics["energy_pj"]

        d0 = svc.stats["dispatches"]
        warm = svc.explore(jobs, method="exhaustive")
        assert svc.stats["dispatches"] == d0, "warm path must skip engine"
        assert svc.stats["store_hits"] == 2
        for got, ref in zip(warm, reference):
            assert got.config.as_tuple() == ref.config.as_tuple()
            assert got.metrics["energy_pj"] == ref.metrics["energy_pj"]
    finally:
        svc.close()


def test_cli_job_spec_parsing():
    from repro.service import job_from_spec
    job, method = job_from_spec({
        "macro": "tpdcim-macro", "workload": "bert-large",
        "area_budget_mm2": 2.23, "objective": "th",
        "method": "exhaustive",
        "space": {"mr": [1, 2], "mc": [1, 2], "scr": [1, 4],
                  "is_kb": [16], "os_kb": [16]},
    })
    assert method == "exhaustive"
    assert job.macro.name == "tpdcim-macro"
    assert job.objective == "th"
    assert job.design_space().mr == (1, 2)
    inline, _ = job_from_spec({
        "macro": "vanilla-dcim", "area_budget_mm2": 1.0,
        "workload": {"name": "tiny", "ops": [[64, 64, 64, 2]]}})
    assert inline.workload.ops[0].count == 2
