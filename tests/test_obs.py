"""Telemetry subsystem (`repro.obs`) tests.

Registry semantics, the Prometheus text contract (round-tripped through
``tools/check_metrics.py`` -- the same parser the CI fleet smoke uses),
the ``StatCounters`` migration facade, span tracing + the Chrome
trace_event export (including the ``repro-service trace`` CLI), the
logging selectors, the progress bus, SSE ``progress`` interleaving, and
the HTTP surface under concurrent load.  The unit tests build their own
``Registry`` / ``Tracer`` / ``ProgressBus`` instances; only the
server-level tests touch the process-wide registry, and those assert
deltas / monotonicity, never absolute values.
"""
from __future__ import annotations

import importlib.util
import json
import logging
import os
import subprocess
import sys
import threading
import urllib.request

import pytest
from test_server import _get_json, _post_json, _server
from test_service import SMALL, CountingStubEngine, _job

from repro import obs
from repro.obs.events import ProgressBus
from repro.obs.log import _parse_spec, configure_logging
from repro.obs.metrics import Registry, StatCounters
from repro.obs.trace import Tracer
from repro.service import job_to_spec
from repro.service.client import _read_sse

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_tool(name: str):
    """Import a script from tools/ (not a package) by file path."""
    path = os.path.join(REPO, "tools", f"{name}.py")
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_metrics = _load_tool("check_metrics")


# ------------------------------------------------------------------ #
# registry: instrument semantics
# ------------------------------------------------------------------ #
def test_counter_gauge_histogram_basics():
    reg = Registry()
    c = reg.counter("t_jobs_total", "jobs", ("kind",))
    c.inc(kind="a")
    c.inc(2, kind="a")
    c.inc(kind="b")
    assert c.value(kind="a") == 3
    assert c.value(kind="b") == 1
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, kind="a")
    with pytest.raises(ValueError, match="expects labels"):
        c.inc(wrong="a")

    g = reg.gauge("t_depth", "depth")
    g.set(5)
    g.inc(2)
    g.dec()
    assert g.value() == 6

    h = reg.histogram("t_latency_seconds", "latency",
                      buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 5.0, 50.0):
        h.observe(v)
    child = h.labels()
    assert child.snapshot() == (55.55, 4)
    # cumulative over (0.1, 1.0, 10.0, +Inf): one value per band
    assert child.cumulative() == [1, 2, 3, 4]


def test_registry_registration_idempotent_and_type_checked():
    reg = Registry()
    a = reg.counter("t_total", "help", ("x",))
    assert reg.counter("t_total", "other help", ("x",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("t_total", "help", ("x",))
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("t_total", "help", ("y",))
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("0bad", "help")
    with pytest.raises(ValueError, match="bad label name"):
        reg.counter("t_ok_total", "help", ("le gume",))


def test_snapshot_flattens_histograms_to_sum_and_count():
    reg = Registry()
    reg.counter("t_a_total", "a").inc(3)
    reg.histogram("t_h_seconds", "h", buckets=(1.0,)).observe(0.5)
    snap = reg.snapshot()
    assert snap["t_a_total"] == 3
    assert snap["t_h_seconds_sum"] == 0.5
    assert snap["t_h_seconds_count"] == 1
    assert not any("_bucket" in k for k in snap)


# ------------------------------------------------------------------ #
# the Prometheus text contract, via the CI gate's own parser
# ------------------------------------------------------------------ #
def test_render_roundtrips_through_check_metrics():
    reg = Registry()
    reg.counter("t_reqs_total", "requests", ("route", "method")) \
       .inc(4, route="/v1/jobs/{key}", method="GET")
    reg.gauge("t_depth", "queue depth", ("state",)).set(7, state="pending")
    reg.histogram("t_wait_seconds", "wait", buckets=(0.01, 0.1)) \
       .observe(0.05)
    # label values with every escaped character must survive the wire
    reg.counter("t_esc_total", "escaping", ("v",)) \
       .inc(v='quote " back \\ newline \n done')

    families = check_metrics.parse(reg.render())
    assert set(families) == {"t_reqs_total", "t_depth", "t_wait_seconds",
                             "t_esc_total"}
    assert families["t_reqs_total"]["type"] == "counter"
    assert families["t_depth"]["type"] == "gauge"
    assert families["t_wait_seconds"]["type"] == "histogram"
    assert check_metrics.family_total(families, "t_reqs_total") == 4
    assert check_metrics.family_total(families, "t_wait_seconds") == 1
    # the histogram emitted the full _bucket/_sum/_count series incl +Inf
    names = set(families["t_wait_seconds"]["samples"])
    assert any(name.startswith("t_wait_seconds_bucket") and "+Inf" in name
               for name in names)
    assert any(name.startswith("t_wait_seconds_sum") for name in names)


def test_check_metrics_rejects_malformed_exposition():
    with pytest.raises(ValueError):
        check_metrics.parse("t_x_total 1\n")       # sample without a TYPE
    with pytest.raises(ValueError):
        check_metrics.parse("# TYPE t_x_total counter\nt_x_total one\n")


def test_check_metrics_validates_histogram_self_consistency():
    good = (
        "# TYPE t_h histogram\n"
        't_h_bucket{k="a",le="1"} 1\n'
        't_h_bucket{k="a",le="+Inf"} 2\n'
        't_h_sum{k="a"} 3.5\n'
        't_h_count{k="a"} 2\n')
    assert check_metrics.histogram_errors(check_metrics.parse(good)) == []
    # +Inf bucket disagreeing with _count
    bad = good.replace('t_h_count{k="a"} 2', 't_h_count{k="a"} 3')
    errs = check_metrics.histogram_errors(check_metrics.parse(bad))
    assert any("+Inf bucket" in e and "_count" in e for e in errs), errs
    # cumulative counts must be monotone non-decreasing in le
    bad = good.replace('le="+Inf"} 2', 'le="+Inf"} 0')
    errs = check_metrics.histogram_errors(check_metrics.parse(bad))
    assert any("monotone" in e for e in errs), errs
    # a bucket series with no +Inf at all
    errs = check_metrics.histogram_errors(check_metrics.parse(
        "# TYPE t_h histogram\n"
        't_h_bucket{k="a",le="1"} 1\n'
        't_h_sum{k="a"} 1\nt_h_count{k="a"} 1\n'))
    assert any("+Inf" in e for e in errs), errs


def test_exemplars_render_gated_and_parse_with_span_ids(monkeypatch):
    monkeypatch.setenv("CIM_TUNER_EXEMPLARS", "1")
    reg = Registry()
    h = reg.histogram("t_ex_seconds", "x", ("k",), buckets=(0.1, 1.0))
    tr = Tracer(capacity=8)
    with tr.span("unit.ex", histogram=h.labels(k="a")):
        pass
    text = reg.render()
    assert " # {span_id=" in text
    families = check_metrics.parse(text)
    assert check_metrics.histogram_errors(families) == []
    span_ids = check_metrics.exemplar_span_ids(families)
    ev = tr.events()[-1]
    assert span_ids == {ev["id"]}, "exemplar must link the span's id"
    # the trace-json cross-check accepts the matching export...
    ex = families["t_ex_seconds"]["exemplars"]
    assert list(ex.values())[0]["value"] == pytest.approx(
        ev["dur"] / 1e6, rel=1e-2)
    # ...and the env gate strips the suffixes entirely
    monkeypatch.setenv("CIM_TUNER_EXEMPLARS", "0")
    off = reg.render()
    assert "span_id" not in off
    assert not any(rec["exemplars"]
                   for rec in check_metrics.parse(off).values())


def test_span_ids_are_unique_and_foreign_histograms_still_observe():
    tr = Tracer(capacity=8)
    h = Registry().histogram("t_plain_seconds", "x", buckets=(1.0,))

    class _Plain:                 # a histogram without exemplar support
        calls = 0

        def observe(self, value, exemplar=None):
            if exemplar is not None:
                raise TypeError("no exemplars here")
            _Plain.calls += 1

    with tr.span("unit.a", histogram=h.labels()):
        pass
    with tr.span("unit.b", histogram=_Plain()):
        pass
    ids = [e["id"] for e in tr.events()]
    assert len(set(ids)) == 2, ids
    assert _Plain.calls == 1, "TypeError fallback must re-observe"


def test_check_metrics_catalog_drift_both_directions(tmp_path):
    md = ("| family | type |\n|---|---|\n"
          "| `cim_present_total` | counter |\n"
          "| `cim_ghost_total` | counter |\n")
    text = ("# TYPE cim_present_total counter\ncim_present_total 1\n"
            "# TYPE cim_extra_total counter\ncim_extra_total 1\n")
    errs = check_metrics.catalog_drift(check_metrics.parse(text), md)
    assert any("cim_extra_total" in e and "missing from the docs" in e
               for e in errs)
    assert any("cim_ghost_total" in e and "absent from the scrape" in e
               for e in errs)
    # the CLI wires it all together, including the trace cross-check
    prom = tmp_path / "m.prom"
    prom.write_text(
        "# TYPE cim_present_total counter\ncim_present_total 1\n"
        "# TYPE t_h histogram\n"
        't_h_bucket{le="+Inf"} 1 # {span_id="77-1"} 0.5 1.0\n'
        "t_h_sum 0.5\nt_h_count 1\n")
    cat = tmp_path / "cat.md"
    cat.write_text("| `cim_present_total` | counter |\n")
    trace = tmp_path / "trace.json"
    trace.write_text(json.dumps({"traceEvents": [{"id": "77-1"}]}))
    rc = check_metrics.main([str(prom), "--require-exemplars", "t_h",
                             "--catalog", str(cat),
                             "--trace-json", str(trace)])
    assert rc == 0
    trace.write_text(json.dumps({"traceEvents": [{"id": "other"}]}))
    assert check_metrics.main([str(prom), "--trace-json",
                               str(trace)]) == 1
    assert check_metrics.main([str(prom), "--require-exemplars",
                               "cim_present_total"]) == 1


def test_check_dashboard_catches_undocumented_metrics(tmp_path):
    check_dashboard = _load_tool("check_dashboard")
    # the shipped dashboard must pass against the shipped catalog
    assert check_dashboard.main([]) == 0
    board = {"panels": [
        {"id": 1, "title": "outer", "targets": [
            {"expr": "rate(cim_real_total[5m])"}],
         "panels": [{"id": 2, "title": "nested", "targets": [
             {"expr": "histogram_quantile(0.9, cim_fake_seconds_bucket)"
              }]}]}]}
    path = tmp_path / "board.json"
    path.write_text(json.dumps(board))
    cat = tmp_path / "cat.md"
    cat.write_text("| `cim_real_total` | counter |\n")
    refs = check_dashboard.dashboard_families(board)
    assert set(refs) == {"cim_real_total", "cim_fake_seconds"}
    assert check_dashboard.main(["--dashboard", str(path),
                                 "--catalog", str(cat)]) == 1
    cat.write_text("| `cim_real_total` | counter |\n"
                   "| `cim_fake_seconds` | histogram |\n")
    assert check_dashboard.main(["--dashboard", str(path),
                                 "--catalog", str(cat)]) == 0


# ------------------------------------------------------------------ #
# kernel profiling hooks
# ------------------------------------------------------------------ #
def test_profile_gate_roofline_and_instrument(monkeypatch):
    from repro.obs import profile

    monkeypatch.delenv("CIM_TUNER_PROFILE", raising=False)
    assert not profile.profiling_enabled()
    monkeypatch.setenv("CIM_TUNER_PROFILE", "1")
    assert profile.profiling_enabled()

    # roofline: attainable is min(peak compute, bw * intensity)
    monkeypatch.setenv("CIM_TUNER_PEAK_FLOPS", "100")
    monkeypatch.setenv("CIM_TUNER_PEAK_BW", "10")
    # intensity 1 flop/byte -> bw-bound at 10 FLOP/s; achieving 5 = 50%
    assert profile.roofline_utilization(5, 5, 1.0) == pytest.approx(0.5)
    # huge intensity -> compute-bound at 100 FLOP/s
    assert profile.roofline_utilization(100, 0.001, 1.0) \
        == pytest.approx(1.0)
    assert profile.roofline_utilization(0, 0, 1.0) == 0.0
    assert profile.roofline_utilization(1, 1, 0.0) == 0.0

    calls = []
    wrapped = profile.instrument(
        "t_kernel", lambda x: calls.append(x) or x * 2,
        lambda x: f"b{x}")
    monkeypatch.delenv("CIM_TUNER_PROFILE", raising=False)
    assert wrapped(3) == 6                 # off: plain passthrough
    monkeypatch.setenv("CIM_TUNER_PROFILE", "1")
    before = profile._M_US.labels(kernel="t_kernel", bucket="b4") \
        .snapshot()[1]
    assert wrapped(4) == 8                 # on: observed into cim_kernel_us
    after = profile._M_US.labels(kernel="t_kernel", bucket="b4") \
        .snapshot()[1]
    assert after == before + 1
    assert calls == [3, 4]
    rows = [r for r in profile.summary() if r["kernel"] == "t_kernel"]
    assert rows and rows[0]["bucket"] == "b4" \
        and rows[0]["us_per_call"] > 0


# ------------------------------------------------------------------ #
# StatCounters: the legacy-dict facade
# ------------------------------------------------------------------ #
def test_statcounters_reads_like_the_legacy_dict():
    reg = Registry()
    fam = reg.counter("t_events_total", "events", ("event",))
    stats = StatCounters({"hits": fam.labels(event="hits"),
                          "misses": fam.labels(event="misses"),
                          "local_only": None})
    stats.bump("hits")
    stats.bump("hits", 2)
    stats.bump("misses")
    stats.bump("local_only", 5)
    # exact legacy read surface
    assert stats["hits"] == 3
    assert dict(stats) == {"hits": 3, "misses": 1, "local_only": 5}
    assert stats.snapshot() == dict(stats)
    assert len(stats) == 3 and set(stats) == set(dict(stats))
    assert "3" in repr(stats)
    # mirrored children saw the same increments; None stayed local
    assert fam.value(event="hits") == 3
    assert fam.value(event="misses") == 1


def test_statcounters_negative_corrections_stay_local():
    reg = Registry()
    fam = reg.counter("t_corr_total", "corrections", ("event",))
    stats = StatCounters({"hits": fam.labels(event="hits")})
    stats.bump("hits", 2)
    stats.bump("hits", -1)          # legacy correction pattern
    assert stats["hits"] == 1
    assert fam.value(event="hits") == 2, \
        "registry counters are monotonic; corrections must not decrement"


# ------------------------------------------------------------------ #
# span tracer + Chrome export
# ------------------------------------------------------------------ #
def test_tracer_records_spans_and_exports_chrome_shape(tmp_path):
    jsonl = tmp_path / "spans.jsonl"
    tr = Tracer(capacity=16, jsonl_path=str(jsonl))
    with tr.span("unit.outer", widget="a"):
        with tr.span("unit.inner"):
            pass
    events = tr.events()
    assert [e["name"] for e in events] == ["unit.inner", "unit.outer"]
    for e in events:
        assert e["ph"] == "X" and e["ts"] >= 0 and e["dur"] >= 0
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid"} <= set(e)
    assert events[1]["args"]["widget"] == "a"
    # the JSONL sink mirrors the ring buffer line-for-line
    lines = [json.loads(ln) for ln in jsonl.read_text().splitlines()]
    assert [e["name"] for e in lines] == ["unit.inner", "unit.outer"]

    doc = obs.chrome_trace(events)
    assert isinstance(doc["traceEvents"], list) and len(
        doc["traceEvents"]) == 2
    json.dumps(doc)                       # Perfetto wants plain JSON

    tr.clear()
    assert tr.events() == []


def test_tracer_ring_buffer_caps_and_histogram_observes():
    reg = Registry()
    h = reg.histogram("t_span_seconds", "span time", buckets=(60.0,))
    tr = Tracer(capacity=3)
    for i in range(5):
        with tr.span("unit.loop", histogram=h.labels(), i=i):
            pass
    events = tr.events()
    assert len(events) == 3, "ring buffer must cap at capacity"
    assert [e["args"]["i"] for e in events] == [2, 3, 4]
    assert h.labels().snapshot()[1] == 5


def test_trace_cli_exports_perfetto_loadable_file(tmp_path):
    spans = tmp_path / "spans.jsonl"
    tr = Tracer(capacity=8, jsonl_path=str(spans))
    with tr.span("cli.work", rows=3):
        pass
    out = tmp_path / "trace.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.service", "trace",
         "--input", str(spans), "--export", "chrome", "-o", str(out)],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=120)
    assert proc.returncode == 0, proc.stderr
    doc = json.loads(out.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    ev = doc["traceEvents"][0]
    assert ev["name"] == "cli.work" and ev["ph"] == "X"
    assert {"ts", "dur", "pid", "tid"} <= set(ev)


# ------------------------------------------------------------------ #
# logging selectors
# ------------------------------------------------------------------ #
def test_log_spec_parsing():
    assert _parse_spec("server") == {"server": logging.DEBUG}
    assert _parse_spec("engine,queue=INFO") == {
        "engine": logging.DEBUG, "queue": logging.INFO}
    assert _parse_spec("all=WARNING") == {"all": logging.WARNING}
    assert _parse_spec(" Server = info ") == {"server": logging.INFO}
    assert _parse_spec("") == {}
    assert _parse_spec("x=bogus") == {"x": logging.DEBUG}


def test_configure_logging_applies_selectors_idempotently():
    root = configure_logging("engine=INFO,queue", force=True)
    try:
        assert root.level == logging.WARNING
        assert logging.getLogger("repro.engine").level == logging.INFO
        assert logging.getLogger("repro.queue").level == logging.DEBUG
        assert obs.get_logger("engine").getEffectiveLevel() == logging.INFO
        # one tagged handler no matter how often we configure
        configure_logging("all=INFO", force=True)
        assert root.level == logging.INFO
        tagged = [h for h in root.handlers
                  if getattr(h, "_repro_obs", False)]
        assert len(tagged) == 1
        assert root.propagate is False
    finally:
        configure_logging("", force=True)
        logging.getLogger("repro.engine").setLevel(logging.NOTSET)
        logging.getLogger("repro.queue").setLevel(logging.NOTSET)


# ------------------------------------------------------------------ #
# progress bus
# ------------------------------------------------------------------ #
def test_progress_bus_replays_history_then_delivers_live():
    bus = ProgressBus(history_per_key=4)
    bus.publish("k1", phase="race", rung=0)
    bus.publish("k1", phase="race", rung=1)
    bus.publish("other", phase="race", rung=0)

    got: list[dict] = []
    history = bus.subscribe(["k1"], lambda key, ev: got.append(ev))
    assert [ev["seq"] for ev in history] == [0, 1]
    assert all(ev["key"] == "k1" for ev in history)
    live = bus.publish("k1", phase="final")
    bus.publish("other", phase="final")      # not subscribed: not seen
    assert got == [live]
    assert live["seq"] == 2, "seq must stay monotonic across the boundary"

    bus.unsubscribe(lambda key, ev: None)    # unknown sink: no-op
    bus.unsubscribe(got.append)


def test_progress_bus_bounds_history_and_keys():
    bus = ProgressBus(history_per_key=2, max_keys=2)
    for rung in range(5):
        bus.publish("k1", rung=rung)
    assert [ev["rung"] for ev in bus.subscribe(["k1"], lambda *a: None)] \
        == [3, 4]
    bus.publish("k2")
    bus.publish("k3")                        # evicts the LRU key (k1)
    assert bus.subscribe(["k1"], lambda *a: None) == []
    assert bus.publish("k1")["seq"] == 0, "evicted key restarts its seq"


def test_progress_bus_survives_broken_sinks():
    bus = ProgressBus()

    def broken(key, ev):
        raise RuntimeError("dead subscriber")

    got = []
    bus.subscribe(["k"], broken)
    bus.subscribe(["k"], lambda key, ev: got.append(ev))
    bus.publish("k", rung=0)
    assert len(got) == 1, "one broken sink must not stall the others"


# ------------------------------------------------------------------ #
# HTTP surface: /v1/metrics, /v1/stats shape, concurrent load
# ------------------------------------------------------------------ #
def test_metrics_endpoint_serves_parseable_prometheus(tmp_path):
    srv = _server(tmp_path)
    try:
        _post_json(f"{srv.url}/v1/jobs?wait=30",
                   [job_to_spec(_job(), "exhaustive")])
        req = urllib.request.urlopen(f"{srv.url}/v1/metrics", timeout=30)
        with req as resp:
            ctype = resp.headers.get("Content-Type", "")
            body = resp.read().decode()
        assert ctype.startswith("text/plain") and "version=0.0.4" in ctype
        families = check_metrics.parse(body)
        assert len(families) >= 12
        for fam in ("cim_queue_submitted_total", "cim_queue_depth",
                    "cim_queue_wait_seconds", "cim_store_ops_total",
                    "cim_http_requests_total", "cim_http_request_seconds",
                    "cim_engine_jobs_total", "cim_search_pulls_total"):
            assert fam in families, f"missing family {fam}"
        assert check_metrics.family_total(
            families, "cim_queue_submitted_total") >= 1
        assert check_metrics.family_total(
            families, "cim_http_requests_total") >= 1
        # /v1/stats keeps its legacy JSON shape on the same numbers
        stats = _get_json(f"{srv.url}/v1/stats")
        assert {"queue", "server", "store"} <= set(stats)
        assert {"submitted", "store_hits", "inflight_dedup", "dispatches",
                "completed", "failed"} <= set(stats["queue"])
        assert stats["queue"]["submitted"] >= 1
    finally:
        srv.shutdown()


def test_stats_and_metrics_consistent_under_concurrent_load(tmp_path):
    """N reader threads hammer /v1/stats + /v1/metrics while a blocked
    batch is in flight and further jobs stream in: every stats snapshot
    must be internally consistent (no torn reads) and every counter
    monotonic across samples; every metrics scrape must stay parseable."""
    from repro.configs import get_arch
    eng = CountingStubEngine()
    from repro.core import ExploreJob
    from repro.core.macro import TPDCIM_MACRO
    slow_wl = get_arch("whisper-small").workload(seq=512)
    eng.block_buckets = {eng.bucket_key(
        ExploreJob(TPDCIM_MACRO, slow_wl, 2.23, space=SMALL), "exhaustive")}
    srv = _server(tmp_path, engine=eng)
    errors: list[str] = []
    stop = threading.Event()

    def reader():
        last: dict[str, float] = {}
        while not stop.is_set():
            try:
                stats = _get_json(f"{srv.url}/v1/stats")
                flat = {f"{sec}.{k}": v
                        for sec in ("queue", "server", "store")
                        for k, v in stats[sec].items()
                        if isinstance(v, (int, float))}
                for k in ("queue.submitted", "queue.dispatches",
                          "queue.completed", "server.requests"):
                    if flat[k] < last.get(k, 0):
                        errors.append(
                            f"{k} went backwards: {last[k]} -> {flat[k]}")
                    last[k] = flat[k]
                if flat["queue.completed"] > flat["queue.submitted"]:
                    errors.append(f"torn read: {flat}")
                with urllib.request.urlopen(f"{srv.url}/v1/metrics",
                                            timeout=30) as resp:
                    check_metrics.parse(resp.read().decode())
            except Exception as exc:      # noqa: BLE001 -- collected
                errors.append(f"reader died: {exc!r}")
                return

    threads = [threading.Thread(target=reader) for _ in range(4)]
    try:
        # hold one bucket open (the single queue worker blocks on it),
        # then pile further submissions on top: admission-side counters
        # (submitted, depth, store misses, http requests) keep moving on
        # the handler threads while the batch is active
        out = _post_json(f"{srv.url}/v1/jobs",
                         [job_to_spec(_job(wl=slow_wl), "exhaustive")])
        keys = [out["jobs"][0]["key"]]
        for t in threads:
            t.start()
        for budget in (2.23, 3.0, 4.0, 5.0):
            out = _post_json(f"{srv.url}/v1/jobs",
                             [job_to_spec(_job(budget=budget),
                                          "exhaustive")])
            keys.append(out["jobs"][0]["key"])
        eng.release.set()
        url = f"{srv.url}/v1/stream?keys={','.join(keys)}&timeout=30"
        with urllib.request.urlopen(url, timeout=60) as resp:
            done = {obj["key"] for event, obj in _read_sse(resp)
                    if event == "result"}
        assert done == set(keys)
    finally:
        eng.release.set()
        stop.set()
        for t in threads:
            t.join(timeout=30)
        srv.shutdown()
    assert not errors, errors[:5]


# ------------------------------------------------------------------ #
# SSE progress events
# ------------------------------------------------------------------ #
def test_stream_interleaves_progress_before_result(tmp_path):
    """A subscriber must see per-rung ``progress`` events -- including
    ones published before the stream attached (history replay) -- ahead
    of the final ``result`` for the same key."""
    # a budget no other test uses: the progress bus is process-global and
    # keyed by canonical job_key, so publishing against a shared job would
    # leak replayed history into other tests streaming the same key
    job = _job(budget=7.77)
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(job, "exhaustive")}
    srv = _server(tmp_path, engine=eng)
    try:
        out = _post_json(f"{srv.url}/v1/jobs",
                         [job_to_spec(job, "exhaustive")])
        key = out["jobs"][0]["key"]
        # rung events fire while the job computes, BEFORE the client
        # attaches its stream -- exactly the POST-then-stream race
        bus = obs.progress_bus()
        bus.publish(key, phase="race", allocator="bandit", rung=0,
                    best=2.0, pulls={"sa": 1})
        bus.publish(key, phase="race", allocator="bandit", rung=1,
                    best=1.0, pulls={"sa": 2})
        url = f"{srv.url}/v1/stream?keys={key}&timeout=30"
        events = []
        with urllib.request.urlopen(url, timeout=60) as resp:
            it = _read_sse(resp)
            for event, obj in it:
                events.append((event, obj))
                if event == "progress" and obj.get("rung") == 1:
                    # live event after the replay, then let it finish
                    bus.publish(key, phase="final", best=1.0)
                    eng.release.set()
                if event == "end":
                    break
        kinds = [e for e, _ in events]
        assert kinds.index("progress") < kinds.index("result")
        progress = [obj for e, obj in events if e == "progress"]
        assert [p["seq"] for p in progress] == [0, 1, 2]
        assert [p["phase"] for p in progress] == ["race", "race", "final"]
        assert progress[0]["rung"] == 0 and progress[0]["key"] == key
        assert kinds[-2:] == ["result", "end"]
    finally:
        eng.release.set()
        srv.shutdown()


# Runs in a child interpreter: one more real XLA engine run inside the
# suite process shifts native allocator state enough that a later jitted
# test aborts with glibc heap corruption ("corrupted double-linked
# list"); the bus/engine wiring under test is identical either way.
_PORTFOLIO_PROGRESS_CHILD = """
import json, sys
from test_service import _job
from repro import obs
from repro.core import ExplorationEngine, job_key
from repro.search import PortfolioSettings
from repro.service.queue import resolve_settings

settings = resolve_settings(
    "portfolio", PortfolioSettings(backends=("sobol", "sa"),
                                   total_evals=512, rungs=2))
job = _job(budget=7.91)
key = job_key(job, "portfolio", settings)
got = []
bus = obs.progress_bus()
bus.subscribe([key], lambda k, ev: got.append(ev))
res = ExplorationEngine().run([job], method="portfolio",
                              settings=settings)[0]
json.dump({"key": key, "winner": res.search["portfolio"]["winner"],
           "events": got}, sys.stdout)
"""


@pytest.mark.slow
def test_portfolio_run_publishes_per_rung_progress():
    """The real engine's portfolio path publishes >= 1 per-rung race
    event and a final event for each job's key."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c", _PORTFOLIO_PROGRESS_CHILD],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=300)
    assert proc.returncode == 0, proc.stderr
    out = json.loads(proc.stdout)
    key, got = out["key"], out["events"]
    assert out["winner"] in ("sobol", "sa")
    phases = [ev["phase"] for ev in got]
    assert phases.count("race") >= 1, got
    assert phases[-1] == "final"
    assert all(ev["key"] == key for ev in got)
    race = [ev for ev in got if ev["phase"] == "race"]
    assert {"allocator", "rung", "best", "pulls"} <= set(race[0])
