"""Hypothesis property: shape-bucket padding is value-transparent through
the batched/streamed path.

For random small workloads, submitting a job through the service queue
alongside a companion job that (a) pads the operator bucket (more merged
ops) and (b) has a different pruned-candidate count (different budget, so
the exhaustive sweep's chunk lanes pad differently) must produce the exact
same best config and metrics -- bit for bit -- as a solo single-job
``ExplorationEngine.run()``.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    MatmulOp,
    Workload,
    get_macro,
)
from repro.service import JobQueue, QueueConfig  # noqa: E402

pytestmark = pytest.mark.slow      # hypothesis sweeps re-trace per example

MACRO = get_macro("vanilla-dcim")
TINY = DesignSpace(mr=(1, 2), mc=(1, 2), scr=(1, 4),
                   is_kb=(2, 16), os_kb=(2, 16))

op_st = st.tuples(
    st.integers(1, 96),          # m
    st.integers(1, 512),         # k
    st.integers(1, 256),         # n
    st.integers(1, 4),           # count
    st.booleans(),               # weights_static
)
workload_st = st.lists(op_st, min_size=1, max_size=6)


def _workload(ops, name="prop"):
    return Workload(name, tuple(
        MatmulOp(m=m, k=k, n=n, count=c, weights_static=w,
                 name=f"op{i}")
        for i, (m, k, n, c, w) in enumerate(ops)))


# 7 distinct merged ops -> pads the 8-wide operator bucket that 5-6-op
# random workloads share; its larger budget keeps MORE pruned candidates,
# so the shared [jobs, chunk] sweep pads the small job's exhausted lane
BIG_JOB = ExploreJob(
    MACRO,
    _workload([(64, 64 + 8 * i, 64, 1, True) for i in range(7)],
              name="big"),
    5.0, objective="ee", space=TINY)

# module-level engines/queue: the executable cache amortizes compiles
# across hypothesis examples (results are state-independent)
SOLO_ENGINE = ExplorationEngine()
QUEUE = JobQueue(engine=ExplorationEngine(), store=None,
                 config=QueueConfig(batch_window_s=0.02))


@settings(max_examples=15, deadline=None)
@given(ops=workload_st, objective=st.sampled_from(["ee", "th"]))
def test_streamed_best_cost_equals_single_job_bitwise(ops, objective):
    wl = _workload(ops)
    job = ExploreJob(MACRO, wl, 3.0, objective=objective, space=TINY)

    solo = SOLO_ENGINE.run([job], method="exhaustive")[0]

    futs = QUEUE.submit_many([job, BIG_JOB], method="exhaustive")
    streamed = futs[0].result(timeout=600)

    assert streamed.config.as_tuple() == solo.config.as_tuple()
    for key in ("energy_pj", "latency_cycles", "tops_w", "gops",
                "area_mm2"):
        assert streamed.metrics[key] == solo.metrics[key], \
            (key, "padded/streamed value differs from solo run")


# ------------------------------------------------------------------ #
# scheduler liveness: arbitrary submit/close interleavings (stub
# engine, no JAX) -- every accepted future resolves exactly once and
# the store ends up holding exactly the resolved job keys
# ------------------------------------------------------------------ #
op_seq_st = st.lists(st.integers(0, 5), min_size=1, max_size=10)


class _PropEngine:
    """Instant stub that still exercises the admission path: one
    admission poll per dispatch, results in engine order."""

    def bucket_key(self, job, method=None):
        return ("prop-bucket",)

    def run(self, jobs, method=None, settings=None, sa_settings=None,
            keys=None, admit=None):
        from test_service import _fake_result
        jobs = list(jobs)
        if admit is not None:
            for job, _key in admit():
                jobs.append(job)
        return [_fake_result(j) for j in jobs]


@settings(max_examples=25, deadline=None)
@given(ops=op_seq_st, close_at=st.integers(0, 10))
def test_submit_close_interleavings_resolve_exactly_once(ops, close_at):
    import shutil
    import tempfile

    from repro.search import PortfolioSettings
    from repro.service import ResultStore

    bandit = PortfolioSettings(backends=("sa", "sobol"),
                               total_evals=64, rungs=2)
    root = tempfile.mkdtemp(prefix="cim-sched-prop-")
    q = JobQueue(engine=_PropEngine(), store=ResultStore(root),
                 config=QueueConfig(batch_window_s=0.005,
                                    max_batch_jobs=3))
    futures, counts = [], {}
    try:
        for i, v in enumerate(ops):
            if i == close_at:
                q.close()
            job = ExploreJob(
                MACRO, _workload([(8, 8, 8, 1, True)], name=f"wl{v % 3}"),
                3.0 + v * 1e-6, objective="ee", space=TINY)
            # odd variants ride the continuous bandit-portfolio path,
            # even ones the plain window path
            kwargs = ({"method": "portfolio", "settings": bandit}
                      if v % 2 else {"method": "exhaustive"})
            try:
                f = q.submit(job, **kwargs)
            except RuntimeError:
                assert i >= close_at, "open queue rejected a submission"
                continue
            counts[id(f)] = 0
            f.add_done_callback(
                lambda fut: counts.__setitem__(
                    id(fut), counts[id(fut)] + 1))
            futures.append(f)
        q.close()
        for f in futures:
            assert f.wait(30), "close() stranded an accepted future"
            assert f.exception(0) is None
            assert counts[id(f)] == 1, "future resolved more than once"
        store = ResultStore(root)
        assert set(store.keys()) == {f.key for f in futures}, \
            "store contents != resolved job keys"
    finally:
        q.close()
        shutil.rmtree(root, ignore_errors=True)
