"""Hypothesis property: the portfolio racer never returns worse than its
best constituent's result on the same seed -- under EITHER budget
allocator (UCB bandit or fixed-rung halving).

Every race run is bit-reproducible standalone (constituent settings +
derived seeds come deterministically from the portfolio settings via
``race_plan`` / ``bandit_pull_plan``; the bandit's initialization pulls
ARE halving's rung 0), and the racer reports the min across all phases --
so for any seed/budget/allocator the portfolio's best raw objective must
be <= every constituent's rung-0 best.  Seeds are normalized out of the
engine's executable cache key, so the sweep re-uses one compile per
(backend, budget) and only the RNG inputs vary.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings as hyp_settings, strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    bert_large_workload,
    get_macro,
)
from repro.search import PortfolioSettings, race_plan  # noqa: E402

pytestmark = pytest.mark.slow      # hypothesis sweep (nightly tier)

MACRO = get_macro("vanilla-dcim")
SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))

# module-level engine: the executable cache amortizes compiles across
# hypothesis examples (seeds vary, shapes/budgets mostly don't)
ENGINE = ExplorationEngine()


@hyp_settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1_000_000),
       total_evals=st.sampled_from([800, 1600]),
       objective=st.sampled_from(["ee", "th"]),
       allocator=st.sampled_from(["bandit", "halving"]))
def test_portfolio_never_worse_than_best_constituent(
        seed, total_evals, objective, allocator):
    job = ExploreJob(MACRO, bert_large_workload(), 3.0,
                     objective=objective, space=SMALL)
    pf_settings = PortfolioSettings(total_evals=total_evals, seed=seed,
                                    allocator=allocator)
    pf = ENGINE.run([job], method="portfolio", settings=pf_settings)[0]
    pf_best = float(pf.sa.best_value)

    race = pf.search["portfolio"]["race"]
    assert pf_best <= min(race.values()) + 1e-9

    rung0 = race_plan(pf_settings)[0]
    for name in pf_settings.backends:
        solo = ENGINE.run([job], method=name, settings=rung0[name])[0]
        assert pf_best <= float(solo.sa.best_value) + 1e-9, (name, seed)
