"""HLO call-graph analyzer: loop-trip-count correctness + parser units."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import _type_bytes, analyze, parse_module


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()
    for layers in (2, 8):
        c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                     jax.ShapeDtypeStruct((layers, 256, 256), jnp.float32))
        got = analyze(c.as_text())["dot_flops"]
        assert got == 2 * 128 * 256 * 256 * layers, layers


def test_nested_scan_flops():
    def g(x, w):
        def outer(h, wi):
            def inner(h2, _):
                return jnp.tanh(h2 @ wi), None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h.sum()
    c = _compile(g, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((4, 256, 256), jnp.float32))
    got = analyze(c.as_text())["dot_flops"]
    assert got == 2 * 128 * 256 * 256 * 12


def test_unrolled_matches_xla_cost_analysis():
    def f(x, w):
        for i in range(4):
            x = x @ w[i]
        return x.sum()
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((4, 64, 64), jnp.float32))
    from repro.compat import compiled_cost_analysis
    ours = analyze(c.as_text())["dot_flops"]
    xla = compiled_cost_analysis(c)["flops"]
    # unrolled: both must count all 4 matmuls (xla adds small reduce flops)
    assert abs(ours - 2 * 64 * 64 * 64 * 4) < 1e-6
    assert ours <= xla <= ours * 1.02


def test_type_bytes_parser():
    assert _type_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _type_bytes("bf16[2,3]") == 12
    assert _type_bytes("(f32[4], s8[8])") == 24
    assert _type_bytes("pred[]") == 1
    assert _type_bytes("token[]") == 0


def test_parse_module_finds_entry_and_while():
    def f(x):
        def body(h, _):
            return jnp.tanh(h) * 1.01, None
        h, _ = jax.lax.scan(body, x, None, length=5)
        return h.sum()
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    comps, entry = parse_module(c.as_text())
    assert entry in comps
    mults = [m for comp in comps.values() for (_cal, m) in comp.edges]
    assert 5 in mults                       # trip count discovered


def test_hbm_write_bytes_lower_than_total():
    def f(x, w):
        return jnp.tanh(x @ w).sum()
    c = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    t = analyze(c.as_text())
    assert 0 < t["hbm_write_bytes"] <= t["hbm_bytes"]
