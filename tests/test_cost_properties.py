"""Hypothesis property tests on the cost model's invariants."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install .[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.compat import enable_x64  # noqa: E402
from repro.core import (  # noqa: E402
    ALL_STRATEGIES,
    AcceleratorConfig,
    get_macro,
    matmul_cost,
    strategy_feasible,
)
from repro.core.cost_model import INFEASIBLE  # noqa: E402

pytestmark = pytest.mark.slow      # hypothesis sweeps re-trace per example

MACRO = get_macro("vanilla-dcim")

cfg_st = st.builds(
    AcceleratorConfig,
    mr=st.integers(1, 4), mc=st.integers(1, 4),
    scr=st.sampled_from([1, 2, 4, 8, 16, 32]),
    is_kb=st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128]),
    os_kb=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
    bw=st.just(256),
)
dims_st = st.tuples(st.integers(1, 96), st.integers(1, 700),
                    st.integers(1, 500))


def _cost(cfg, m, k, n, s):
    return matmul_cost(
        m, k, n, float(s.spatial == "R"), float(s.temporal == "WP"),
        float(s.tiling == "PF"), cfg.mr, cfg.mc, cfg.scr, cfg.is_kb,
        cfg.os_kb, cfg.bw, 1.0, MACRO)


@settings(max_examples=40, deadline=None)
@given(cfg=cfg_st, dims=dims_st)
def test_af_reads_inputs_more_pf_writes_psums_more(cfg, dims):
    """Paper Fig. 8: AF raises Input-SRAM overhead, PF raises Output-SRAM
    overhead (per-strategy-pair, same scheduling)."""
    m, k, n = dims
    with enable_x64(True):
        af = _cost(cfg, m, k, n, ALL_STRATEGIES[0])   # NR-IP-AF
        pf = _cost(cfg, m, k, n, ALL_STRATEGIES[1])   # NR-IP-PF
    assert float(af.is_rd_bits) >= float(pf.is_rd_bits)
    assert float(pf.os_wr_bits) >= float(af.os_wr_bits)


@settings(max_examples=40, deadline=None)
@given(cfg=cfg_st, dims=dims_st)
def test_wp_streams_inputs_once(cfg, dims):
    """Weight-priority keeps IS rows resident: streamed-matrix traffic under
    WP never exceeds IP's."""
    m, k, n = dims
    s_ip, s_wp = ALL_STRATEGIES[0], ALL_STRATEGIES[2]
    if not strategy_feasible(MACRO, cfg, m, k, n, s_wp):
        return
    with enable_x64(True):
        ip = _cost(cfg, m, k, n, s_ip)
        wp = _cost(cfg, m, k, n, s_wp)
    assert float(wp.v_ema_bits) <= float(ip.v_ema_bits)
    # ... at the price of >= weight reloads
    assert float(wp.s_ema_bits) >= float(ip.s_ema_bits)


@settings(max_examples=40, deadline=None)
@given(cfg=cfg_st, dims=dims_st)
def test_latency_positive_and_energy_scales(cfg, dims):
    m, k, n = dims
    with enable_x64(True):
        cb = _cost(cfg, m, k, n, ALL_STRATEGIES[0])
    lat, en = float(cb.latency_cycles), float(cb.energy_pj)
    assert lat > 0 and en > 0
    if lat < INFEASIBLE:
        assert float(cb.macs) >= m * k * n           # padding only adds
        assert float(cb.ema_bits) >= m * n * MACRO.dw_out  # outputs at least


@settings(max_examples=30, deadline=None)
@given(cfg=cfg_st, dims=dims_st)
def test_bigger_buffers_never_increase_traffic(cfg, dims):
    """Growing IS can only reduce (or keep) external streamed traffic."""
    import dataclasses
    m, k, n = dims
    big = dataclasses.replace(cfg, is_kb=cfg.is_kb * 8)
    with enable_x64(True):
        small_c = _cost(cfg, m, k, n, ALL_STRATEGIES[0])
        big_c = _cost(big, m, k, n, ALL_STRATEGIES[0])
    assert float(big_c.v_ema_bits) <= float(small_c.v_ema_bits)


@settings(max_examples=30, deadline=None)
@given(dims=dims_st, scr1=st.sampled_from([1, 2, 4]),
       scale=st.sampled_from([2, 4, 8]))
def test_bigger_scr_never_more_af_spill(dims, scr1, scale):
    """More resident planes => fewer AF accumulation groups => less psum
    spill (the SCR storage-vs-compute trade the paper optimizes)."""
    m, k, n = dims
    c1 = AcceleratorConfig(2, 2, scr1, 16, 4)
    c2 = AcceleratorConfig(2, 2, scr1 * scale, 16, 4)
    with enable_x64(True):
        a = _cost(c1, m, k, n, ALL_STRATEGIES[0])
        b = _cost(c2, m, k, n, ALL_STRATEGIES[0])
    assert float(b.spill_ema_bits) <= float(a.spill_ema_bits)
