"""THE core validation: the closed-form cost model must reproduce the
instruction-flow compiler's per-set schedule sums exactly (integer for
integer) for every strategy, and the address-level trace must perform the
exact matrix multiplication under IS/CIM/OS capacity invariants."""
import numpy as np
import pytest

from repro.compat import enable_x64

from repro.core import (
    ALL_STRATEGIES,
    AcceleratorConfig,
    compile_schedule,
    compile_trace,
    get_macro,
    matmul_cost,
    replay_trace,
    schedule_totals,
    strategy_feasible,
)

FIELDS = dict(
    v_bits="v_ema_bits", s_bits="s_ema_bits", spill_bits="spill_ema_bits",
    y_bits="y_ema_bits", is_rd_bits="is_rd_bits", is_wr_bits="is_wr_bits",
    os_rd_bits="os_rd_bits", os_wr_bits="os_wr_bits",
    compute_cycles="compute_cycles", update_cycles="update_cycles",
)


def _closed_form(macro, cfg, m, k, n, s):
    return matmul_cost(
        m, k, n,
        float(s.spatial == "R"), float(s.temporal == "WP"),
        float(s.tiling == "PF"),
        cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw,
        1.0, macro)


def _random_cases(n_cases, seed):
    rng = np.random.default_rng(seed)
    macros = [get_macro(x) for x in
              ("vanilla-dcim", "lcc-cim", "trancim-macro", "fpcim")]
    for i in range(n_cases):
        yield (
            macros[i % len(macros)],
            AcceleratorConfig(
                mr=int(rng.integers(1, 4)), mc=int(rng.integers(1, 4)),
                scr=int(2 ** rng.integers(0, 6)),
                is_kb=int(2 ** rng.integers(0, 8)),
                os_kb=int(2 ** rng.integers(0, 7)), bw=256),
            int(rng.integers(1, 80)), int(rng.integers(1, 600)),
            int(rng.integers(1, 500)),
        )


def test_closed_form_matches_compiler_exactly():
    checked = 0
    with enable_x64(True):
        for macro, cfg, m, k, n in _random_cases(40, seed=123):
            for s in ALL_STRATEGIES:
                if not strategy_feasible(macro, cfg, m, k, n, s):
                    continue
                tot = schedule_totals(compile_schedule(macro, cfg, m, k, n, s))
                cb = _closed_form(macro, cfg, m, k, n, s)
                for sf, cf in FIELDS.items():
                    assert tot[sf] == float(getattr(cb, cf)), (
                        f"{sf} mismatch: {s} op={(m, k, n)} "
                        f"cfg={cfg.as_tuple()} macro={macro.name}")
                checked += 1
    assert checked > 150


def test_compute_cycles_strategy_invariant():
    """Total plane-compute work is identical across temporal/tiling (padding
    aside) -- the mapping only re-orders it."""
    macro = get_macro("vanilla-dcim")
    cfg = AcceleratorConfig(2, 2, 8, 32, 16)
    with enable_x64(True):
        for (m, k, n) in ((64, 300, 200), (17, 100, 90)):
            vals = set()
            for s in ALL_STRATEGIES:
                if s.spatial == "R" or not strategy_feasible(
                        macro, cfg, m, k, n, s):
                    continue
                cb = _closed_form(macro, cfg, m, k, n, s)
                vals.add(float(cb.compute_cycles))
            assert len(vals) == 1


@pytest.mark.parametrize("strategy", ALL_STRATEGIES, ids=str)
def test_trace_replay_computes_matmul(strategy):
    """The compiled instruction flow performs the exact matrix product (the
    paper's silicon-verification 'validation script')."""
    rng = np.random.default_rng(7)
    macro = get_macro("vanilla-dcim")
    for cfg, (m, k, n) in [
        (AcceleratorConfig(2, 2, 4, 8, 2), (37, 200, 150)),
        (AcceleratorConfig(1, 1, 2, 4, 1), (9, 70, 40)),
        (AcceleratorConfig(3, 2, 16, 64, 8), (21, 500, 120)),
    ]:
        if not strategy_feasible(macro, cfg, m, k, n, strategy):
            continue
        x = rng.integers(-4, 4, (m, k)).astype(np.float64)
        w = rng.integers(-4, 4, (k, n)).astype(np.float64)
        tr = compile_trace(macro, cfg, m, k, n, strategy)
        y = replay_trace(tr, x, w, macro, cfg, strategy)
        np.testing.assert_allclose(y, x @ w)


def test_reversed_is_swap_symmetry():
    """R(m,k,n) == NR(n,k,m) when streamed/stationary widths are equal."""
    macro = get_macro("vanilla-dcim")
    cfg = AcceleratorConfig(2, 2, 4, 16, 8)
    with enable_x64(True):
        for s_idx in (0, 1, 2, 3):
            s = ALL_STRATEGIES[s_idx]            # NR variants
            r = ALL_STRATEGIES[s_idx + 4]        # matching R variants
            a = _closed_form(macro, cfg, 40, 300, 120, r)
            b = _closed_form(macro, cfg, 120, 300, 40, s)
            assert float(a.latency_cycles) == float(b.latency_cycles)
            assert float(a.ema_bits) == float(b.ema_bits)


def test_infeasible_strategies_get_sentinel():
    from repro.core.cost_model import INFEASIBLE
    macro = get_macro("fpcim")    # AL=128 -> big rows
    # IS too small to hold one full row: WP infeasible, IP fine
    cfg = AcceleratorConfig(2, 1, 2, 1, 8)      # 1 KB IS
    m, k, n = 32, 4096, 256
    with enable_x64(True):
        wp = _closed_form(macro, cfg, m, k, n, ALL_STRATEGIES[2])  # NR-WP-AF
        ip = _closed_form(macro, cfg, m, k, n, ALL_STRATEGIES[0])  # NR-IP-AF
    assert float(wp.latency_cycles) == INFEASIBLE
    assert float(ip.latency_cycles) < INFEASIBLE
