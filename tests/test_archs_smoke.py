"""Per-architecture smoke tests: reduced family-faithful configs run one
forward/train step on CPU with finite outputs, and cached decode matches the
uncached forward (catches KV/ring/state cache bugs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import build_model


def _batch(cfg, key, b=2, t=16):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab),
             "labels": jax.random.randint(ks[1], (b, t), 0, cfg.vocab)}
    if cfg.n_memory:
        batch["memory"] = jax.random.normal(
            ks[2], (b, cfg.n_memory, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_and_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), arch_id
    assert metrics["tokens"] > 0

    # one full optimizer step (gradients flow through every block)
    from repro.launch.steps import make_train_step
    from repro.optim import AdamW
    step = jax.jit(make_train_step(model, AdamW()))
    new_params, _, m2 = step(params, AdamW().init(params), batch)
    assert jnp.isfinite(m2["loss"])
    assert jnp.isfinite(m2["grad_norm"])
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_consistency(arch_id):
    """Greedy decode after prefill must match the uncached full forward at
    the same position (validates every cache variant: full KV, SWA ring,
    conv+SSM state, RG-LRU state, cross-KV)."""
    cfg = get_arch(arch_id).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    b, t = 2, 12
    batch = _batch(cfg, key, b=b, t=t + 1)
    toks = batch["tokens"]

    # cached: prefill on first t tokens, decode token t
    pb = {"tokens": toks[:, :t], "caches": model.init_cache(b, t + 4)}
    if "memory" in batch:
        pb["memory"] = batch["memory"]
    logits_p, caches = jax.jit(model.prefill)(params, pb)
    logits_d, _ = jax.jit(model.decode)(params, caches, toks[:, t:t + 1])

    # uncached ground truth
    fb = {"tokens": toks}
    if "memory" in batch:
        fb["memory"] = batch["memory"]
    from repro.models import transformer as tf
    mem = None
    if cfg.n_memory:
        mem = fb["memory"].astype(jnp.bfloat16)
        if cfg.encoder_layers:
            mem = tf.encode_memory(params, cfg, mem)
    full_logits, _, _ = jax.jit(
        lambda p, tk, mm: tf.lm_apply(p, cfg, tk, memory=mm))(
        params, toks, mem)

    got = np.asarray(logits_d[:, 0])
    want = np.asarray(full_logits[:, t])
    # bf16 compute: compare top-1 agreement + numeric closeness
    np.testing.assert_allclose(got, want, atol=0.2, rtol=0.1)
    assert (got.argmax(-1) == want.argmax(-1)).mean() >= 0.5
    # prefill logits must also match the full forward on the prefix
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, t - 1]),
        atol=0.2, rtol=0.1)


def test_swa_ring_cache_long_decode():
    """Ring cache beyond the window: decoding past the window keeps shapes
    and numerics finite (danube reduced, window=32)."""
    cfg = get_arch("h2o-danube-3-4b").reduced()
    assert cfg.window == 32
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = 1
    caches = model.init_cache(b, 64)
    pb = {"tokens": jnp.ones((b, 40), jnp.int32), "caches": caches}
    logits, caches = jax.jit(model.prefill)(params, pb)
    dec = jax.jit(model.decode)
    tok = jnp.ones((b, 1), jnp.int32)
    for _ in range(6):
        logits, caches = dec(params, caches, tok)
    assert bool(jnp.isfinite(logits).all())
    assert int(caches["step"]) == 46
