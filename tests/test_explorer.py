"""Pruning + SA + exhaustive co-exploration tests."""
from repro.core import (
    AcceleratorConfig,
    DesignSpace,
    SASettings,
    co_explore,
    evaluate_config,
    get_macro,
    prune_space,
)
from repro.core.ir import bert_large_workload
from repro.core.macro import TPDCIM_MACRO

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


def test_prune_space_counts():
    cands, stats = prune_space(SMALL, get_macro("vanilla-dcim"),
                               area_budget_mm2=3.0)
    assert stats["raw"] == SMALL.size == 3 * 2 * 3 * 3 * 3
    assert stats["kept"] == len(cands)
    assert stats["kept"] + stats["bandwidth_pruned"] + \
        stats["area_pruned"] == stats["raw"]
    assert stats["pruned_fraction"] > 0.0
    # every surviving candidate respects the budget + bandwidth rule
    from repro.core.template import accelerator_area_mm2, bandwidth_ok
    for row in cands:
        cfg = AcceleratorConfig(*[int(x) for x in row])
        assert accelerator_area_mm2(cfg, get_macro("vanilla-dcim")) <= 3.0
        assert bandwidth_ok(cfg, get_macro("vanilla-dcim"))


def test_fixed_axes():
    s = SMALL.fix(mr=2, scr=16)
    assert s.mr == (2,) and s.scr == (16,)
    assert s.mc == SMALL.mc


def test_sa_matches_exhaustive_on_small_space():
    wl = bert_large_workload()
    kw = dict(macro=TPDCIM_MACRO, workload=wl, area_budget_mm2=2.23,
              objective="ee", space=SMALL)
    ex = co_explore(method="exhaustive", **kw)
    sa = co_explore(method="sa",
                    sa_settings=SASettings(n_chains=24, n_steps=120, seed=1),
                    **kw)
    # SA must reach within 1% of the exhaustive optimum
    assert sa.metrics["energy_pj"] <= ex.metrics["energy_pj"] * 1.01
    assert ex.config.scr >= 1


def test_objectives_differ():
    wl = bert_large_workload()
    ee = co_explore(TPDCIM_MACRO, wl, 2.23, objective="ee",
                    method="exhaustive", space=SMALL)
    th = co_explore(TPDCIM_MACRO, wl, 2.23, objective="th",
                    method="exhaustive", space=SMALL)
    assert th.metrics["gops"] >= ee.metrics["gops"] * 0.999
    assert ee.metrics["tops_w"] >= th.metrics["tops_w"] * 0.999


def test_st_dominates_so():
    """CIM-Tuner's scheduling+tiling space contains [19]'s spatial-only
    space, so the per-config optimum can only improve (Fig. 7 mechanism)."""
    wl = bert_large_workload()
    cfg = AcceleratorConfig(2, 2, 8, 16, 16)
    st_m = evaluate_config(TPDCIM_MACRO, cfg, wl, strategy_set="st")
    so_m = evaluate_config(TPDCIM_MACRO, cfg, wl, strategy_set="so")
    assert st_m["energy_pj"] <= so_m["energy_pj"] * (1 + 1e-9)
    assert st_m["latency_cycles"] <= so_m["latency_cycles"] * (1 + 1e-9)


def test_budget_respected():
    wl = bert_large_workload()
    res = co_explore(TPDCIM_MACRO, wl, 2.0, method="exhaustive", space=SMALL)
    assert res.metrics["area_mm2"] <= 2.0 + 1e-6


def test_per_op_strategies_reported():
    wl = bert_large_workload()
    res = co_explore(TPDCIM_MACRO, wl, 2.23, method="exhaustive", space=SMALL)
    assert len(res.per_op_strategy) == len(wl.merged().ops)
    for v in res.per_op_strategy.values():
        assert v.count("-") == 2


def test_macro_library_co_exploration():
    """Outer macro-family selection on top of the paper's co-exploration."""
    from repro.core import co_explore_macros, get_macro
    wl = bert_large_workload()
    macros = [get_macro("vanilla-dcim"), get_macro("lcc-cim")]
    best, results = co_explore_macros(
        macros, wl, 3.0, objective="ee", method="exhaustive", space=SMALL)
    assert len(results) == 2
    assert best.metrics["tops_w"] == max(r.metrics["tops_w"] for r in results)
    assert best.metrics["area_mm2"] <= 3.0 + 1e-6


def test_pareto_frontier_monotone_and_contains_extremes():
    from repro.core.explorer import pareto_explore
    from repro.core import get_macro
    wl = bert_large_workload()
    macro = get_macro("vanilla-dcim")
    fr = pareto_explore(macro, wl, 5.0, space=SMALL)
    assert len(fr) >= 1
    gops = [p["gops"] for p in fr]
    ee = [p["tops_w"] for p in fr]
    assert all(a >= b for a, b in zip(gops, gops[1:]))   # gops decreasing
    assert all(a <= b for a, b in zip(ee, ee[1:]))       # ee increasing
    # endpoints at least as good as single-objective exhaustive optima
    ee_opt = co_explore(macro, wl, 5.0, objective="ee", method="exhaustive",
                        space=SMALL)
    th_opt = co_explore(macro, wl, 5.0, objective="th", method="exhaustive",
                        space=SMALL)
    assert ee[-1] >= ee_opt.metrics["tops_w"] * 0.999
    assert gops[0] >= th_opt.metrics["gops"] * 0.999
