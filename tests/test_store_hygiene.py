"""Result-store hygiene: TTL expiry, size cap with LRU-ish eviction, and
the env-var wiring (CIM_TUNER_RESULT_STORE_TTL / _MAX_MB).  Pure file-level
tests -- no engine, no JAX work."""
from __future__ import annotations

import os
import time

from repro.core.engine import ExploreResult
from repro.core.macro import TPDCIM_MACRO
from repro.core.template import AcceleratorConfig
from repro.service import ResultStore


def _result(tag: str = "x") -> ExploreResult:
    return ExploreResult(
        config=AcceleratorConfig(1, 1, 1, 2, 2),
        macro=TPDCIM_MACRO, workload="wl", objective="ee",
        strategy_set="st", per_op_strategy={"op0": "IS-W-F"},
        metrics={"tops_w": 1.0}, search={"method": "stub", "tag": tag},
    )


def _key(i: int) -> str:
    return f"{i:02d}" + "ab" * 31          # 64 hex-ish chars, distinct shards


def test_ttl_expires_records(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=0.05, max_mb=None)
    store.put(_key(1), _result())
    assert _key(1) in store
    assert store.get(_key(1)) is not None
    time.sleep(0.08)
    assert _key(1) not in store, "membership must be TTL-aware"
    assert store.get(_key(1)) is None, "expired record must read as a miss"
    assert store.stats["expired"] == 1
    assert not os.path.exists(store._path(_key(1))), \
        "expired record must be deleted"
    # the caller re-computes and re-puts; the fresh record serves again
    store.put(_key(1), _result("fresh"))
    assert store.get(_key(1)).search["tag"] == "fresh"


def test_size_cap_evicts_least_recently_used(tmp_path):
    probe = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    probe.put(_key(0), _result())
    rec_bytes = os.path.getsize(probe._path(_key(0)))
    probe.clear()

    # capacity for ~3 records
    store = ResultStore(str(tmp_path), ttl_s=None,
                        max_mb=3.5 * rec_bytes / 1e6)
    for i in range(3):
        store.put(_key(i), _result(str(i)))
        time.sleep(0.02)                 # distinct mtimes
    # touch key 0 (a hit refreshes its mtime), making key 1 the LRU
    assert store.get(_key(0)) is not None
    time.sleep(0.02)
    store.put(_key(3), _result("3"))     # overflows the cap -> evict LRU
    assert store.stats["evicted"] >= 1
    assert store.get(_key(1)) is None, "LRU record must be evicted"
    assert store.get(_key(0)) is not None, "recently-used record survives"
    assert store.get(_key(3)) is not None, "just-written record survives"


def test_limits_read_from_env(tmp_path, monkeypatch):
    monkeypatch.setenv("CIM_TUNER_RESULT_STORE_TTL", "123.5")
    monkeypatch.setenv("CIM_TUNER_RESULT_STORE_MAX_MB", "2")
    store = ResultStore(str(tmp_path))
    assert store.ttl_s == 123.5
    assert store.max_bytes == 2e6
    monkeypatch.setenv("CIM_TUNER_RESULT_STORE_TTL", "not-a-number")
    monkeypatch.delenv("CIM_TUNER_RESULT_STORE_MAX_MB")
    store = ResultStore(str(tmp_path))
    assert store.ttl_s is None and store.max_bytes is None
    # explicit arguments beat the environment
    monkeypatch.setenv("CIM_TUNER_RESULT_STORE_TTL", "1")
    store = ResultStore(str(tmp_path), ttl_s=None, max_mb=0.5)
    assert store.ttl_s is None and store.max_bytes == 0.5e6


def test_uncapped_store_never_evicts(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    for i in range(5):
        store.put(_key(i), _result(str(i)))
    assert store.stats["evicted"] == 0
    assert len(store.keys()) == 5


_MEAS = [{"kernel": "cim_matmul", "bucket": "128x128x128", "tiling": "AF",
          "us": 12.5, "flops": 4.2e6, "bytes": 2.0e5, "seed": 0}]


def test_measurements_sidecar_round_trip(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    store.put(_key(1), _result())
    assert store.get_measurements(_key(1)) is None, \
        "no sidecar yet -> miss"
    store.put_measurements(_key(1), _MEAS)
    assert store.get_measurements(_key(1)) == _MEAS
    assert os.path.exists(store._measurements_path(_key(1)))


def test_measurements_sidecar_ttl_expires_with_parent(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=0.05, max_mb=None)
    store.put(_key(1), _result())
    store.put_measurements(_key(1), _MEAS)
    time.sleep(0.08)
    assert store.get(_key(1)) is None
    assert not os.path.exists(store._measurements_path(_key(1))), \
        "expired record must take its measurements sidecar with it"
    assert store.get_measurements(_key(1)) is None


def test_measurements_sidecar_recency_refreshed_on_hit(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    store.put(_key(1), _result())
    store.put_measurements(_key(1), _MEAS)
    sidecar = store._measurements_path(_key(1))
    mtime0 = os.path.getmtime(sidecar)
    time.sleep(0.05)
    assert store.get(_key(1)) is not None
    assert os.path.getmtime(sidecar) > mtime0, \
        "a hit on the parent must refresh the sidecar's LRU recency too"


def test_measurements_sidecar_evicted_with_parent(tmp_path):
    probe = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    probe.put(_key(0), _result())
    rec_bytes = os.path.getsize(probe._path(_key(0)))
    probe.clear()

    store = ResultStore(str(tmp_path), ttl_s=None,
                        max_mb=3.5 * rec_bytes / 1e6)
    for i in range(3):
        store.put(_key(i), _result(str(i)))
        store.put_measurements(_key(i), _MEAS)
        time.sleep(0.02)
    assert store.get(_key(0)) is not None     # key 1 becomes the LRU
    time.sleep(0.02)
    store.put(_key(3), _result("3"))
    assert store.get(_key(1)) is None, "LRU record must be evicted"
    assert not os.path.exists(store._measurements_path(_key(1))), \
        "eviction must remove the measurements sidecar, not orphan it"
    assert store.get_measurements(_key(0)) == _MEAS, \
        "surviving record keeps its sidecar"


def test_clear_removes_measurement_sidecars(tmp_path):
    store = ResultStore(str(tmp_path), ttl_s=None, max_mb=None)
    store.put(_key(1), _result())
    store.put_measurements(_key(1), _MEAS)
    store.clear()
    assert store.get_measurements(_key(1)) is None
    assert not os.path.exists(store._measurements_path(_key(1)))
