"""Cycle simulator: sandwich bounds vs the closed form + pipeline sanity."""
import numpy as np

from repro.compat import enable_x64
from repro.core import (
    ALL_STRATEGIES,
    AcceleratorConfig,
    analytic_latency_bounds,
    compile_schedule,
    get_macro,
    matmul_cost,
    simulate_schedule,
    strategy_feasible,
)


def test_sandwich_bounds():
    macro = get_macro("vanilla-dcim")
    rng = np.random.default_rng(3)
    n_checked = 0
    with enable_x64(True):
        for _ in range(10):
            cfg = AcceleratorConfig(
                int(rng.integers(1, 4)), int(rng.integers(1, 4)),
                int(2 ** rng.integers(0, 5)), int(2 ** rng.integers(1, 7)),
                int(2 ** rng.integers(0, 6)), bw=256)
            m, k, n = (int(rng.integers(4, 64)), int(rng.integers(16, 400)),
                       int(rng.integers(16, 300)))
            for s in ALL_STRATEGIES[:4]:
                if not strategy_feasible(macro, cfg, m, k, n, s):
                    continue
                rec = compile_schedule(macro, cfg, m, k, n, s)
                lb, ub = analytic_latency_bounds(rec, cfg.bw)
                for overlap in (True, False):
                    sim = simulate_schedule(rec, cfg.bw, overlap)
                    lat = sim["latency_cycles"]
                    assert lb - 1e-6 <= lat <= ub * (1 + 1e-9), (
                        s, cfg.as_tuple(), (m, k, n), overlap, lb, lat, ub)
                # closed-form analytic also lies within the same bounds
                cb = matmul_cost(
                    m, k, n, float(s.spatial == "R"),
                    float(s.temporal == "WP"), float(s.tiling == "PF"),
                    cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw,
                    1.0, macro)
                # overlapped closed form == max of the three sums
                # (up to per-set vs global ceil on the bus term)
                assert float(cb.latency_cycles) <= ub * (1 + 1e-9) + \
                    len(rec["planes"])
                n_checked += 1
    assert n_checked >= 15


def test_overlap_never_slower():
    macro = get_macro("vanilla-dcim")
    cfg = AcceleratorConfig(2, 2, 4, 16, 8)
    rec = compile_schedule(macro, cfg, 40, 300, 200, ALL_STRATEGIES[0])
    with_ov = simulate_schedule(rec, cfg.bw, True)["latency_cycles"]
    without = simulate_schedule(rec, cfg.bw, False)["latency_cycles"]
    assert with_ov <= without


def test_utilization_fields():
    macro = get_macro("vanilla-dcim")
    cfg = AcceleratorConfig(2, 2, 4, 16, 8)
    rec = compile_schedule(macro, cfg, 40, 300, 200, ALL_STRATEGIES[0])
    sim = simulate_schedule(rec, cfg.bw, True)
    assert 0 < sim["compute_utilization"] <= 1.0
    assert 0 < sim["bus_utilization"] <= 1.0
    assert sim["n_sets"] == len(rec["planes"])
