"""Optimizer, data pipeline, checkpointing, trainer, serving tests."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticLMStream, TokenFileStream
from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.optim.compression import (compressed_allreduce, dequantize_int8,
                                     quantize_int8)
from repro.train.checkpoint import CheckpointManager

pytestmark = pytest.mark.slow      # trainer/serving compiles take minutes


# ---------------------------------------------------------------------- #
# optimizer
# ---------------------------------------------------------------------- #
def test_adamw_minimizes_quadratic():
    opt = AdamW(AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                            weight_decay=0.0))
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        return opt.update(g, s, p)

    for _ in range(150):
        params, state, stats = step(params, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert float(stats["grad_norm"]) < 1.0


def test_grad_clip_caps_update():
    opt = AdamW(AdamWConfig(grad_clip=1.0, peak_lr=1e-3))
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    big = {"w": jnp.full((4,), 1e6)}
    _, _, stats = opt.update(big, state, params)
    assert float(stats["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup=10, total=100))
    lrw = float(cosine_schedule(10, peak_lr=1.0, warmup=10, total=100))
    lre = float(cosine_schedule(100, peak_lr=1.0, warmup=10, total=100))
    assert lr0 < 0.2 and lrw == pytest.approx(1.0) and lre < 0.2


def test_no_weight_decay_on_vectors():
    opt = AdamW(AdamWConfig(peak_lr=0.0, weight_decay=1.0))
    params = {"scale": jnp.ones((8,)), "w": jnp.ones((4, 4))}
    state = opt.init(params)
    g = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(new_p["scale"]), 1.0)  # untouched


# ---------------------------------------------------------------------- #
# compression
# ---------------------------------------------------------------------- #
def test_int8_quant_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1000,)) * 5.0, jnp.float32)
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape, jnp.float32)
    err = np.abs(np.asarray(y) - np.asarray(x)).max()
    # round-to-nearest with per-block absmax scale: err <= blockmax/127/2
    bound = float(np.abs(np.asarray(x)).max()) / 127.0
    assert err <= bound


def test_compressed_allreduce_error_feedback():
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("pod",))
    from jax.sharding import PartitionSpec as P
    grads = {"w": jnp.asarray(np.random.default_rng(1)
                              .standard_normal((64, 64)), jnp.float32)}

    def body(g):
        out, err = compressed_allreduce(g, "pod")
        return out, err

    smapped = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(),), out_specs=(P(), P())))
    out, err = smapped(grads)
    # single participant: mean == dequant(quant(g)); EF residual = g - deq
    resid = np.asarray(grads["w"]) - np.asarray(out["w"])
    np.testing.assert_allclose(resid, np.asarray(err["w"]), atol=1e-6)
    assert np.abs(resid).max() < 0.1


# ---------------------------------------------------------------------- #
# data pipeline
# ---------------------------------------------------------------------- #
def test_stream_deterministic_and_restartable():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab=100, seed=9)
    s1, s2 = SyntheticLMStream(cfg), SyntheticLMStream(cfg)
    b5a = s1.global_batch_at(5)
    b5b = s2.global_batch_at(5)          # fresh object, same (seed, step)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert not np.array_equal(s1.global_batch_at(6)["tokens"],
                              b5a["tokens"])


def test_stream_has_learnable_structure():
    cfg = DataConfig(seq_len=4096, global_batch=2, vocab=64, seed=0)
    s = SyntheticLMStream(cfg)
    b = s.global_batch_at(0)
    toks, labels = b["tokens"], b["labels"]
    # P(label == perm[token]) is strongly elevated over the ~1/vocab base
    # rate (the mixing coin is applied against the pre-mix chain, so the
    # realized hit rate is ~0.25, still >15x the base rate)
    hit = (labels == s._perm[toks]).mean()
    assert hit > 10.0 / 64
    assert hit > 5 * (1.0 / 64)


def test_token_file_stream():
    cfg = DataConfig(seq_len=16, global_batch=3, vocab=50, seed=2)
    with tempfile.NamedTemporaryFile(suffix=".bin", delete=False) as f:
        np.arange(10000, dtype=np.int32).tofile(f)
        path = f.name
    try:
        st = TokenFileStream(cfg, path)
        b = st.global_batch_at(0)
        assert b["tokens"].shape == (3, 16)
        np.testing.assert_array_equal(
            b["labels"][:, :-1], b["tokens"][:, 1:])
    finally:
        os.unlink(path)


# ---------------------------------------------------------------------- #
# checkpointing
# ---------------------------------------------------------------------- #
def test_checkpoint_roundtrip_and_retention():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.asarray(3), "d": [jnp.ones((4,)), jnp.zeros(())]}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        for step in (10, 20, 30):
            cm.save(step, tree)
        assert cm.latest_step() == 30
        assert cm._steps() == [20, 30]           # retention
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored, step = cm.restore(like)
        assert step == 30
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(1, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            cm.restore({"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


# ---------------------------------------------------------------------- #
# trainer end-to-end (tiny arch) + nan guard
# ---------------------------------------------------------------------- #
# Runs in a child interpreter: the train-jit + checkpoint path allocates
# heavily, and late in a full-suite run the accumulated native allocator
# state makes it abort with glibc heap corruption; a fresh process keeps
# the same coverage hermetic.
_TRAINER_E2E_CHILD = """
import tempfile
import numpy as np
from repro.configs import get_arch
from repro.launch.mesh import make_debug_mesh
from repro.train.trainer import Trainer, TrainerConfig

cfg = get_arch("yi-6b").reduced()
with tempfile.TemporaryDirectory() as d:
    tcfg = TrainerConfig(steps=6, seq_len=32, global_batch=2,
                         ckpt_every=3, ckpt_dir=d, log_every=100)
    tr = Trainer(cfg, tcfg, make_debug_mesh())
    tr.train(log=lambda s: None)
    assert tr.ckpt.latest_step() == 6
    losses1 = [h["loss"] for h in tr.history]
    assert all(np.isfinite(l) for l in losses1)

    # resume continues from step 6
    tcfg2 = TrainerConfig(steps=8, seq_len=32, global_batch=2,
                          ckpt_every=4, ckpt_dir=d, log_every=100)
    tr2 = Trainer(cfg, tcfg2, make_debug_mesh())
    tr2.train(log=lambda s: None)
    assert tr2.history[0]["step"] == 7
    assert tr2.ckpt.latest_step() == 8
"""


def test_trainer_runs_checkpoints_and_resumes():
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-c", _TRAINER_E2E_CHILD],
        env=env, capture_output=True, text=True, cwd=repo, timeout=600)
    assert proc.returncode == 0, proc.stderr


def test_nan_guard_skips_bad_step():
    from repro.configs import get_arch
    from repro.launch.mesh import make_debug_mesh
    from repro.launch.steps import make_train_step
    from repro.models import build_model
    from repro.train.trainer import _nan_guarded

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW()
    step = jax.jit(_nan_guarded(make_train_step(model, opt)))
    bad = {"tokens": jnp.zeros((2, 8), jnp.int32),
           "labels": jnp.zeros((2, 8), jnp.int32)}
    # poison the params to force a nan loss
    poisoned = jax.tree.map(lambda x: x * jnp.nan, params)
    new_p, _, m = step(poisoned, opt.init(poisoned), bad)
    assert bool(m["skipped"])
    # params unchanged (still nan-poisoned, not updated)
    assert bool(jnp.isnan(jax.tree.leaves(new_p)[0]).any())


# ---------------------------------------------------------------------- #
# serving
# ---------------------------------------------------------------------- #
def test_serve_engine_greedy_deterministic():
    from repro.configs import get_arch
    from repro.launch.mesh import make_debug_mesh
    from repro.serve.engine import GenerationConfig, ServeEngine

    cfg = get_arch("yi-6b").reduced()
    eng = ServeEngine(cfg, make_debug_mesh(), seed=0)
    prompts = [[1, 2, 3, 4], [5, 6, 7]]
    g = GenerationConfig(max_new_tokens=6)
    o1 = eng.generate(prompts, g)
    o2 = eng.generate(prompts, g)
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])
    assert o1["tokens"].shape == (2, 6)
    assert o1["tokens_per_s"] > 0
