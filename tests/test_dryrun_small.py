"""Integration: the dry-run driver end-to-end on 8 fake devices (subprocess
so the forced device count can't leak into other tests)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow      # 8-fake-device compile in a subprocess

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
from repro.compat import make_mesh
import repro.launch.mesh as meshmod
# single pod: 4 devices; multi pod: 8 -> per-device work halves
meshmod.make_production_mesh = lambda multi_pod=False: make_mesh(
    (2, 2, 2) if multi_pod else (2, 2),
    ("pod", "data", "model") if multi_pod else ("data", "model"))

# shrink the arch so an 8-device compile is quick but structure is intact
import repro.configs.base as base
import dataclasses
import repro.configs.yi_6b as yi
yi.CONFIG = dataclasses.replace(
    yi.CONFIG, n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=512, vocab=1024)
base.SHAPES = {
    "train_4k": base.ShapeSpec("train_4k", 256, 8, "train"),
    "decode_32k": base.ShapeSpec("decode_32k", 1024, 8, "decode"),
}

from repro.launch.dryrun import run_cell
out = {}
for shape in ("train_4k", "decode_32k"):
    for multi in (False, True):
        rec = run_cell("yi-6b", shape, multi)
        out[f"{shape}_{'m' if multi else 's'}"] = {
            "status": rec["status"],
            "flops": rec.get("dot_flops_per_device", 0),
            "coll": rec.get("collectives", {}).get("total_bytes", 0),
        }
print("RESULT" + json.dumps(out))
"""


def test_dryrun_pipeline_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env["DRYRUN_XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert all(v["status"] == "OK" for v in out.values()), out
    # train must do more flops than decode; multi-pod halves per-device work
    assert out["train_4k_s"]["flops"] > out["decode_32k_s"]["flops"]
    ratio = out["train_4k_s"]["flops"] / max(out["train_4k_m"]["flops"], 1)
    assert 1.5 < ratio < 2.5
    # sharded train step must exchange gradients
    assert out["train_4k_s"]["coll"] > 0
