"""HTTP front door (`repro.service.server`) end-to-end tests.

Everything except the OS-process fleet test runs against an in-process
ephemeral-port server backed by stub engines, so the protocol paths (spec
round-trip, SSE ordering, remote store read-through, error handling,
graceful shutdown) are exercised without JAX work and cannot flake on
compile timing.  The `slow`-marked fleet test is the acceptance check:
separate OS processes against one `repro-service serve`, with the warm
repeat answered from the shared store and asserted via `/v1/stats`.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest
from test_service import SMALL, CountingStubEngine, _job

from repro.core import ExploreJob, bert_large_workload, job_key
from repro.core.macro import TPDCIM_MACRO
from repro.service import (
    ResultStore,
    ServiceClient,
    job_from_spec,
    job_to_spec,
    settings_from_spec,
)
from repro.service.client import _read_sse
from repro.service.server import DSEServer, ServerConfig
from repro.service.streams import as_completed

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _server(tmp_path, engine=None, store="unset", **cfg) -> DSEServer:
    if store == "unset":
        store = ResultStore(str(tmp_path / "server-store"))
    config = ServerConfig(port=0, stream_ping_s=0.2, **cfg)
    return DSEServer(engine=engine or CountingStubEngine(),
                     store=store, config=config).start()


def _get_json(url: str) -> dict:
    with urllib.request.urlopen(url, timeout=30) as resp:
        return json.loads(resp.read().decode())


def _post_json(url: str, payload) -> dict:
    req = urllib.request.Request(
        url, data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"}, method="POST")
    with urllib.request.urlopen(req, timeout=60) as resp:
        return json.loads(resp.read().decode())


# ------------------------------------------------------------------ #
# spec round-trip + status endpoints
# ------------------------------------------------------------------ #
def test_post_jobs_roundtrip_including_portfolio(tmp_path):
    srv = _server(tmp_path)
    try:
        specs = [
            {"macro": "tpdcim-macro", "workload": "bert-large",
             "area_budget_mm2": 2.23, "objective": "ee",
             "search": "exhaustive",
             "space": {"mr": [1, 2], "mc": [1, 2], "scr": [1, 4],
                       "is_kb": [2, 16], "os_kb": [2, 16]}},
            {"macro": "tpdcim-macro", "workload": "bert-large",
             "area_budget_mm2": 2.23, "objective": "th",
             "search": "portfolio",
             "space": {"mr": [1, 2], "mc": [1, 2], "scr": [1, 4],
                       "is_kb": [2, 16], "os_kb": [2, 16]}},
        ]
        out = _post_json(f"{srv.url}/v1/jobs?wait=30", specs)
        assert [s["status"] for s in out["jobs"]] == ["done", "done"]
        # the server's canonical keys must equal a client's local
        # computation -- cross-host store sharing hinges on this parity
        for spec, state in zip(specs, out["jobs"]):
            job, method = job_from_spec(spec)
            from repro.service.queue import resolve_settings
            assert state["key"] == job_key(
                job, method, resolve_settings(method))
            assert state["result"]["workload"] == "bert-large"
        # status endpoint serves the same record
        key = out["jobs"][0]["key"]
        state = _get_json(f"{srv.url}/v1/jobs/{key}")
        assert state["status"] == "done"
        assert state["result"]["objective"] == "ee"
    finally:
        srv.shutdown()


def test_inline_job_spec_roundtrip_preserves_key():
    """job_to_spec -> JSON -> job_from_spec keeps the canonical job_key
    bit-for-bit for arbitrary in-memory jobs (custom space, workload)."""
    job = ExploreJob(TPDCIM_MACRO, bert_large_workload(384), 1.75,
                     objective="th", strategy_set="so", bw=128, space=SMALL,
                     merge_ops=False, search_method="genetic")
    wire = json.loads(json.dumps(job_to_spec(job)))
    back, method = job_from_spec(wire)
    assert method == "genetic"
    from repro.service.queue import resolve_settings
    assert job_key(back, method, resolve_settings(method)) == \
        job_key(job, "genetic", resolve_settings("genetic"))


def test_spec_settings_parse_and_reject_unknown_fields():
    from repro.search.genetic import GASettings
    got = settings_from_spec("genetic", {"pop": 8, "generations": 5})
    assert got == GASettings(pop=8, generations=5)
    with pytest.raises(ValueError, match="unknown GASettings fields"):
        settings_from_spec("genetic", {"population": 8})
    assert settings_from_spec("exhaustive", {"x": 1}) is None


# ------------------------------------------------------------------ #
# SSE streaming: per-bucket completion order mirrors as_completed
# ------------------------------------------------------------------ #
def test_sse_stream_order_matches_as_completed(tmp_path):
    from repro.configs import get_arch
    fast_wl = bert_large_workload()
    slow_wl = get_arch("whisper-small").workload(seq=512)
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(
        ExploreJob(TPDCIM_MACRO, slow_wl, 2.23, space=SMALL), "exhaustive")}
    srv = _server(tmp_path, engine=eng)
    try:
        # fast bucket first: the queue dispatches groups in (priority,
        # arrival) order and the stub holds the slow bucket open
        specs = [job_to_spec(_job(wl=fast_wl), "exhaustive"),
                 job_to_spec(_job(wl=slow_wl), "exhaustive")]
        out = _post_json(f"{srv.url}/v1/jobs", specs)
        fast_key, slow_key = (s["key"] for s in out["jobs"])
        url = f"{srv.url}/v1/stream?keys={slow_key},{fast_key}&timeout=30"
        events = []
        with urllib.request.urlopen(url, timeout=60) as resp:
            it = _read_sse(resp)
            event, obj = next(it)
            events.append((event, obj))
            # fast bucket streamed while the slow bucket is still held
            assert obj["key"] == fast_key
            eng.release.set()
            for event, obj in it:
                events.append((event, obj))
        assert [e for e, _ in events] == ["result", "result", "end"]
        assert events[1][1]["key"] == slow_key
        assert events[1][1]["status"] == "done"
    finally:
        eng.release.set()
        srv.shutdown()


def test_remote_client_streams_in_completion_order(tmp_path):
    from repro.configs import get_arch
    fast_wl = bert_large_workload()
    slow_wl = get_arch("whisper-small").workload(seq=512)
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(
        ExploreJob(TPDCIM_MACRO, slow_wl, 2.23, space=SMALL), "exhaustive")}
    srv = _server(tmp_path, engine=eng)
    cli = ServiceClient(base_url=srv.url, store=None)
    try:
        futs = cli.submit_many([_job(wl=fast_wl), _job(wl=slow_wl)],
                               method="exhaustive", metas=["fast", "slow"])
        stream = as_completed(futs, timeout=30)
        first = next(stream)
        assert first.meta == "fast"
        assert not futs[1].done()
        eng.release.set()
        assert next(stream).meta == "slow"
        assert futs[1].result(timeout=30).workload == slow_wl.name
    finally:
        eng.release.set()
        cli.close()
        srv.shutdown()


# ------------------------------------------------------------------ #
# shared-store semantics (the acceptance criterion, stub-engine tier)
# ------------------------------------------------------------------ #
def test_identical_resubmission_answered_from_shared_store(tmp_path):
    """Client A computes; client B (separate ServiceClient, cold local
    tier) resubmits the identical job and must be answered from the
    server's store with zero additional engine runs -- asserted via
    /v1/stats like the CI fleet job."""
    eng = CountingStubEngine()
    srv = _server(tmp_path, engine=eng)
    try:
        a = ServiceClient(base_url=srv.url, store=None)
        cold = a.explore([_job()], method="exhaustive")[0]
        assert eng.runs == 1
        a.close()

        b = ServiceClient(base_url=srv.url, store=None)
        warm = b.explore([_job()], method="exhaustive")[0]
        b.close()
        assert eng.runs == 1, "repeat must not reach the engine"
        assert warm.config.as_tuple() == cold.config.as_tuple()
        assert warm.search["cache"] == "remote-store"

        stats = _get_json(f"{srv.url}/v1/stats")
        assert stats["server"]["store_get_hits"] >= 1
        assert stats["store"]["hits"] >= 1
        assert stats["queue"]["dispatches"] == 1
    finally:
        srv.shutdown()


def test_remote_store_read_through_warms_local_tier(tmp_path):
    eng = CountingStubEngine()
    srv = _server(tmp_path, engine=eng)
    local = ResultStore(str(tmp_path / "client-store"))
    try:
        seed = ServiceClient(base_url=srv.url, store=None)
        seed.explore([_job()], method="exhaustive")
        seed.close()

        cli = ServiceClient(base_url=srv.url, store=local)
        got = cli.explore([_job()], method="exhaustive")[0]
        assert got.search["cache"] == "remote-store"
        assert cli.queue.store.stats["remote_hits"] == 1
        # the read-through wrote the record locally: a second query is
        # answered without any HTTP traffic at all
        before = srv.http_stats["requests"]
        again = cli.explore([_job()], method="exhaustive")[0]
        assert again.search["cache"] == "store"
        assert cli.queue.store.stats["local_hits"] == 1
        assert srv.http_stats["requests"] == before
        cli.close()
    finally:
        srv.shutdown()


def test_remote_values_submission(tmp_path):
    srv = _server(tmp_path)
    cli = ServiceClient(base_url=srv.url, store=None)
    try:
        rows = np.tile(np.asarray([1, 1, 1, 2, 2, 256], np.float64), (5, 1))
        fut = cli.submit_values(_job(), rows)
        vals = fut.result(timeout=30)
        np.testing.assert_allclose(vals, np.arange(5, dtype=float) + 1.0)
    finally:
        cli.close()
        srv.shutdown()


def test_stream_timeout_fails_pending_futures_instead_of_hanging(tmp_path):
    """When the server's stream ends (timeout event) before a bucket
    resolves, the remote client must fail the futures -- tagged with
    their job keys -- not leave callers blocked forever."""
    from repro.service.client import RemoteQueue
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(_job(), "exhaustive")}
    srv = _server(tmp_path, engine=eng)
    q = RemoteQueue(srv.url, store=None, timeout_s=0.5)
    try:
        fut = q.submit(_job(), method="exhaustive")
        exc = fut.exception(timeout=30)
        assert exc is not None
        assert fut.key[:16] in str(exc)
        assert exc.job_key == fut.key
    finally:
        eng.release.set()
        q.close()
        srv.shutdown()


def test_registry_eviction_never_drops_pending_futures(tmp_path):
    """With a tiny registry cap and every entry still running, eviction
    must overrun rather than make running work unreachable -- /v1/stream
    on both keys must succeed once released."""
    from repro.configs import get_arch
    eng = CountingStubEngine()
    eng.block_buckets = {
        eng.bucket_key(_job(), "exhaustive"),
        eng.bucket_key(_job(wl=get_arch("whisper-small").workload(seq=512)),
                       "exhaustive")}
    srv = _server(tmp_path, engine=eng, registry_cap=1)
    try:
        specs = [job_to_spec(_job(), "exhaustive"),
                 job_to_spec(_job(wl=get_arch("whisper-small")
                                  .workload(seq=512)), "exhaustive")]
        out = _post_json(f"{srv.url}/v1/jobs", specs)
        keys = [s["key"] for s in out["jobs"]]
        eng.release.set()
        url = f"{srv.url}/v1/stream?keys={','.join(keys)}&timeout=30"
        with urllib.request.urlopen(url, timeout=60) as resp:
            got = {obj.get("key") for event, obj in _read_sse(resp)
                   if event == "result"}
        assert got == set(keys)
    finally:
        eng.release.set()
        srv.shutdown()


# ------------------------------------------------------------------ #
# malformed requests
# ------------------------------------------------------------------ #
def _status_of(url: str, payload=None) -> int:
    try:
        if payload is None:
            urllib.request.urlopen(url, timeout=30)
        else:
            req = urllib.request.Request(
                url, data=payload,
                headers={"Content-Type": "application/json"},
                method="POST")
            urllib.request.urlopen(req, timeout=30)
    except urllib.error.HTTPError as exc:
        return exc.code
    return 200


def test_malformed_requests_get_400s(tmp_path):
    srv = _server(tmp_path)
    try:
        jobs = f"{srv.url}/v1/jobs"
        assert _status_of(jobs, b"{not json") == 400
        assert _status_of(jobs, b"[]") == 400
        assert _status_of(jobs, b'["not-a-spec"]') == 400
        assert _status_of(jobs, json.dumps(
            [{"workload": "bert-large", "area_budget_mm2": 1}]
        ).encode()) == 400                              # missing macro
        assert _status_of(jobs, json.dumps(
            [{"macro": "tpdcim-macro", "workload": "bert-large",
              "area_budget_mm2": 1, "search": "nope"}]).encode()) == 400
        bad_cands = {"macro": "tpdcim-macro", "workload": "bert-large",
                     "area_budget_mm2": 1, "candidates": [[1, 2, 3]]}
        assert _status_of(jobs, json.dumps([bad_cands]).encode()) == 400
        # one bad spec poisons nothing: the whole batch is rejected and
        # nothing was admitted
        assert _get_json(f"{srv.url}/v1/stats")["queue"]["submitted"] == 0
        assert _status_of(f"{srv.url}/v1/stream") == 400
        assert _status_of(f"{srv.url}/v1/stream?keys=deadbeef") == 404
        assert _status_of(f"{srv.url}/v1/jobs/deadbeef") == 404
        assert _status_of(f"{srv.url}/v1/store/deadbeef") == 404
        assert _status_of(f"{srv.url}/nope") == 404
        assert _get_json(f"{srv.url}/v1/stats")["server"]["bad_requests"] > 0
    finally:
        srv.shutdown()


# ------------------------------------------------------------------ #
# graceful shutdown
# ------------------------------------------------------------------ #
def test_graceful_shutdown_drains_inflight_buckets(tmp_path):
    eng = CountingStubEngine()
    eng.block_buckets = {eng.bucket_key(_job(), "exhaustive")}
    store = ResultStore(str(tmp_path / "server-store"))
    srv = _server(tmp_path, engine=eng, store=store)
    out = _post_json(f"{srv.url}/v1/jobs", [job_to_spec(_job(),
                                                        "exhaustive")])
    key = out["jobs"][0]["key"]
    assert out["jobs"][0]["status"] == "pending"

    done = threading.Event()
    threading.Thread(target=lambda: (srv.shutdown(drain=True),
                                     done.set()), daemon=True).start()
    time.sleep(0.1)
    assert not done.is_set(), "shutdown must wait for the held bucket"
    eng.release.set()
    assert done.wait(30), "drain never completed"
    # the accepted job's result was evaluated and persisted on the way out
    assert store.get(key) is not None


# ------------------------------------------------------------------ #
# pareto SSE endpoint (stub candidate sweep)
# ------------------------------------------------------------------ #
def test_pareto_endpoint_streams_frontiers(tmp_path):
    srv = _server(tmp_path)
    try:
        url = (f"{srv.url}/v1/pareto?macro=tpdcim-macro"
               f"&workloads=bert-large&area_budget_mm2=2.23&timeout=30")
        events = []
        with urllib.request.urlopen(url, timeout=60) as resp:
            for event, obj in _read_sse(resp):
                events.append((event, obj))
        assert [e for e, _ in events] == ["frontier", "end"]
        front = events[0][1]
        assert front["workload"] == "bert-large"
        assert front["frontier"], "stub sweep must yield frontier points"
        assert {"config", "gops", "tops_w"} <= set(front["frontier"][0])
    finally:
        srv.shutdown()


# ------------------------------------------------------------------ #
# acceptance: separate OS processes sharing one serve instance
# ------------------------------------------------------------------ #
@pytest.mark.slow
def test_fleet_of_processes_shares_one_server(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env["CIM_TUNER_RESULT_STORE"] = str(tmp_path / "server-store")
    env.pop("CIM_TUNER_SERVICE_URL", None)

    specs = [
        {"macro": "tpdcim-macro", "workload": "bert-large",
         "area_budget_mm2": 2.23, "objective": obj, "search": "exhaustive",
         "space": {"mr": [1, 2], "mc": [1, 2], "scr": [1, 4],
                   "is_kb": [16, 128], "os_kb": [16, 64]}}
        for obj in ("ee", "th")
    ]
    jobs_file = tmp_path / "jobs.json"
    jobs_file.write_text(json.dumps(specs))
    port_file = tmp_path / "port.txt"

    server = subprocess.Popen(
        [sys.executable, "-m", "repro.service", "serve", "--port", "0",
         "--port-file", str(port_file)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, cwd=REPO)
    try:
        deadline = time.monotonic() + 120
        while not port_file.exists():
            assert server.poll() is None, \
                f"server died early:\n{server.stdout.read()}"
            assert time.monotonic() < deadline, "server never bound a port"
            time.sleep(0.2)
        url = f"http://127.0.0.1:{port_file.read_text().strip()}"
        assert _get_json(f"{url}/healthz")["ok"] is True

        def client(tag: str, extra: list[str]) -> subprocess.Popen:
            cenv = dict(env)
            cenv["CIM_TUNER_RESULT_STORE"] = str(tmp_path / f"{tag}-store")
            cenv["CIM_TUNER_SERVICE_URL"] = url
            return subprocess.Popen(
                [sys.executable, "-m", "repro.service", "explore",
                 str(jobs_file), *extra],
                env=cenv, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True, cwd=REPO)

        # two concurrent client processes against the one server
        a = client("client-a", ["--stream"])
        b = client("client-b", [])
        out_a, _ = a.communicate(timeout=600)
        out_b, _ = b.communicate(timeout=600)
        assert a.returncode == 0, f"client A failed:\n{out_a}"
        assert b.returncode == 0, f"client B failed:\n{out_b}"
        assert out_a.count("bert-large") >= 2, out_a

        # third process resubmits the identical file: answered from the
        # shared store without another engine run
        before = _get_json(f"{url}/v1/stats")
        c = client("client-c", [])
        out_c, _ = c.communicate(timeout=600)
        assert c.returncode == 0, f"client C failed:\n{out_c}"
        after = _get_json(f"{url}/v1/stats")
        assert after["store"]["hits"] > before["store"]["hits"], \
            "warm repeat must be served by the shared store"
        assert after["queue"]["dispatches"] == before["queue"]["dispatches"], \
            "warm repeat must not dispatch new engine work"

        server.terminate()                              # SIGTERM: graceful
        out_s, _ = server.communicate(timeout=60)
        assert server.returncode == 0, f"server exit nonzero:\n{out_s}"
        assert "draining" in out_s
    finally:
        if server.poll() is None:
            server.kill()
