"""Pluggable search-backend portfolio: parity vs exhaustive ground truth,
registry semantics, portfolio racing guarantees, and the job-key
regression (a warm-store SA result must never answer a GA query)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import (
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    bert_large_workload,
    co_explore,
    job_key,
    valid_methods,
)
from repro.core.macro import TPDCIM_MACRO
from repro.search import (
    DESettings,
    GASettings,
    PortfolioSettings,
    SASettings,
    SobolSettings,
    available_backends,
    get_backend,
    race_plan,
    register_backend,
)
from repro.search.sobol import SobolBackend

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))

#: per-backend settings sized for the 162-point SMALL space (each well
#: under a second of search once compiled)
PARITY_SETTINGS = {
    "sa": SASettings(n_chains=24, n_steps=120, seed=1),
    "genetic": GASettings(pop=24, generations=40, seed=1),
    "evolution": DESettings(pop=16, generations=50, seed=1),
    "sobol": SobolSettings(n_points=1024, seed=1),
    "portfolio": PortfolioSettings(total_evals=3000, seed=1),
}


def _job(objective="ee", method="sa"):
    return ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                      objective=objective, space=SMALL,
                      search_method=method)


# ------------------------------------------------------------------ #
# registry
# ------------------------------------------------------------------ #
def test_registry_lists_all_backends():
    names = available_backends()
    for expected in ("sa", "genetic", "evolution", "sobol", "portfolio"):
        assert expected in names
    assert valid_methods() == names + ("exhaustive",)
    with pytest.raises(ValueError, match="unknown search backend"):
        get_backend("nope")
    with pytest.raises(ValueError, match="unknown search backend"):
        ExplorationEngine().run([_job()], method="nope")


def test_custom_backend_registers_and_runs():
    """The documented extension path: subclass, register, use as method=."""
    class HalfSobol(SobolBackend):
        name = "half-sobol"

    register_backend(HalfSobol(), overwrite=True)
    assert "half-sobol" in available_backends()
    res = ExplorationEngine().run(
        [_job()], method="half-sobol",
        settings=SobolSettings(n_points=64))[0]
    assert res.search["method"] == "half-sobol"
    assert res.metrics["area_mm2"] <= 2.23 * 1.001


# ------------------------------------------------------------------ #
# parity: every backend reaches (near-)exhaustive quality on the small
# space, mirroring the historical SA-vs-exhaustive test
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("method", ["genetic", "evolution", "sobol",
                                    "portfolio"])
def test_backend_matches_exhaustive_on_small_space(method):
    kw = dict(macro=TPDCIM_MACRO, workload=bert_large_workload(),
              area_budget_mm2=2.23, objective="ee", space=SMALL)
    ex = co_explore(method="exhaustive", **kw)
    got = co_explore(method=method, settings=PARITY_SETTINGS[method], **kw)
    # adaptive backends must reach within 1% of the exhaustive optimum;
    # the non-adaptive Sobol baseline gets a looser 10%
    tol = 1.10 if method == "sobol" else 1.01
    assert got.metrics["energy_pj"] <= ex.metrics["energy_pj"] * tol, method
    assert got.metrics["area_mm2"] <= kw["area_budget_mm2"] * 1.001
    assert got.search["method"] == method


def test_backends_share_engine_executable_cache():
    """Resubmission of any backend must hit the in-process executable
    cache (one compile per (bucket, backend, settings))."""
    engine = ExplorationEngine()
    jobs = [_job("ee"), _job("th")]
    for method in ("genetic", "evolution", "sobol"):
        settings = PARITY_SETTINGS[method]
        first = engine.run(jobs, method=method, settings=settings)
        misses = engine.stats["executable_cache_misses"]
        again = engine.run(jobs, method=method, settings=settings)
        assert engine.stats["executable_cache_misses"] == misses, method
        for a, b in zip(first, again):                 # deterministic replay
            assert a.config.as_tuple() == b.config.as_tuple()


def test_mixed_methods_in_one_batch():
    """method=None dispatches each job by its own search_method."""
    engine = ExplorationEngine()
    jobs = [_job(method="sobol"), _job(method="exhaustive")]
    outs = engine.run(jobs)
    assert outs[0].search["method"] == "sobol"
    assert outs[1].search["method"] == "exhaustive"


# ------------------------------------------------------------------ #
# portfolio racing guarantees
# ------------------------------------------------------------------ #
def test_portfolio_not_worse_than_any_constituent_same_seed():
    """The racer's reported best is the min across every phase, and each
    race run is bit-reproducible standalone (same derived seed), so the
    portfolio can never return worse than any constituent's race run."""
    settings = PortfolioSettings(total_evals=2000, seed=3)
    engine = ExplorationEngine()
    job = _job()
    pf = engine.run([job], method="portfolio", settings=settings)[0]
    race = pf.search["portfolio"]["race"]
    assert set(race) == set(settings.backends)
    assert float(pf.sa.best_value) <= min(race.values()) + 1e-9
    assert float(pf.sa.best_value) <= pf.search["portfolio"]["final"] + 1e-9
    # diagnostics come from the phase that produced the reported best
    assert float(np.min(np.asarray(pf.sa.best_per_chain))) == \
        pytest.approx(float(pf.sa.best_value), rel=1e-12)

    rung0 = race_plan(settings)[0]
    for name in settings.backends:
        solo = engine.run([job], method=name, settings=rung0[name])[0]
        assert float(pf.sa.best_value) <= float(solo.sa.best_value) + 1e-9, \
            name
        # the recorded race value IS the standalone run's best (exact
        # replay through the same executable + derived seed)
        assert race[name] <= float(solo.sa.best_value) + 1e-9, name


def test_portfolio_through_service_spec():
    """JSON spec path: {"search": "portfolio"} runs end-to-end."""
    from repro.service import ServiceClient, job_from_spec

    spec = {"macro": "tpdcim-macro", "workload": "bert-large",
            "area_budget_mm2": 2.23, "search": "portfolio",
            "space": {"mr": [1, 2, 3], "mc": [1, 2], "scr": [1, 4, 16],
                      "is_kb": [2, 16, 128], "os_kb": [2, 16, 64]}}
    job, method = job_from_spec(spec)
    assert method == "portfolio" and job.search_method == "portfolio"
    svc = ServiceClient(engine=ExplorationEngine(), store=None)
    try:
        res = svc.submit(job, method,
                         settings=PortfolioSettings(total_evals=1500)) \
            .result(timeout=600)
        assert res.search["method"] == "portfolio"
        assert res.search["portfolio"]["winner"] in \
            PortfolioSettings().backends
    finally:
        svc.close()


# ------------------------------------------------------------------ #
# job-key regression: method + settings are part of the canonical key
# ------------------------------------------------------------------ #
def test_job_key_distinguishes_methods_and_settings():
    job = _job()
    keys = {
        job_key(job, m, s) for m, s in [
            ("sa", SASettings()),
            ("sa", SASettings(seed=1)),
            ("genetic", GASettings()),
            ("genetic", GASettings(pop=32)),
            ("evolution", DESettings()),
            ("sobol", SobolSettings()),
            ("portfolio", PortfolioSettings()),
            ("exhaustive", None),
        ]
    }
    assert len(keys) == 8, "every (method, settings) must key differently"
    # method=None defers to the job's own search_method
    assert job_key(job, None, SASettings()) == \
        job_key(job, "sa", SASettings())
    # the override spelling and the job-field spelling share a key
    assert job_key(_job(method="genetic"), None, GASettings()) == \
        job_key(_job(method="sa"), "genetic", GASettings())


def test_warm_store_sa_result_never_answers_ga_query(tmp_path):
    """Regression: an SA result persisted in the store must NOT satisfy a
    genetic query for the same job (and vice versa)."""
    from repro.service import JobQueue, QueueConfig, ResultStore

    class CountingEngine(ExplorationEngine):
        def __init__(self):
            super().__init__(persistent_compile_cache=False)
            self.run_methods: list[str] = []

        def run(self, jobs, method=None, settings=None, sa_settings=None,
                keys=None):
            self.run_methods.append(method)
            return super().run(jobs, method, settings, sa_settings, keys)

    sa_settings = SASettings(n_chains=8, n_steps=30, seed=0)
    ga_settings = GASettings(pop=8, generations=10, seed=0)
    store = ResultStore(str(tmp_path))
    eng = CountingEngine()
    with JobQueue(engine=eng, store=store,
                  config=QueueConfig(batch_window_s=0.0)) as q:
        q.submit(_job(), "sa", sa_settings).result(timeout=600)
        assert store.stats["puts"] == 1
        res = q.submit(_job(), "genetic",
                       settings=ga_settings).result(timeout=600)
        assert q.stats["store_hits"] == 0, \
            "GA query must not be served from the SA record"
        assert res.search["method"] == "genetic"
        assert eng.run_methods == ["sa", "genetic"]

    # identical resubmission DOES hit the store, per method
    with JobQueue(engine=CountingEngine(), store=ResultStore(str(tmp_path)),
                  config=QueueConfig(batch_window_s=0.0)) as q2:
        warm = q2.submit(_job(), "genetic",
                         settings=ga_settings).result(timeout=600)
        assert q2.stats["store_hits"] == 1
        assert warm.search["method"] == "genetic"


def test_sobol_population_is_stratified():
    """The shared init-population provider must cover a small grid almost
    completely (quasi-random, not i.i.d. uniform)."""
    import jax
    import jax.numpy as jnp

    from repro.search import sobol_index_population

    lens = jnp.asarray([3, 2, 3, 3, 3], jnp.int32)
    idx = np.asarray(sobol_index_population(
        1024, lens, jax.random.PRNGKey(0)))
    assert idx.min() >= 0
    assert (idx.max(axis=0) <= np.array([2, 1, 2, 2, 2])).all()
    cells = {tuple(row) for row in idx}
    assert len(cells) >= 0.95 * 162          # near-complete grid coverage
