"""Two-fidelity portfolio race: the measured final rung, job-key
separation between fidelities, deterministic replay under a pinned
calibration artifact, and the unified submit contract's fidelity
normalization."""
from __future__ import annotations

import dataclasses

import pytest

from repro.core import (
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    bert_large_workload,
    job_key,
)
from repro.core.calibration import (
    CALIBRATION_ENV,
    fit_corrections,
    reset_calibration_state,
    save_calibration,
)
from repro.core.macro import TPDCIM_MACRO
from repro.search import FIDELITIES, PortfolioSettings, SASettings
from repro.service.queue import _normalize_submit_args

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


def _job(objective="ee"):
    return ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                      objective=objective, space=SMALL,
                      search_method="portfolio")


def _synthetic_records(n: int = 8) -> list[dict]:
    from repro.obs import profile
    pf, pb = profile.peak_flops(), profile.peak_bw()
    return [{"kernel": "cim_matmul", "bucket": f"b{i}", "tiling": "AF",
             "us": 2.0 * (1e9 * (i + 1)) / pf * 1e6
             + 0.5 * (1e6 * (n - i)) / pb * 1e6,
             "flops": 1e9 * (i + 1), "bytes": 1e6 * (n - i), "seed": 0}
            for i in range(n)]


@pytest.fixture
def pinned_artifact(tmp_path, monkeypatch):
    """A calibration artifact pinned via CIM_TUNER_CALIBRATION, so the
    measured rung never runs a live kernel sweep inside the test."""
    records = _synthetic_records()
    path = str(tmp_path / "calibration.json")
    save_calibration(path, fit_corrections(records), records=records)
    monkeypatch.setenv(CALIBRATION_ENV, path)
    reset_calibration_state()
    yield path
    monkeypatch.delenv(CALIBRATION_ENV)
    reset_calibration_state()


# ------------------------------------------------------------------ #
# settings validation
# ------------------------------------------------------------------ #
def test_portfolio_settings_fidelity_validation():
    assert FIDELITIES == ("analytic", "measured")
    assert PortfolioSettings().fidelity == "analytic"
    assert PortfolioSettings(fidelity="measured").topk >= 1
    with pytest.raises(ValueError, match="fidelity"):
        PortfolioSettings(fidelity="quantum")
    with pytest.raises(ValueError, match="topk"):
        PortfolioSettings(topk=0)


# ------------------------------------------------------------------ #
# job-key separation
# ------------------------------------------------------------------ #
def test_job_key_separates_fidelities(pinned_artifact):
    job = _job()
    k_analytic = job_key(job, "portfolio", PortfolioSettings(seed=1))
    k_measured = job_key(job, "portfolio",
                         PortfolioSettings(seed=1, fidelity="measured"))
    assert k_analytic != k_measured, \
        "a warm analytic result must never answer a calibrated query"
    # analytic keys are calibration-independent: same key with no pin
    import os
    pin = os.environ.pop(CALIBRATION_ENV)
    reset_calibration_state()
    try:
        assert job_key(job, "portfolio",
                       PortfolioSettings(seed=1)) == k_analytic
    finally:
        os.environ[CALIBRATION_ENV] = pin
        reset_calibration_state()


# ------------------------------------------------------------------ #
# the measured rung
# ------------------------------------------------------------------ #
def test_measured_rung_reports_both_rankings(pinned_artifact):
    engine = ExplorationEngine()
    settings = PortfolioSettings(total_evals=3000, seed=1,
                                 fidelity="measured", topk=4)
    (res,) = engine.run([_job()], method="portfolio", settings=settings)
    assert res.search["portfolio"]["fidelity"] == "measured"
    tf = res.search["two_fidelity"]
    assert tf["source"] == "artifact"
    assert tf["measurement_count"] == 8
    assert -1.0 <= tf["rank_correlation"] <= 1.0
    n = tf["topk"]
    assert 1 <= n <= 4, "re-scored pool is capped at settings.topk"
    assert sorted(tf["analytic_ranking"]) == list(range(n))
    assert sorted(tf["measured_ranking"]) == list(range(n))
    assert len(tf["analytic_values"]) == len(tf["measured_values"]) == n
    # winners are config rows (mr, mc, scr, is, os) under each fidelity
    assert len(tf["analytic_winner"]) == len(tf["measured_winner"]) == 5
    assert tf["calibration_version"] != "uncalibrated"
    # analytic runs carry no two_fidelity payload
    (res_a,) = engine.run([_job()], method="portfolio",
                          settings=PortfolioSettings(total_evals=3000,
                                                     seed=1))
    assert res_a.search["portfolio"]["fidelity"] == "analytic"
    assert "two_fidelity" not in res_a.search


def test_measured_rung_replays_deterministically(pinned_artifact):
    settings = PortfolioSettings(total_evals=3000, seed=1,
                                 fidelity="measured", topk=4)
    runs = []
    for _ in range(2):
        (res,) = ExplorationEngine().run([_job()], method="portfolio",
                                         settings=settings)
        runs.append(res)
    a, b = runs
    assert a.config.as_tuple() == b.config.as_tuple()
    assert a.search["two_fidelity"] == b.search["two_fidelity"], \
        "pinned artifact + fixed seed must replay bit-for-bit"


# ------------------------------------------------------------------ #
# the unified submit contract
# ------------------------------------------------------------------ #
def test_normalize_submit_args_fidelity_aliases():
    job = _job()
    m, eff, key = _normalize_submit_args(job, method="portfolio",
                                         fidelity="two")
    assert m == "portfolio" and eff.fidelity == "measured"
    m2, eff2, key2 = _normalize_submit_args(job, method="portfolio",
                                            fidelity="measured")
    assert eff2.fidelity == "measured" and key2 == key
    # analytic (or omitted) leaves the settings untouched
    m3, eff3, key3 = _normalize_submit_args(job, method="portfolio")
    assert eff3.fidelity == "analytic" and key3 != key
    base = PortfolioSettings(seed=7)
    _, eff4, _ = _normalize_submit_args(job, method="portfolio",
                                        settings=base,
                                        fidelity="analytic")
    assert eff4 is base or eff4 == base


def test_normalize_submit_args_rejects_bad_fidelity():
    job = _job()
    with pytest.raises(ValueError, match="fidelity"):
        _normalize_submit_args(job, method="portfolio", fidelity="bogus")
    # backends without a fidelity axis reject non-analytic requests
    with pytest.raises(ValueError, match="fidelity"):
        _normalize_submit_args(job, method="sa", settings=SASettings(),
                               fidelity="measured")
    # ...but explicitly-analytic submissions pass through unchanged
    m, eff, _ = _normalize_submit_args(job, method="sa",
                                       settings=SASettings(),
                                       fidelity="analytic")
    assert m == "sa" and isinstance(eff, SASettings)


def test_fidelity_settings_replace_preserves_other_fields():
    base = PortfolioSettings(total_evals=1234, seed=9, topk=3)
    _, eff, _ = _normalize_submit_args(_job(), method="portfolio",
                                       settings=base, fidelity="two")
    assert eff.fidelity == "measured"
    assert eff.total_evals == 1234 and eff.seed == 9 and eff.topk == 3
    assert dataclasses.replace(eff, fidelity="analytic") == base
