"""Strategy enum + systolic baseline unit tests."""
import pytest

from repro.core.strategies import ALL_STRATEGIES, SPATIAL_ONLY, Strategy
from repro.core.systolic import SystolicConfig, buffer_sweep, systolic_latency


def test_strategy_index_roundtrip():
    for i, s in enumerate(ALL_STRATEGIES):
        assert s.index == i
        assert Strategy.from_index(i) == s
        assert Strategy.parse(str(s)) == s


def test_strategy_validation():
    with pytest.raises(ValueError):
        Strategy("XX", "IP", "AF")
    with pytest.raises(ValueError):
        Strategy.from_index(8)


def test_spatial_only_is_subset():
    assert set(SPATIAL_ONLY) < set(ALL_STRATEGIES)
    assert all(s.temporal == "IP" and s.tiling == "AF" for s in SPATIAL_ONLY)


def test_systolic_refetch_depends_on_buffer():
    small = systolic_latency(SystolicConfig(32, 32, buf_kb=8), 512, 2048, 2048)
    big = systolic_latency(SystolicConfig(32, 32, buf_kb=2048), 512, 2048, 2048)
    assert small["refetch"] > big["refetch"]
    assert small["dram_cycles"] > big["dram_cycles"]
    assert small["compute_cycles"] == big["compute_cycles"]


def test_systolic_sweep_has_optimum():
    rows = buffer_sweep(area_budget_mm2=5.0, m=512, k=2048, n=2048)
    lats = [r["total_cycles"] for r in rows]
    best = min(lats)
    # an interior/boundary optimum exists and the spread is non-trivial
    assert max(lats) > best
    assert all(r["area_mm2"] <= 5.0 + 1e-6 for r in rows)


def test_systolic_is_dataflow_swaps_dims():
    a = systolic_latency(SystolicConfig(16, 16, buf_kb=64), 100, 256, 300,
                         dataflow="ws")
    b = systolic_latency(SystolicConfig(16, 16, buf_kb=64), 300, 256, 100,
                         dataflow="is")
    assert a["compute_cycles"] == b["compute_cycles"]
