"""Distributed (shard_map) DSE: correctness on a 1-device mesh, checkpoint/
elastic-resume, monotone incumbent."""
import os
import tempfile

from repro.core import DesignSpace, SASettings, distributed_co_explore
from repro.core.ir import bert_large_workload
from repro.core.macro import TPDCIM_MACRO

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


def _mesh():
    from repro.compat import make_mesh
    return make_mesh((1,), ("data",))


def test_distributed_runs_and_improves():
    res = distributed_co_explore(
        _mesh(), TPDCIM_MACRO, bert_large_workload(), 2.23,
        space=SMALL, settings=SASettings(seed=0),
        chains_per_device=8, rounds=4, sync_every=40)
    assert res.best_value < 1e29
    # incumbent best is monotone non-increasing across rounds
    assert all(b <= a * (1 + 1e-9)
               for a, b in zip(res.trace, res.trace[1:]))
    assert res.config.mr in SMALL.mr


def test_multi_job_population_sharded():
    """The job x chain population anneals all jobs in one sharded run."""
    from repro.core import ExploreJob, get_macro
    from repro.core.distributed import distributed_co_explore_jobs

    jobs = [
        ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                   objective="ee", space=SMALL),
        ExploreJob(get_macro("vanilla-dcim"), bert_large_workload(), 5.0,
                   objective="th", space=SMALL),
    ]
    results = distributed_co_explore_jobs(
        _mesh(), jobs, settings=SASettings(seed=0),
        chains_per_device=6, rounds=3, sync_every=30)
    assert len(results) == 2
    for job, res in zip(jobs, results):
        assert res.best_value < 1e29
        assert res.n_chains == 6
        assert res.config.mr in SMALL.mr
        # per-job incumbent is monotone non-increasing across rounds
        assert all(b <= a * (1 + 1e-9)
                   for a, b in zip(res.trace, res.trace[1:]))
    # different objectives -> generally different incumbent values
    assert results[0].best_value != results[1].best_value


def test_checkpoint_and_elastic_resume():
    with tempfile.TemporaryDirectory() as d:
        r1 = distributed_co_explore(
            _mesh(), TPDCIM_MACRO, bert_large_workload(), 2.23,
            space=SMALL, settings=SASettings(seed=0),
            chains_per_device=4, rounds=2, sync_every=30,
            checkpoint_dir=d)
        assert os.path.exists(os.path.join(d, "dse_state.npz"))
        # resume with a different population size (elastic)
        r2 = distributed_co_explore(
            _mesh(), TPDCIM_MACRO, bert_large_workload(), 2.23,
            space=SMALL, settings=SASettings(seed=0),
            chains_per_device=8, rounds=4, sync_every=30,
            checkpoint_dir=d, resume=True)
        assert len(r2.trace) == 4          # 2 restored + 2 new rounds
        assert r2.best_value <= r1.best_value * 1.5
