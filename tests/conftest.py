import atexit
import os
import shutil
import sys
import tempfile

# tests run single-device (the multi-device dry-run has its own subprocess
# test); never inherit a forced device count from the environment
os.environ.pop("XLA_FLAGS", None)

# hermetic service result store: co_explore & friends go through the
# process-wide DSE service, whose persistent cache must not leak results
# between test runs (or from a developer's warm ~/.cache); registered here
# (before the service's own atexit close) so LIFO ordering removes the
# directory only after the queue has drained
_test_store = tempfile.mkdtemp(prefix="cim-tuner-test-store-")
os.environ["CIM_TUNER_RESULT_STORE"] = _test_store
atexit.register(shutil.rmtree, _test_store, ignore_errors=True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
