import os
import sys

# tests run single-device (the multi-device dry-run has its own subprocess
# test); never inherit a forced device count from the environment
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
