"""Operator IR + size-aware merging tests."""
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core.ir import MatmulOp, Workload, bert_large_workload


def test_merge_preserves_totals():
    wl = Workload("t", (
        MatmulOp(128, 256, 512), MatmulOp(128, 256, 512, count=3),
        MatmulOp(64, 64, 64), MatmulOp(128, 256, 512, weights_static=False),
    ))
    m = wl.merged()
    assert m.total_macs == wl.total_macs
    assert len(m.ops) == 3          # same-size static ops gathered
    merged_op = [o for o in m.ops if o.weights_static and o.m == 128][0]
    assert merged_op.count == 4


def test_merge_is_idempotent():
    wl = bert_large_workload().merged()
    assert wl.merged() == wl


def test_bert_large_shape():
    wl = bert_large_workload()
    # 24 layers x (qkv + attn + ffn): merged to a handful of unique sizes
    assert 3 <= len(wl.ops) <= 8
    assert wl.total_macs > 1e11


def test_invalid_op():
    with pytest.raises(ValueError):
        MatmulOp(0, 1, 1)
    with pytest.raises(ValueError):
        Workload("empty", ())


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_workload_extraction(arch_id):
    """Every assigned architecture yields a CIM-Tuner workload (the
    technique applies to all 10 -- DESIGN.md Arch-applicability)."""
    cfg = get_arch(arch_id)
    wl = cfg.workload(seq=512)
    assert len(wl.ops) >= 3
    assert wl.total_macs > 0
    # act x act attention GEMMs flagged dynamic for attention archs
    if cfg.family not in ("ssm",):
        assert any(not op.weights_static for op in wl.ops)
    # merging keeps totals
    assert wl.merged().total_macs == wl.total_macs


def test_moe_merging_gathers_experts():
    g = get_arch("granite-moe-3b-a800m")
    wl = g.workload(seq=512)
    moe_ops = [o for o in wl.ops if o.n == 512 or o.k == 512]
    assert moe_ops and all(o.count >= 32 for o in moe_ops)


def test_as_arrays_padding():
    wl = bert_large_workload().merged()
    arr = wl.as_arrays(pad_to=len(wl.ops) + 5)
    assert arr.shape == (len(wl.ops) + 5, 5)
    assert (arr[len(wl.ops):, 3] == 0).all()      # count sentinel
    assert (arr[len(wl.ops):, :3] == 1).all()     # dims stay positive
