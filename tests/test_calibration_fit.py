"""Calibration fitting pass: synthetic round-trip recovery, held-out
generalization, artifact save/load, version stability, and the pinned-env
CostModel resolution.  No JAX work -- measurement records are hand-built
from the documented MeasurementRecord schema."""
from __future__ import annotations

import dataclasses
import math

import pytest

from repro.core.calibration import (
    DEFAULT_TECH,
    CALIBRATION_ENV,
    CorrectionFactors,
    CostModel,
    calibration_version,
    default_cost_model,
    evaluate_corrections,
    fit_corrections,
    fit_report,
    load_calibration,
    reset_calibration_state,
    resolve_tech,
    save_calibration,
)
from repro.obs import profile


@pytest.fixture(autouse=True)
def _fresh_calibration(monkeypatch):
    """Each test sees no pinned artifact and no cached live fit."""
    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    reset_calibration_state()
    yield
    reset_calibration_state()


def _synthetic_records(compute: float, memory: float, n: int = 12,
                       noise: float = 0.0) -> list[dict]:
    """Records whose timings follow the fit model with KNOWN factors.

    flops:bytes ratios are spread out so the two roofline features are
    far from collinear and the joint 2x2 solve is well conditioned."""
    pf, pb = profile.peak_flops(), profile.peak_bw()
    records = []
    for i in range(n):
        flops = 1e9 * (i + 1)
        nbytes = 1e6 * (n - i)
        t_c = flops / pf * 1e6
        t_m = nbytes / pb * 1e6
        us = compute * t_c + memory * t_m
        if noise:
            us *= 1.0 + noise * ((-1) ** i)     # deterministic "noise"
        records.append({"kernel": "cim_matmul", "bucket": f"b{i}",
                        "tiling": "AF", "us": us, "flops": flops,
                        "bytes": nbytes, "seed": 0})
    return records


def test_fit_recovers_known_distortion():
    cf = fit_corrections(_synthetic_records(compute=3.7, memory=0.4))
    assert cf.compute == pytest.approx(3.7, rel=1e-6)
    assert cf.memory == pytest.approx(0.4, rel=1e-6)
    assert cf.update == cf.memory, "update must ride the memory term"
    assert cf.leakage == 1.0, "microbench cannot observe static power"
    assert cf.fitted_on == 12
    assert cf.residual_us == pytest.approx(0.0, abs=1e-6)


def test_fit_survives_noise_and_clamps():
    cf = fit_corrections(_synthetic_records(2.0, 5.0, noise=0.1))
    assert cf.compute == pytest.approx(2.0, rel=0.35)
    assert cf.memory == pytest.approx(5.0, rel=0.35)
    assert cf.residual_us > 0.0
    # absurd distortions clamp to the documented [1e-3, 1e3] range
    big = fit_corrections(_synthetic_records(1e9, 1e9))
    assert big.compute <= 1e3 and big.memory <= 1e3


def test_fit_raises_without_cost_analysis():
    bad = [{"kernel": "k", "bucket": "b", "tiling": "t", "us": 1.0,
            "flops": None, "bytes": None, "seed": 0}]
    with pytest.raises(ValueError, match="no usable measurement"):
        fit_corrections(bad)


def test_held_out_error_strictly_below_uncalibrated():
    records = _synthetic_records(4.0, 0.25, n=16, noise=0.05)
    rep = fit_report(records, holdout_fraction=0.25, seed=3)
    assert rep["holdout_records"] >= 1
    assert rep["train_records"] + rep["holdout_records"] == len(records)
    assert rep["calibrated_rms_us"] < rep["uncalibrated_rms_us"], \
        "fitted model must beat the identity model on records it never saw"
    assert rep["improvement"] > 1.0
    # the report's factors match a direct fit on the same train split
    cal = evaluate_corrections(records, fit_corrections(records))
    assert cal <= evaluate_corrections(records)


def test_version_stable_and_content_addressed():
    a = fit_corrections(_synthetic_records(3.0, 0.5))
    b = fit_corrections(_synthetic_records(3.0, 0.5))
    c = fit_corrections(_synthetic_records(3.1, 0.5))
    assert calibration_version(a) == calibration_version(b)
    assert calibration_version(a) != calibration_version(c)
    assert calibration_version(None) == "uncalibrated"
    assert calibration_version(CorrectionFactors()) == "uncalibrated"


def test_artifact_round_trip(tmp_path):
    records = _synthetic_records(2.5, 0.8)
    cf = fit_corrections(records)
    path = str(tmp_path / "calibration.json")
    payload = save_calibration(path, cf, records=records,
                               report=fit_report(records))
    loaded, raw = load_calibration(path)
    assert loaded == cf
    assert raw["version"] == payload["version"] == calibration_version(cf)
    assert len(raw["measurements"]) == len(records)
    assert raw["report"]["improvement"] > 0.0


def test_with_corrections_touches_energy_not_area():
    cf = CorrectionFactors(compute=2.0, memory=3.0, update=4.0)
    tech = DEFAULT_TECH.with_corrections(cf)
    assert tech.e_mac_pj == DEFAULT_TECH.e_mac_pj * 2.0
    assert tech.e_sram_rd_pj_bit == DEFAULT_TECH.e_sram_rd_pj_bit * 3.0
    assert tech.e_ema_pj_bit == DEFAULT_TECH.e_ema_pj_bit * 3.0
    assert tech.e_cim_update_pj_bit == \
        DEFAULT_TECH.e_cim_update_pj_bit * 4.0
    # area and frequency are fidelity-invariant by design
    assert tech.a_cell_um2_bit == DEFAULT_TECH.a_cell_um2_bit
    assert tech.a_cu_um2 == DEFAULT_TECH.a_cu_um2
    assert tech.freq_mhz == DEFAULT_TECH.freq_mhz
    # identity corrections are bit-exact no-ops (same object)
    assert DEFAULT_TECH.with_corrections(None) is DEFAULT_TECH
    assert DEFAULT_TECH.with_corrections(CorrectionFactors()) is DEFAULT_TECH


def test_cost_model_facade_resolution():
    analytic = CostModel()
    assert analytic.tech is DEFAULT_TECH and not analytic.calibrated
    assert analytic.version == "uncalibrated"
    cf = CorrectionFactors(compute=2.0, memory=2.0, update=2.0)
    measured = CostModel(corrections=cf)
    assert measured.calibrated
    assert measured.version == calibration_version(cf)
    assert measured.tech.e_mac_pj == DEFAULT_TECH.e_mac_pj * 2.0
    assert resolve_tech(None) is DEFAULT_TECH
    custom = dataclasses.replace(DEFAULT_TECH, freq_mhz=1000.0)
    assert resolve_tech(custom) is custom


def test_default_cost_model_follows_env_pin(tmp_path, monkeypatch):
    assert not default_cost_model().calibrated
    records = _synthetic_records(3.0, 0.5)
    path = str(tmp_path / "cal.json")
    save_calibration(path, fit_corrections(records), records=records)
    monkeypatch.setenv(CALIBRATION_ENV, path)
    reset_calibration_state()               # env changed -> re-resolve
    cm = default_cost_model()
    assert cm.calibrated
    assert cm.version == calibration_version(fit_corrections(records))
    assert math.isfinite(cm.tech.e_mac_pj)
    monkeypatch.delenv(CALIBRATION_ENV)
    reset_calibration_state()
    assert not default_cost_model().calibrated
