"""Per-job backend settings end-to-end + the bandit budget allocator.

Covers the PR-5 tentpole surface: JSON job specs carrying per-job
``"search"`` settings (structured form and the legacy top-level
``"settings"``) round-trip through specs and the HTTP server with
``job_key`` parity, one engine batch mixes allocators, the bandit
allocator mirrors the halving dominance guarantees, and a forced
2-CPU-device subprocess proves the device-raced portfolio matches the
single-device path bit-for-bit.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    bert_large_workload,
    job_key,
)
from repro.core.macro import TPDCIM_MACRO
from repro.search import (
    GASettings,
    PortfolioSettings,
    SobolSettings,
    bandit_pull_plan,
    race_plan,
)
from repro.service import (
    ServiceClient,
    job_from_spec,
    job_to_spec,
    merge_spec_settings,
)
from repro.service.queue import resolve_settings

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))
SMALL_SPEC = {"mr": [1, 2, 3], "mc": [1, 2], "scr": [1, 4, 16],
              "is_kb": [2, 16, 128], "os_kb": [2, 16, 64]}


def _job(method="sa", settings=None, objective="ee"):
    return ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                      objective=objective, space=SMALL,
                      search_method=method, search_settings=settings)


# ------------------------------------------------------------------ #
# spec round-trips
# ------------------------------------------------------------------ #
def test_structured_search_spec_roundtrips_with_key_parity():
    """JSON spec (structured "search" form) -> job -> spec -> job keeps
    the canonical job_key bit-for-bit, including the settings."""
    spec = {"macro": "tpdcim-macro", "workload": "bert-large",
            "area_budget_mm2": 2.23, "space": SMALL_SPEC,
            "search": {"method": "genetic",
                       "settings": {"pop": 24, "generations": 40,
                                    "seed": 7}}}
    job, method = job_from_spec(spec)
    assert method == "genetic"
    assert job.search_settings == GASettings(pop=24, generations=40, seed=7)
    wire = json.loads(json.dumps(job_to_spec(job)))
    assert wire["search"]["settings"]["pop"] == 24
    back, method2 = job_from_spec(wire)
    assert method2 == "genetic"
    assert back.search_settings == job.search_settings
    assert job_key(back) == job_key(job)
    # ... and equals the explicit-settings spelling of the same query
    assert job_key(back) == job_key(
        _job("genetic"), "genetic",
        GASettings(pop=24, generations=40, seed=7))


def test_legacy_top_level_settings_and_structured_form_share_a_key():
    base = {"macro": "tpdcim-macro", "workload": "bert-large",
            "area_budget_mm2": 2.23, "space": SMALL_SPEC}
    legacy, _ = job_from_spec(
        {**base, "search": "sobol", "settings": {"n_points": 64}})
    structured, _ = job_from_spec(
        {**base, "search": {"method": "sobol",
                            "settings": {"n_points": 64}}})
    assert legacy.search_settings == SobolSettings(n_points=64)
    assert job_key(legacy) == job_key(structured)


def test_allocator_key_is_portfolio_settings_sugar():
    base = {"macro": "tpdcim-macro", "workload": "bert-large",
            "area_budget_mm2": 2.23, "space": SMALL_SPEC}
    sugar, _ = job_from_spec(
        {**base, "search": {"method": "portfolio", "allocator": "halving",
                            "settings": {"total_evals": 2000}}})
    explicit, _ = job_from_spec(
        {**base, "search": {"method": "portfolio",
                            "settings": {"total_evals": 2000,
                                         "allocator": "halving"}}})
    assert sugar.search_settings == \
        PortfolioSettings(total_evals=2000, allocator="halving")
    assert job_key(sugar) == job_key(explicit)
    # distinct allocators must never share a key (or a store record)
    bandit, _ = job_from_spec(
        {**base, "search": {"method": "portfolio", "allocator": "bandit",
                            "settings": {"total_evals": 2000}}})
    assert job_key(sugar) != job_key(bandit)


def test_bad_search_specs_rejected():
    base = {"macro": "tpdcim-macro", "workload": "bert-large",
            "area_budget_mm2": 2.23}
    with pytest.raises(ValueError, match="unknown 'search' keys"):
        job_from_spec({**base, "search": {"method": "sa", "nope": 1}})
    with pytest.raises(ValueError, match="both top-level and inside"):
        job_from_spec({**base,
                       "search": {"method": "sobol",
                                  "settings": {"n_points": 8}},
                       "settings": {"n_points": 16}})
    with pytest.raises(ValueError, match="unknown search"):
        job_from_spec({**base, "search": {"method": "nope"}})
    with pytest.raises(ValueError, match="unknown PortfolioSettings"):
        job_from_spec({**base, "search": "portfolio",
                       "settings": {"allocators": "bandit"}})
    with pytest.raises(ValueError, match="unknown portfolio allocator"):
        ExplorationEngine().run(
            [_job("portfolio",
                  PortfolioSettings(total_evals=64, allocator="nope"))])


def test_merge_spec_settings_both_spellings():
    legacy = {"macro": "m", "workload": "w", "area_budget_mm2": 1,
              "search": "sobol", "settings": {"n_points": 8}}
    merged = merge_spec_settings(legacy, {"n_points": 32, "seed": 2})
    assert merged["settings"] == {"n_points": 32, "seed": 2}
    structured = {"macro": "m", "workload": "w", "area_budget_mm2": 1,
                  "search": {"method": "portfolio", "allocator": "halving",
                             "settings": {"total_evals": 100}}}
    merged = merge_spec_settings(structured, {"allocator": "bandit"})
    assert "allocator" not in merged["search"] or \
        merged["search"].get("allocator") == "bandit"
    assert merged["search"]["settings"]["allocator"] == "bandit"
    assert merged["search"]["settings"]["total_evals"] == 100
    # inputs are not mutated
    assert structured["search"]["allocator"] == "halving"
    # a spec ambiguous to job_from_spec is equally rejected here, not
    # silently legitimized by the merge
    ambiguous = {"macro": "m", "workload": "w", "area_budget_mm2": 1,
                 "settings": {"n_points": 16},
                 "search": {"method": "sobol",
                            "settings": {"n_points": 64}}}
    with pytest.raises(ValueError, match="both top-level and inside"):
        merge_spec_settings(ambiguous, {"seed": 1})


# ------------------------------------------------------------------ #
# per-job settings through queue / engine (mixed batches)
# ------------------------------------------------------------------ #
def test_mixed_allocators_and_settings_in_one_batch():
    """One run() with settings=None executes each job under its own
    search_settings: bandit and halving portfolios side by side, plus a
    custom-budget Sobol -- three distinct executable groups, three
    distinct keys."""
    engine = ExplorationEngine()
    jobs = [
        _job("portfolio", PortfolioSettings(total_evals=800, seed=2,
                                            allocator="bandit")),
        _job("portfolio", PortfolioSettings(total_evals=800, seed=2,
                                            allocator="halving")),
        _job("sobol", SobolSettings(n_points=64, seed=2)),
    ]
    keys = {job_key(j) for j in jobs}
    assert len(keys) == 3
    outs = engine.run(jobs)
    assert outs[0].search["portfolio"]["allocator"] == "bandit"
    assert outs[1].search["portfolio"]["allocator"] == "halving"
    assert outs[2].search["method"] == "sobol"
    # both allocators spend the same race budget across the same backends
    assert outs[0].search["portfolio"]["race"].keys() == \
        outs[1].search["portfolio"]["race"].keys()


def test_per_job_settings_through_service_and_server(tmp_path):
    """A spec batch mixing allocators round-trips the HTTP server with
    client/server job_key parity (the cross-host store contract)."""
    from repro.service.server import DSEServer, ServerConfig
    from test_service import CountingStubEngine

    srv = DSEServer(engine=CountingStubEngine(), store=None,
                    config=ServerConfig(port=0)).start()
    try:
        specs = [
            {"macro": "tpdcim-macro", "workload": "bert-large",
             "area_budget_mm2": 2.23, "space": SMALL_SPEC,
             "search": {"method": "portfolio", "allocator": alloc,
                        "settings": {"total_evals": 500}}}
            for alloc in ("bandit", "halving")
        ]
        cli = ServiceClient(base_url=srv.url, store=None)
        try:
            results = cli.explore_specs(specs)
            assert len(results) == 2
        finally:
            cli.close()
        # server-side canonical keys == a local client's computation
        import urllib.request
        for spec in specs:
            job, method = job_from_spec(spec)
            key = job_key(job, method, resolve_settings(method, job=job))
            with urllib.request.urlopen(
                    f"{srv.url}/v1/jobs/{key}", timeout=30) as resp:
                state = json.loads(resp.read().decode())
            assert state["status"] == "done", state
    finally:
        srv.shutdown()


def test_engine_settings_override_beats_job_settings():
    engine = ExplorationEngine()
    job = _job("sobol", SobolSettings(n_points=16, seed=0))
    out = engine.run([job], settings=SobolSettings(n_points=64, seed=0))[0]
    assert out.sa.best_per_chain.shape[0] == 64
    # and a method override with type-mismatched job settings falls back
    # to the override backend's defaults instead of raising
    out2 = engine.run([job], method="genetic",
                      settings=GASettings(pop=8, generations=4))[0]
    assert out2.search["method"] == "genetic"


# ------------------------------------------------------------------ #
# bandit dominance (mirrors the halving portfolio-dominance property)
# ------------------------------------------------------------------ #
@pytest.mark.parametrize("allocator", ["bandit", "halving"])
def test_allocator_dominance_over_constituent_rung0(allocator):
    """Either allocator's portfolio never reports worse than any
    constituent's initialization run at the same seed (init pulls ==
    halving rung 0 == ``bandit_pull_plan(..., 0)``, bit-for-bit)."""
    settings = PortfolioSettings(total_evals=2000, seed=11,
                                 allocator=allocator)
    engine = ExplorationEngine()
    job = _job("portfolio")
    pf = engine.run([job], method="portfolio", settings=settings)[0]
    race = pf.search["portfolio"]["race"]
    assert pf.search["portfolio"]["allocator"] == allocator
    assert set(race) == set(settings.backends)
    best = float(pf.sa.best_value)
    assert best <= min(race.values()) + 1e-9
    assert best <= pf.search["portfolio"]["final"] + 1e-9
    assert float(np.min(np.asarray(pf.sa.best_per_chain))) == \
        pytest.approx(best, rel=1e-12)

    rung0 = race_plan(settings)[0]
    for b_idx, name in enumerate(settings.backends):
        assert bandit_pull_plan(settings, b_idx, 0) == rung0[name]
        solo = engine.run([job], method=name, settings=rung0[name])[0]
        assert best <= float(solo.sa.best_value) + 1e-9, name
        assert race[name] <= float(solo.sa.best_value) + 1e-9, name


def test_bandit_spends_exactly_the_halving_pull_budget():
    """Budget parity: the bandit's pull count times its slice equals the
    halving race budget, so the two allocators are eval-for-eval
    comparable; the bandit replays deterministically."""
    from repro.search import bandit_rounds, bandit_slice

    settings = PortfolioSettings(total_evals=1600, seed=4)
    engine = ExplorationEngine()
    pf = engine.run([_job("portfolio")], method="portfolio",
                    settings=settings)[0]
    pulls = pf.search["portfolio"]["pulls"]
    assert sum(pulls.values()) == bandit_rounds(settings)
    assert all(p >= 1 for p in pulls.values())      # every arm initialized
    assert bandit_rounds(settings) * bandit_slice(settings) <= \
        int(settings.total_evals * settings.race_fraction)
    again = engine.run([_job("portfolio")], method="portfolio",
                       settings=settings)[0]
    assert again.config.as_tuple() == pf.config.as_tuple()
    assert float(again.sa.best_value) == float(pf.sa.best_value)
    assert again.search["portfolio"]["pulls"] == pulls


# ------------------------------------------------------------------ #
# device racing (acceptance: forced multi-CPU-device race)
# ------------------------------------------------------------------ #
_DEVICE_RACE_SCRIPT = """
import jax
assert jax.device_count() == 2, jax.devices()
from repro.core import DesignSpace, ExplorationEngine, ExploreJob, \\
    bert_large_workload
from repro.core.macro import TPDCIM_MACRO
from repro.search import PortfolioSettings

space = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))
job = ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                 objective="ee", space=space)
s = PortfolioSettings(total_evals=800, seed=5)
raced = ExplorationEngine().run([job], method="portfolio", settings=s)[0]
assert raced.search["portfolio"]["devices"] == 2, raced.search
single = ExplorationEngine(device_race=False).run(
    [job], method="portfolio", settings=s)[0]
assert single.search["portfolio"]["devices"] == 1
assert raced.config.as_tuple() == single.config.as_tuple()
assert float(raced.sa.best_value) == float(single.sa.best_value)
print("DEVICE_RACE_OK", raced.config.as_tuple())
"""


def test_multi_device_portfolio_race_matches_single_device():
    """With XLA forced to 2 host CPU devices, portfolio race waves shard
    constituents across both devices and the result is bit-identical to
    the single-device fallback (seeds derive from the plan, not the
    placement)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-c", _DEVICE_RACE_SCRIPT], env=env, cwd=REPO,
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "DEVICE_RACE_OK" in out.stdout


# ------------------------------------------------------------------ #
# CLI --search-settings
# ------------------------------------------------------------------ #
def test_cli_search_settings_override(tmp_path, capsys):
    from repro.service.__main__ import main

    jobs_file = tmp_path / "jobs.json"
    jobs_file.write_text(json.dumps([
        {"macro": "tpdcim-macro", "workload": "bert-large",
         "area_budget_mm2": 2.23, "space": SMALL_SPEC,
         "search": "sobol"}]))
    rc = main(["explore", str(jobs_file), "--no-store",
               "--search-settings", '{"n_points": 64, "seed": 3}'])
    assert rc == 0
    assert "bert-large" in capsys.readouterr().out
    # bad JSON fails fast with exit 2
    rc = main(["explore", str(jobs_file), "--no-store",
               "--search-settings", "{not json"])
    assert rc == 2
    # fields unknown to the (overridden) backend fail fast too
    rc = main(["explore", str(jobs_file), "--no-store",
               "--search", "genetic",
               "--search-settings", '{"n_points": 64}'])
    assert rc == 2
