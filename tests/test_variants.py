"""Perf-variant paths must be numerically equivalent to the baselines
(the Sec. Perf A/B comparisons are only meaningful if they are)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.models.moe import moe_apply, moe_apply_row, moe_params
from repro.models.ssm import mamba_apply, mamba_params


def test_moe_row_dispatch_matches_global():
    key = jax.random.PRNGKey(0)
    p = moe_params(key, 32, 64, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 20, 32), jnp.float32)
    y1, a1 = moe_apply(p, x, top_k=2)
    y2, a2 = moe_apply_row(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-2)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_fused_selective_scan_matches_unfused():
    key = jax.random.PRNGKey(0)
    p = mamba_params(key, 32, 64, 8, 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 100, 32), jnp.float32)
    y1, _ = mamba_apply(p, x, d_state=8, dt_rank=4, chunk=16, fused=False)
    y2, _ = mamba_apply(p, x, d_state=8, dt_rank=4, chunk=16, fused=True)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-2)


def test_fused_scan_chunk_invariance():
    key = jax.random.PRNGKey(3)
    p = mamba_params(key, 16, 32, 4, 4)
    x = jax.random.normal(key, (1, 70, 16), jnp.float32)
    outs = [np.asarray(mamba_apply(p, x, d_state=4, dt_rank=4, chunk=c,
                                   fused=True)[0], np.float32)
            for c in (8, 32, 128)]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-3)


@pytest.mark.parametrize("overrides", [
    {"cast_params_bf16": True},
    {"remat_policy": "dots"},
    {"seq_shard_attn": True},
    {"moe_row_dispatch": True},
])
def test_variant_loss_close_to_baseline(overrides):
    base = get_arch("granite-moe-3b-a800m").reduced()
    key = jax.random.PRNGKey(0)
    m0 = build_model(base)
    params = m0.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, base.vocab),
             "labels": jax.random.randint(key, (2, 16), 0, base.vocab)}
    l0, _ = jax.jit(m0.loss)(params, batch)
    m1 = build_model(dataclasses.replace(base, **overrides))
    l1, _ = jax.jit(m1.loss)(params, batch)
    assert abs(float(l0) - float(l1)) < 0.05, overrides


def test_variants_registry_is_valid():
    """Every --variant override must be a real ArchConfig field."""
    from repro.configs.base import ArchConfig
    from repro.launch.dryrun import VARIANTS
    fields = {f.name for f in dataclasses.fields(ArchConfig)}
    for name, ov in VARIANTS.items():
        assert set(ov) <= fields, (name, set(ov) - fields)


def test_grads_flow_through_variants():
    cfg = dataclasses.replace(
        get_arch("falcon-mamba-7b").reduced(),
        ssm_fused_coeffs=True, cast_params_bf16=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = {"tokens": jax.random.randint(key, (2, 24), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 24), 0, cfg.vocab)}
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


def test_microbatch_grads_match_full_batch():
    """Gradient accumulation must be numerically equivalent to the full
    batch (same update, ~float tolerance)."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamW

    cfg = get_arch("yi-6b").reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt = AdamW()
    batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(key, (4, 16), 0, cfg.vocab)}
    p1, _, m1 = jax.jit(make_train_step(model, opt))(
        params, opt.init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, opt, microbatches=2))(
        params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-4)
