"""Matrix abstraction (eqns 1-5) + generalized template unit tests."""
import math

import pytest

from repro.core import (
    AcceleratorConfig,
    MACRO_LIBRARY,
    accelerator_area_mm2,
    get_macro,
)
from repro.core.macro import MacroSpec, TRANCIM_MACRO, TPDCIM_MACRO, VANILLA_DCIM
from repro.core.template import bandwidth_ok, peak_tops


def test_silicon_macro_latencies():
    # paper Sec. IV-E: (AL, PC, SCR, ICW, WUW) = (64, 8, 8, 512, 128)
    m = VANILLA_DCIM
    assert (m.al, m.pc, m.native_scr, m.icw, m.wuw) == (64, 8, 8, 512, 128)
    # eq (3): DW_in / N_bitline = 8 / (512/64) = 1 cycle
    assert m.compute_cycles() == 1
    # eq (5): AL * DW_w / WUW = 64*8/128 = 4 cycles
    assert m.update_cycles() == 4


def test_acim_icw_semantics():
    m = get_macro("acim-2b-dac")   # ICW = AL * DAC precision (eq. 2)
    assert m.icw == m.al * 2
    assert m.compute_cycles() == math.ceil(m.dw_in * m.al / m.icw) == 4


def test_macro_validation():
    with pytest.raises(ValueError):
        MacroSpec(name="bad", al=0, pc=8, native_scr=1, icw=64, wuw=64)
    with pytest.raises(ValueError):
        MacroSpec(name="bad", al=64, pc=8, native_scr=1, icw=64, wuw=64,
                  kind="rram")


def test_library_complete():
    assert {"vanilla-dcim", "fpcim", "lcc-cim", "trancim-macro",
            "tpdcim-macro"} <= set(MACRO_LIBRARY)


def test_table2_baseline_areas_calibrated():
    # Table II baselines must land on their published areas (fit check)
    tran = accelerator_area_mm2(
        AcceleratorConfig(3, 1, 1, 64, 128), TRANCIM_MACRO)
    tp = accelerator_area_mm2(
        AcceleratorConfig(2, 4, 1, 16, 16), TPDCIM_MACRO)
    assert abs(tran - 3.52) / 3.52 < 0.01
    assert abs(tp - 2.23) / 2.23 < 0.01


def test_area_monotone_in_every_axis():
    base = AcceleratorConfig(2, 2, 4, 16, 16)
    a0 = accelerator_area_mm2(base, VANILLA_DCIM)
    import dataclasses
    for field in ("mr", "mc", "scr", "is_kb", "os_kb"):
        bigger = dataclasses.replace(base, **{field: getattr(base, field) * 2})
        assert accelerator_area_mm2(bigger, VANILLA_DCIM) > a0, field


def test_bandwidth_pruning_rule():
    # Sec. III-D: internal bandwidth below BW is eliminated
    m = VANILLA_DCIM   # icw=512, wuw=128
    ok = AcceleratorConfig(1, 2, 1, 16, 16, bw=256)   # wuw*mr*mc=256 >= 256
    bad = AcceleratorConfig(1, 1, 1, 16, 16, bw=256)  # wuw agg = 128 < 256
    assert bandwidth_ok(ok, m)
    assert not bandwidth_ok(bad, m)


def test_peak_tops_scaling():
    c1 = AcceleratorConfig(1, 1, 1, 16, 16)
    c4 = AcceleratorConfig(2, 2, 1, 16, 16)
    assert peak_tops(c4, VANILLA_DCIM) == pytest.approx(
        4 * peak_tops(c1, VANILLA_DCIM))
