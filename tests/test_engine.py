"""Batched exploration engine: per-job equivalence, caching, bucketing."""
import numpy as np
import pytest

from repro.core import (
    DesignSpace,
    ExplorationEngine,
    ExploreJob,
    SASettings,
    bert_large_workload,
    co_explore,
    co_explore_macros,
    get_macro,
)
from repro.core.macro import TPDCIM_MACRO, TRANCIM_MACRO

SMALL = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


def _heterogeneous_jobs():
    """3+ jobs differing in macro, workload, objective AND strategy set."""
    from repro.configs import get_arch
    return [
        ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23,
                   objective="ee", space=SMALL),
        ExploreJob(get_macro("vanilla-dcim"),
                   get_arch("yi-6b").workload(seq=512), 5.0,
                   objective="th", space=SMALL),
        ExploreJob(TRANCIM_MACRO, get_arch("whisper-small").workload(seq=512),
                   3.52, objective="ee", strategy_set="so", space=SMALL),
        ExploreJob(get_macro("lcc-cim"), bert_large_workload(), 3.0,
                   objective="edp", space=SMALL),
    ]


@pytest.mark.parametrize("method", ["exhaustive", "sa"])
def test_batched_matches_per_job_co_explore(method):
    """The batched engine must return the SAME best configs/metrics as the
    sequential per-job path (a batch of one) on heterogeneous jobs."""
    jobs = _heterogeneous_jobs()
    settings = SASettings(n_chains=16, n_steps=100, seed=3)
    engine = ExplorationEngine()
    batched = engine.run(jobs, method=method, sa_settings=settings)
    for job, b in zip(jobs, batched):
        s = co_explore(job.macro, job.workload, job.area_budget_mm2,
                       objective=job.objective,
                       strategy_set=job.strategy_set, method=method,
                       space=SMALL, sa_settings=settings)
        assert b.config.as_tuple() == s.config.as_tuple(), (method, job)
        for key in ("energy_pj", "latency_cycles", "tops_w", "gops"):
            assert b.metrics[key] == pytest.approx(s.metrics[key], rel=1e-9)
        assert b.metrics["area_mm2"] <= job.area_budget_mm2 * 1.001


def test_executable_cache_hits_on_resubmission():
    jobs = _heterogeneous_jobs()
    settings = SASettings(n_chains=8, n_steps=40, seed=0)
    engine = ExplorationEngine()
    first = engine.run(jobs, method="sa", sa_settings=settings)
    misses = engine.stats["executable_cache_misses"]
    again = engine.run(jobs, method="sa", sa_settings=settings)
    assert engine.stats["executable_cache_misses"] == misses, \
        "repeat submission must not build new executables"
    assert engine.stats["executable_cache_hits"] > 0
    for a, b in zip(first, again):
        assert a.config.as_tuple() == b.config.as_tuple()
        assert a.metrics["energy_pj"] == b.metrics["energy_pj"]


def test_bucketing_pads_are_cost_transparent():
    """Jobs bucketed together (padded operator arrays) score identically to
    solo runs: padded rows carry count == 0 and contribute nothing."""
    from repro.configs import get_arch
    wl_small = bert_large_workload()                 # few merged ops
    wl_big = get_arch("whisper-small").workload(seq=512)  # many (cross-attn)
    engine = ExplorationEngine()
    solo = engine.run(
        [ExploreJob(TPDCIM_MACRO, wl_small, 2.23, space=SMALL)],
        method="exhaustive")[0]
    mixed = engine.run(
        [ExploreJob(TPDCIM_MACRO, wl_small, 2.23, space=SMALL),
         ExploreJob(TPDCIM_MACRO, wl_big, 2.23, space=SMALL)],
        method="exhaustive")[0]
    assert solo.config.as_tuple() == mixed.config.as_tuple()
    assert solo.metrics["energy_pj"] == mixed.metrics["energy_pj"]


def test_macro_library_runs_as_one_batch():
    """co_explore_macros stacks per-macro jobs into one engine batch (macro
    constants are per-job arrays inside a shared executable)."""
    engine = ExplorationEngine()
    wl = bert_large_workload()
    macros = [get_macro("vanilla-dcim"), get_macro("lcc-cim")]
    best, results = co_explore_macros(
        macros, wl, 3.0, objective="ee", method="exhaustive", space=SMALL,
        engine=engine)
    assert engine.stats["jobs"] == 2
    assert engine.stats["batches"] == 1
    assert best.metrics["tops_w"] == max(r.metrics["tops_w"]
                                         for r in results)


def test_search_stats_reported():
    engine = ExplorationEngine()
    res = engine.run(
        [ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23, space=SMALL)],
        method="exhaustive")[0]
    assert res.search["method"] == "exhaustive"
    assert res.search["batch_jobs"] == 1
    assert res.search["runtime_s"] > 0
    assert res.search["kept"] > 0                    # prune stats forwarded


def test_candidate_values_match_objective():
    """candidate_values (the Pareto path) equals the argmin path's scores."""
    from repro.core.pruning import candidates_with_bw, prune_space
    job = ExploreJob(TPDCIM_MACRO, bert_large_workload(), 2.23, space=SMALL)
    engine = ExplorationEngine()
    cands, _ = prune_space(SMALL, job.macro, job.area_budget_mm2, job.bw,
                           job.tech)
    rows = candidates_with_bw(cands, job.bw)
    vals = engine.candidate_values([job], [rows])[0]
    assert len(vals) == len(rows)
    best = engine.run([job], method="exhaustive")[0]
    np_best = rows[int(np.argmin(vals))]
    assert tuple(int(x) for x in np_best[:5]) == best.config.as_tuple()
