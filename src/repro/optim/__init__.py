from repro.optim.adamw import AdamW, AdamWConfig, cosine_schedule
from repro.optim.compression import (
    compressed_allreduce,
    dequantize_int8,
    quantize_int8,
)

__all__ = [
    "AdamW", "AdamWConfig", "cosine_schedule",
    "quantize_int8", "dequantize_int8", "compressed_allreduce",
]
