"""Gradient compression for cross-pod data-parallel sync.

int8 block-quantized all-reduce with error feedback: each leaf is quantized
per 256-element block (absmax scale), summed across the "pod" axis, and
dequantized; the quantization residual is carried to the next step (EF-SGD),
which keeps convergence unchanged to first order while cutting the inter-pod
all-reduce payload 4x (bf16->int8 plus scales).

Used by the trainer's ``grad_compression="int8"`` option inside a shard_map
over the pod axis (the intra-pod reduce stays full precision -- ICI is fast;
the DCN hop between pods is the scarce resource this targets).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (q int8 [N], scales f32 [N/BLOCK]) for a flattened leaf."""
    flat, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    blocks = q.reshape(-1, BLOCK).astype(jnp.float32) * scale[:, None]
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


def compressed_allreduce(grads, axis_name: str, errors=None):
    """Error-feedback int8 all-reduce over ``axis_name`` (inside shard_map).

    Returns (mean_grads, new_errors).  ``errors`` carries the per-leaf
    quantization residual between steps.
    """
    if errors is None:
        errors = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq_local = dequantize_int8(q, scale, g.shape, jnp.float32)
        new_e = corrected - deq_local
        # int8 payload summed in int32 to avoid overflow; scales averaged
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        scale_sum = jax.lax.psum(scale, axis_name)
        n = jax.lax.psum(1, axis_name)
        deq = dequantize_int8(
            jnp.clip(summed, -32767, 32767).astype(jnp.int32),
            scale_sum / n, g.shape, jnp.float32) / n
        return deq.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
