"""AdamW with warmup+cosine schedule and global-norm clipping, built from
scratch (no optax dependency).

Optimizer state mirrors the parameter pytree, so it inherits the parameters'
GSPMD sharding (FSDP params => ZeRO-sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(1.0, warmup)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0, 1)
    cos = peak_lr * (floor_frac + (1 - floor_frac) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamW:
    def __init__(self, config: AdamWConfig = AdamWConfig()):
        self.config = config

    def init(self, params: Any) -> dict:
        zeros = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), t)
        return {"m": zeros(params), "v": zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads: Any, state: dict, params: Any
               ) -> tuple[Any, dict, dict]:
        """Returns (new_params, new_state, stats)."""
        c = self.config
        step = state["step"] + 1
        lr = cosine_schedule(step, peak_lr=c.peak_lr,
                             warmup=c.warmup_steps, total=c.total_steps)

        # global-norm clip (fp32)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-12))

        b1c = 1.0 - c.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - c.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            step_ = mhat / (jnp.sqrt(vhat) + c.eps)
            decay = c.weight_decay * p.astype(jnp.float32) \
                if p.ndim >= 2 else 0.0   # no decay on norms/biases
            new_p = p.astype(jnp.float32) - lr * (step_ + decay)
            return new_p.astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_state = {"m": tdef.unflatten([o[1] for o in out]),
                     "v": tdef.unflatten([o[2] for o in out]),
                     "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
