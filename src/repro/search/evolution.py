"""Discrete differential evolution (rand/1/bin) over the index space.

The classic DE mutant ``x_r1 + F * (x_r2 - x_r3)`` is computed in *float
index space* and snapped back to the integer grid (round + clip to the
axis's true length), which preserves DE's self-scaling step sizes on the
pow-2 axes; binomial crossover (``cr``, with the guaranteed ``j_rand``
gene) and greedy one-to-one selection are standard.  Greedy selection makes
DE inherently elitist: the final population's min fitness IS the best value
ever seen.  Init population comes from the scrambled-Sobol provider.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.search.base import SearchBackend, cfg_from_indices, register_backend
from repro.search.sobol import sobol_index_population

__all__ = ["DESettings", "DifferentialEvolutionBackend"]


@dataclasses.dataclass(frozen=True)
class DESettings:
    pop: int = 48
    generations: int = 530            # ~ SA's default budget (64 x 400)
    f: float = 0.6                    # differential weight
    cr: float = 0.9                   # crossover rate
    seed: int = 0


class DifferentialEvolutionBackend(SearchBackend):
    name = "evolution"
    settings_cls = DESettings

    def budget(self, settings: DESettings) -> int:
        return settings.pop * (settings.generations + 1)

    def with_budget(self, settings: DESettings, n_evals: int):
        pop = min(settings.pop, max(8, int(n_evals) // 8))
        return dataclasses.replace(
            settings, pop=pop, generations=max(1, int(n_evals) // pop - 1))

    def make_keys(self, settings: DESettings, key=None):
        if key is None:
            key = jax.random.PRNGKey(settings.seed)
        return jax.random.split(key, settings.generations + 1)

    def run(self, objective_fn, mat, lens, bw, settings: DESettings, keys):
        pop_n = settings.pop
        evaluate = jax.vmap(
            lambda row: objective_fn(cfg_from_indices(mat, row, bw)))

        pop = sobol_index_population(pop_n, lens, keys[0])
        fit = evaluate(pop)

        def generation(state, k):
            pop, fit = state
            k_pick, k_cx, k_jrand = jax.random.split(k, 3)

            # rand/1: three donors per member (independent draws; a rare
            # collision just produces a null difference vector)
            r = jax.random.randint(k_pick, (pop_n, 3), 0, pop_n)
            mutant = pop[r[:, 0]].astype(jnp.float32) + settings.f * (
                pop[r[:, 1]] - pop[r[:, 2]]).astype(jnp.float32)
            mutant = jnp.clip(
                jnp.round(mutant), 0,
                (lens - 1)[None, :].astype(jnp.float32)).astype(pop.dtype)

            # bin: binomial crossover with a guaranteed mutant gene
            cross = jax.random.bernoulli(k_cx, settings.cr, (pop_n, 5))
            j_rand = jax.random.randint(k_jrand, (pop_n,), 0, 5)
            cross = cross | (jnp.arange(5)[None, :] == j_rand[:, None])
            trial = jnp.where(cross, mutant, pop)

            # greedy one-to-one selection
            trial_fit = evaluate(trial)
            keep = trial_fit <= fit
            pop = jnp.where(keep[:, None], trial, pop)
            fit = jnp.where(keep, trial_fit, fit)
            return (pop, fit), jnp.min(fit)

        (pop, fit), trace = jax.lax.scan(generation, (pop, fit), keys[1:])
        return pop, fit, trace


register_backend(DifferentialEvolutionBackend())
