"""Scrambled quasi-random (Sobol) baseline backend.

A low-discrepancy sweep over the 5-axis index space: Sobol points in
[0, 1)^5 (Joe-Kuo direction numbers, first five dimensions, digital-shift
scrambled from the run key) are mapped to per-axis indices.  Serves two
roles:

1. the cheapest sensible baseline an optimizer must beat -- evenly
   stratified coverage of the pruned pow-2 grid, no adaptivity;
2. the init-population provider for the population backends
   (:func:`sobol_index_population` seeds GA / DE with stratified rather
   than i.i.d. uniform members).

Direction numbers are precomputed in numpy at import (static constants);
point generation itself is pure ``jnp`` bit-twiddling, so the backend jits
and vmaps over the engine's stacked job axis like every other backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.search.base import SearchBackend, cfg_from_indices, register_backend

__all__ = ["SobolSettings", "SobolBackend", "sobol_index_population"]

#: bits of Sobol resolution (< 31 keeps everything in safe int32 range)
_BITS = 30


def _direction_numbers(bits: int = _BITS) -> np.ndarray:
    """[5, bits] uint32 direction numbers (dim 1 = van der Corput; dims 2-5
    from the Joe-Kuo primitive-polynomial table)."""
    polys = (                        # (s, a, initial m values), dims 2..5
        (1, 0, (1,)),
        (2, 1, (1, 3)),
        (3, 1, (1, 3, 1)),
        (3, 2, (1, 1, 1)),
    )
    v = np.zeros((5, bits), dtype=np.uint32)
    v[0] = [1 << (bits - 1 - j) for j in range(bits)]
    for d, (s, a, m_init) in enumerate(polys, start=1):
        m = list(m_init)
        for i in range(s, bits):
            new = m[i - s] ^ (m[i - s] << s)
            for k in range(1, s):
                new ^= ((a >> (s - 1 - k)) & 1) * (m[i - k] << k)
            m.append(new)
        v[d] = [m[j] << (bits - 1 - j) for j in range(bits)]
    return v


_DIRECTIONS = _direction_numbers()


def _scrambled_sobol(n: int, key) -> jax.Array:
    """[n, 5] scrambled Sobol points in [0, 1); ``n`` is static, the
    digital-shift scramble comes from ``key``."""
    i = jnp.arange(n, dtype=jnp.uint32)
    gray = i ^ (i >> 1)
    x = jnp.zeros((n, 5), dtype=jnp.uint32)
    directions = jnp.asarray(_DIRECTIONS)                    # [5, bits]
    for j in range(_BITS):                                   # static unroll
        bit = ((gray >> j) & jnp.uint32(1)).astype(jnp.uint32)
        x = x ^ (bit[:, None] * directions[None, :, j])
    shift = jax.random.bits(key, (5,), jnp.uint32) & jnp.uint32((1 << _BITS) - 1)
    x = x ^ shift[None, :]
    return x.astype(jnp.float32) / jnp.float32(1 << _BITS)


def sobol_index_population(n: int, lens, key) -> jax.Array:
    """[n, 5] int32 axis indices, stratified over the per-axis ranges --
    the shared init-population provider (GA / DE / the Sobol sweep)."""
    u = _scrambled_sobol(n, key)
    idx = jnp.floor(u * lens[None, :].astype(jnp.float32)).astype(jnp.int32)
    return jnp.minimum(idx, (lens - 1)[None, :].astype(jnp.int32))


@dataclasses.dataclass(frozen=True)
class SobolSettings:
    n_points: int = 1024
    seed: int = 0


class SobolBackend(SearchBackend):
    name = "sobol"
    settings_cls = SobolSettings

    def budget(self, settings: SobolSettings) -> int:
        return settings.n_points

    def with_budget(self, settings: SobolSettings, n_evals: int):
        return dataclasses.replace(settings, n_points=max(8, int(n_evals)))

    def run(self, objective_fn, mat, lens, bw, settings: SobolSettings, keys):
        idx = sobol_index_population(settings.n_points, lens, keys)
        vals = jax.vmap(
            lambda row: objective_fn(cfg_from_indices(mat, row, bw)))(idx)
        trace = jax.lax.associative_scan(jnp.minimum, vals)  # running best
        return idx, vals, trace


register_backend(SobolBackend())
