"""``repro.search`` -- pluggable batched optimizer portfolio.

The extended CIM-Tuner search space (hardware sizing x two-level mapping
under an area budget) is explored by interchangeable, fully jittable
backends that all share one interface (:class:`~repro.search.base.
SearchBackend`) and the same ``[jobs]``-leading-axis contract as
``core/annealing.anneal`` -- so every backend drops straight into the
batched engine's vmapped one-executable-per-bucket path:

* ``"sa"``         -- the paper's simulated annealing (adapter over
  ``core/annealing``);
* ``"genetic"``    -- tournament-selection GA, uniform crossover +
  axis-index mutation;
* ``"evolution"``  -- discrete differential evolution (rand/1/bin on
  index space);
* ``"sobol"``      -- scrambled quasi-random baseline (and the init-
  population provider for GA / DE);
* ``"portfolio"``  -- budget-allocated racer over the other backends
  (composite; the engine orchestrates it per job, racing constituents
  across the visible JAX devices).  ``PortfolioSettings.allocator``
  selects the race-budget allocator: ``"bandit"`` (deterministic UCB over
  per-backend improvement rates, the default) or ``"halving"`` (fixed
  successive-halving rungs).

Every registered name is a valid ``method=`` for ``ExplorationEngine.run``,
the ``co_explore`` family, service submissions, JSON job specs
(``"search": "genetic"``) and ``benchmarks/fig7_mapping.py --search``.
Register your own with :func:`register_backend` (see ``base.py``).
"""
from repro.search.base import (SearchBackend, SearchResult,
                               available_backends, cfg_from_indices,
                               get_backend, register_backend)
from repro.search.evolution import DESettings, DifferentialEvolutionBackend
from repro.search.genetic import GASettings, GeneticBackend
from repro.search.portfolio import (ALLOCATORS, FIDELITIES,
                                    PortfolioBackend,
                                    PortfolioSettings, bandit_pull_plan,
                                    bandit_rounds, bandit_slice,
                                    constituent_devices, final_plan,
                                    race_plan, ucb_scores)
from repro.search.sa import SASettings, SimulatedAnnealingBackend
from repro.search.sobol import (SobolBackend, SobolSettings,
                                sobol_index_population)

__all__ = [
    "SearchBackend", "SearchResult", "register_backend", "get_backend",
    "available_backends", "cfg_from_indices",
    "SASettings", "SimulatedAnnealingBackend",
    "GASettings", "GeneticBackend",
    "DESettings", "DifferentialEvolutionBackend",
    "SobolSettings", "SobolBackend", "sobol_index_population",
    "PortfolioSettings", "PortfolioBackend", "race_plan", "final_plan",
    "ALLOCATORS", "FIDELITIES", "bandit_pull_plan", "bandit_rounds",
    "bandit_slice", "ucb_scores", "constituent_devices",
]
