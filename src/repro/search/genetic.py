"""Tournament-selection genetic algorithm over the pruned pow-2 index space.

Population members are [5] axis-index rows (the same walk space as SA);
generations run under ``lax.scan`` so the whole search is one jitted,
vmappable expression:

* **init** -- scrambled-Sobol stratified population
  (:func:`repro.search.sobol.sobol_index_population`);
* **selection** -- size-``tournament`` tournaments (argmin fitness wins);
* **crossover** -- uniform: each axis independently picks parent A or B;
* **mutation** -- axis-index redraw: each gene resamples uniformly inside
  its axis's true length with probability ``mutation_prob`` (the discrete
  analogue of a jump move);
* **elitism** -- the best ``elite`` members survive unchanged, so the
  incumbent best can never be lost.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.search.base import SearchBackend, cfg_from_indices, register_backend
from repro.search.sobol import sobol_index_population

__all__ = ["GASettings", "GeneticBackend"]


@dataclasses.dataclass(frozen=True)
class GASettings:
    pop: int = 64
    generations: int = 400            # ~ SA's default budget (64 x 400)
    tournament: int = 3
    crossover_prob: float = 0.9
    mutation_prob: float = 0.15
    elite: int = 2
    seed: int = 0


class GeneticBackend(SearchBackend):
    name = "genetic"
    settings_cls = GASettings

    def budget(self, settings: GASettings) -> int:
        return settings.pop * (settings.generations + 1)

    def with_budget(self, settings: GASettings, n_evals: int):
        pop = min(settings.pop, max(8, int(n_evals) // 8))
        return dataclasses.replace(
            settings, pop=pop,
            generations=max(1, int(n_evals) // pop - 1),
            elite=min(settings.elite, pop - 1))

    def make_keys(self, settings: GASettings, key=None):
        if key is None:
            key = jax.random.PRNGKey(settings.seed)
        return jax.random.split(key, settings.generations + 1)

    def run(self, objective_fn, mat, lens, bw, settings: GASettings, keys):
        pop_n, elite = settings.pop, settings.elite
        evaluate = jax.vmap(
            lambda row: objective_fn(cfg_from_indices(mat, row, bw)))

        pop = sobol_index_population(pop_n, lens, keys[0])
        fit = evaluate(pop)
        w0 = jnp.argmin(fit)
        best_idx, best_val = pop[w0], fit[w0]

        def generation(state, k):
            pop, fit, best_idx, best_val = state
            k_sel, k_cx, k_mask, k_mut, k_draw = jax.random.split(k, 5)

            # tournament selection of 2 parents per child
            tsel = jax.random.randint(
                k_sel, (2 * pop_n, settings.tournament), 0, pop_n)
            winners = tsel[jnp.arange(2 * pop_n),
                           jnp.argmin(fit[tsel], axis=1)]
            pa, pb = pop[winners[:pop_n]], pop[winners[pop_n:]]

            # uniform crossover (whole-child bernoulli gates the operator)
            do_cx = jax.random.uniform(k_cx, (pop_n, 1)) < \
                settings.crossover_prob
            take_b = jax.random.bernoulli(k_mask, 0.5, (pop_n, 5))
            child = jnp.where(do_cx & take_b, pb, pa)

            # axis-index mutation: uniform redraw within the axis bounds
            mutate = jax.random.bernoulli(
                k_mut, settings.mutation_prob, (pop_n, 5))
            redraw = jax.random.randint(
                k_draw, (pop_n, 5), 0, 1 << 20) % lens[None, :]
            child = jnp.where(mutate, redraw.astype(child.dtype), child)

            # elitism: current best members overwrite the first rows
            order = jnp.argsort(fit)
            child = child.at[:elite].set(pop[order[:elite]])
            fit = evaluate(child)

            w = jnp.argmin(fit)
            better = fit[w] < best_val
            best_idx = jnp.where(better, child[w], best_idx)
            best_val = jnp.where(better, fit[w], best_val)
            return (child, fit, best_idx, best_val), best_val

        (pop, fit, best_idx, best_val), trace = jax.lax.scan(
            generation, (pop, fit, best_idx, best_val), keys[1:])
        # pin the global best into member 0 so the engine's per-member
        # argmin always sees it regardless of elitism settings
        pop = pop.at[0].set(best_idx)
        fit = fit.at[0].set(best_val)
        return pop, fit, trace


register_backend(GeneticBackend())
