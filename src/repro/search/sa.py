"""Simulated annealing as *just another* search backend.

The paper's optimizer (``core/annealing.anneal``) pre-dates the pluggable
subsystem; this adapter registers it under ``"sa"`` so it runs through the
exact same engine executable path -- one compile per (bucket, backend,
settings) -- as the population backends, and so the portfolio racer can
race it against them.  ``SASettings`` stays the canonical settings class
(engine construction, the service queue and old result-store keys all
reference it).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.annealing import SASettings, anneal, make_chain_keys
from repro.search.base import SearchBackend, register_backend

__all__ = ["SimulatedAnnealingBackend", "SASettings"]


class SimulatedAnnealingBackend(SearchBackend):
    name = "sa"
    settings_cls = SASettings

    def budget(self, settings: SASettings) -> int:
        return settings.n_chains * settings.n_steps

    def with_budget(self, settings: SASettings, n_evals: int):
        chains = min(settings.n_chains, max(4, int(n_evals) // 25))
        return dataclasses.replace(
            settings, n_chains=chains,
            n_steps=max(1, int(n_evals) // chains))

    def make_keys(self, settings: SASettings, key=None):
        return make_chain_keys(settings, key)

    def run(self, objective_fn, mat, lens, bw, settings: SASettings, keys):
        best_idx, best_val, hists = anneal(
            objective_fn, mat, lens, bw, settings, keys)
        return best_idx, best_val, jnp.min(hists, axis=0)


register_backend(SimulatedAnnealingBackend())
