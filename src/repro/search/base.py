"""Pluggable batched search-backend interface + registry.

Every optimizer in ``repro.search`` (simulated annealing, genetic algorithm,
differential evolution, scrambled Sobol, the portfolio racer) implements one
contract so the batched exploration engine can treat them interchangeably:

``backend.run(objective_fn, mat, lens, bw, settings, keys)`` is a *pure,
fully jittable* function over the padded axis-index space -- every operand
may be traced, so the engine ``vmap``s it over a stacked job axis exactly
like ``core/annealing.anneal`` and compiles ONE executable per
(shape bucket, backend, settings).  It returns the raw triple

    (best_idx [members, 5], best_val [members], trace_best [steps])

where *members* is the backend's population axis (chains for SA, the
population for GA/DE, the point count for Sobol) and ``trace_best`` is the
population-best objective value per step (diagnostics).  The engine picks
the argmin member, snaps it to a config and wraps it in a
:class:`SearchResult`.  ``run`` must derive ALL of its randomness from the
``keys`` argument -- ``settings.seed`` only feeds :meth:`SearchBackend.
make_keys` -- or declare ``seed_free_run = False`` (see the class).

Backends also expose a budget algebra (``budget`` / ``with_budget`` /
``reseed``) so the portfolio racer can hand every backend a comparable
slice of the evaluation budget.

Registering a custom backend::

    from repro.search import SearchBackend, register_backend

    class MyBackend(SearchBackend):
        name = "mine"
        settings_cls = MySettings
        def run(self, objective_fn, mat, lens, bw, settings, keys): ...

    register_backend(MyBackend())
    co_explore(macro, wl, 5.0, method="mine")         # now a valid method
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp

__all__ = [
    "SearchResult",
    "SearchBackend",
    "register_backend",
    "get_backend",
    "available_backends",
    "cfg_from_indices",
]


class SearchResult(typing.NamedTuple):
    """Summary of one backend run on one job (attached to ExploreResult)."""

    best_cfg: jax.Array        # [6] (mr, mc, scr, is_kb, os_kb, bw)
    best_value: jax.Array      # scalar raw objective of the winner
    best_per_chain: jax.Array  # [members] per-member best values
    trace_best: jax.Array      # [steps] population-best value per step


def cfg_from_indices(mat, idx, bw):
    """Axis-index row -> cfg row [6]; shared by every index-space backend."""
    vals = mat[jnp.arange(5), idx]
    return jnp.concatenate([vals, jnp.asarray(bw)[None]])


class SearchBackend:
    """Base class: subclasses set ``name`` + ``settings_cls`` and implement
    :meth:`run`; ``composite`` backends (the portfolio) are orchestrated by
    the engine over the other backends' executables instead of running as
    one jitted call themselves."""

    name: str = ""
    settings_cls: type = type(None)
    #: composite backends don't own a jitted executable; the engine races
    #: the registered primitives and re-uses THEIR compiled executables
    composite: bool = False
    #: contract flag: ``run()`` derives ALL randomness from the ``keys``
    #: argument and never reads ``settings.seed`` (which only feeds
    #: :meth:`make_keys`).  The engine then shares one compiled executable
    #: across reseeded runs by normalizing the seed out of its cache key.
    #: Set False in a custom backend whose ``run`` does read
    #: ``settings.seed`` -- the engine will keep the seed in the cache key
    #: and compile per seed instead of silently replaying the first one.
    seed_free_run: bool = True

    # ------------------------------------------------------------- #
    # settings algebra (used by the portfolio's budget split)
    # ------------------------------------------------------------- #
    def default_settings(self):
        """A fresh default-constructed settings object for this backend."""
        return self.settings_cls()

    def reseed(self, settings, seed: int):
        """``settings`` with its RNG seed replaced (the portfolio hands
        every scaled constituent a deterministic derived seed)."""
        return dataclasses.replace(settings, seed=int(seed))

    def budget(self, settings) -> int:
        """Approximate number of objective evaluations one run performs."""
        raise NotImplementedError

    def with_budget(self, settings, n_evals: int):
        """Settings rescaled to roughly ``n_evals`` objective evaluations."""
        raise NotImplementedError

    # ------------------------------------------------------------- #
    # the jittable core
    # ------------------------------------------------------------- #
    def make_keys(self, settings, key: jax.Array | None = None) -> jax.Array:
        """RNG block consumed by :meth:`run` (shape is backend-specific);
        defaults derive from ``settings.seed`` so equal settings replay
        bit-identically."""
        if key is None:
            key = jax.random.PRNGKey(settings.seed)
        return key

    def run(self, objective_fn, mat, lens, bw, settings, keys):
        """Pure batched search over index space -- see the module docstring
        for the exact contract."""
        raise NotImplementedError


# --------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------- #
_REGISTRY: dict[str, SearchBackend] = {}


def register_backend(backend: SearchBackend, overwrite: bool = False) -> SearchBackend:
    """Add a backend to the process-wide registry; its ``name`` becomes a
    valid ``method=`` for the engine, the ``co_explore`` family, service
    submissions and the CLI's ``"search"`` job-spec key."""
    if not backend.name:
        raise ValueError("backend must define a non-empty name")
    if backend.name == "exhaustive":
        raise ValueError("'exhaustive' is reserved for the pruned sweep")
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(name: str) -> SearchBackend:
    """The registered backend for ``name`` (raises ``ValueError`` with
    the registered-name list on a miss; ``"exhaustive"`` is not a backend
    -- the engine special-cases the pruned sweep)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown search backend {name!r}; registered: "
            f"{sorted(_REGISTRY)} (plus 'exhaustive')") from None


def available_backends() -> tuple[str, ...]:
    """Registered backend names, sorted (excludes 'exhaustive')."""
    return tuple(sorted(_REGISTRY))
