"""Portfolio racer: successive halving over the registered backends.

No single optimizer dominates every (macro, workload, objective, budget)
job, so the portfolio races them: every constituent backend gets an equal
slice of the evaluation budget per rung, the per-job losers are culled
(keep the best ``ceil(k/2)`` each rung), and whatever budget remains is
spent on each job's winning backend.  The returned best is the min over
*all* phases, so the portfolio can never report worse than any race run it
performed.

The portfolio is a *composite* backend: it owns no jitted executable of
its own.  The engine orchestrates it (``_run_portfolio_batch``), batching
each rung's surviving jobs through the constituent backends' regular
executables -- so racing N backends still compiles exactly one executable
per (bucket, backend, scaled settings), shared with every direct user of
that backend.

Budget split (``race_plan`` / ``final_plan``) is deterministic from the
settings alone, and every scaled constituent gets a seed derived only from
``(seed, backend index, rung)`` -- running a constituent standalone with a
plan entry's settings reproduces the portfolio's race run bit-for-bit
(what the parity/property tests assert).
"""
from __future__ import annotations

import dataclasses

from repro.search.base import SearchBackend, get_backend, register_backend

__all__ = ["PortfolioSettings", "PortfolioBackend", "race_plan",
           "final_plan", "derived_seed"]


@dataclasses.dataclass(frozen=True)
class PortfolioSettings:
    #: constituent backends to race (must be registered, non-composite)
    backends: tuple[str, ...] = ("sa", "genetic", "evolution", "sobol")
    #: total objective-evaluation budget per job (~ SA's default 64 x 400)
    total_evals: int = 25_600
    #: fraction of the budget spent racing (the rest goes to the winner)
    race_fraction: float = 0.5
    rungs: int = 2
    seed: int = 0


def derived_seed(seed: int, backend_index: int, rung: int) -> int:
    """Per-(backend, rung) seed; primes keep distinct slots distinct."""
    return int(seed) + 7919 * (backend_index + 1) + 104_729 * rung


def _validate(settings: PortfolioSettings) -> None:
    if not settings.backends:
        raise ValueError("portfolio needs at least one constituent backend")
    for name in settings.backends:
        if get_backend(name).composite:
            raise ValueError(
                f"portfolio constituent {name!r} is itself composite")


def race_plan(settings: PortfolioSettings) -> list[dict]:
    """Per-rung ``{backend name: scaled settings}``.  Each rung splits an
    equal share of the race budget among that rung's survivor count
    (``ceil(n / 2**rung)``), so every surviving backend gets the same
    number of evaluations per rung regardless of which ones survived."""
    _validate(settings)
    n = len(settings.backends)
    race = int(settings.total_evals * settings.race_fraction)
    plans = []
    for r in range(settings.rungs):
        alive = max(1, -(-n // (2 ** r)))                # ceil(n / 2^r)
        per_backend = max(1, race // (settings.rungs * alive))
        rung = {}
        for b_idx, name in enumerate(settings.backends):
            b = get_backend(name)
            scaled = b.with_budget(b.default_settings(), per_backend)
            rung[name] = b.reseed(scaled, derived_seed(settings.seed, b_idx, r))
        plans.append(rung)
    return plans


def final_plan(settings: PortfolioSettings) -> dict:
    """``{backend name: settings}`` for the post-race exploitation phase
    (the remaining budget, spent entirely on each job's winner)."""
    _validate(settings)
    remaining = max(
        1, settings.total_evals
        - int(settings.total_evals * settings.race_fraction))
    out = {}
    for b_idx, name in enumerate(settings.backends):
        b = get_backend(name)
        scaled = b.with_budget(b.default_settings(), remaining)
        out[name] = b.reseed(
            scaled, derived_seed(settings.seed, b_idx, settings.rungs))
    return out


class PortfolioBackend(SearchBackend):
    name = "portfolio"
    settings_cls = PortfolioSettings
    composite = True

    def budget(self, settings: PortfolioSettings) -> int:
        return settings.total_evals

    def with_budget(self, settings: PortfolioSettings, n_evals: int):
        return dataclasses.replace(settings, total_evals=max(8, int(n_evals)))

    def run(self, objective_fn, mat, lens, bw, settings, keys):
        raise NotImplementedError(
            "the portfolio is composite: the engine orchestrates it over "
            "the constituent backends' executables")


register_backend(PortfolioBackend())
