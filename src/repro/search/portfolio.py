"""Portfolio racer: budget-allocated racing over the registered backends.

No single optimizer dominates every (macro, workload, objective, budget)
job, so the portfolio races them.  Two budget **allocators** are available
(``PortfolioSettings.allocator``):

``"bandit"`` (default)
    A deterministic UCB bandit over per-backend *improvement rates*.  Every
    backend first gets one initialization pull (a fixed budget slice); each
    subsequent pull goes to the backend maximizing ``mean reward +
    ucb_c * sqrt(ln(total pulls) / pulls)``, **per job**, where a pull's
    reward is the normalized incumbent improvement it achieved -- computed
    from the jittable best-so-far trace each run already returns (the run
    best IS ``min(trace)``).  Ties break on backend order, rewards derive
    only from objective values, and every pull's RNG comes from
    :func:`derived_seed` -- so allocation is bit-deterministic given the
    job seed and race runs still replay standalone.

``"halving"``
    The fixed successive-halving schedule: every surviving backend gets an
    equal slice per rung, each job culls to its best ``ceil(k/2)`` per
    rung.

Both allocators spend ``race_fraction`` of ``total_evals`` racing and hand
the remainder to each job's winning backend; the reported best is the min
over *all* phases, so the portfolio can never report worse than any race
run it performed.  Both spend the same race budget: halving evaluates
``race/rungs`` per rung; the bandit makes ``len(backends) * rungs`` pulls
of ``race / (len(backends) * rungs)`` evaluations each.  The first bandit
pull of every backend therefore has exactly the settings (budget + derived
seed) of halving's rung 0, which is what the dominance tests replay.

The portfolio is a *composite* backend: it owns no jitted executable of
its own.  The engine orchestrates it (``_run_portfolio_batch``), batching
each pull's jobs through the constituent backends' regular executables --
so racing N backends still compiles exactly one executable per (bucket,
backend, scaled settings), shared with every direct user of that backend.
When several JAX devices are visible the engine additionally races the
constituents *across devices* (round-robin placement, asynchronous
dispatch, per-rung best exchange); see ``ExplorationEngine``.

Budget split (``race_plan`` / ``final_plan`` / ``bandit_pull_plan``) is
deterministic from the settings alone, and every scaled constituent gets a
seed derived only from ``(seed, backend index, pull index)`` -- running a
constituent standalone with a plan entry's settings reproduces the
portfolio's race run bit-for-bit (what the parity/property tests assert).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.search.base import SearchBackend, get_backend, register_backend

__all__ = ["PortfolioSettings", "PortfolioBackend", "race_plan",
           "final_plan", "derived_seed", "bandit_slice", "bandit_rounds",
           "bandit_pull_plan", "ucb_scores", "pull_reward", "ALLOCATORS",
           "FIDELITIES", "constituent_devices"]

#: valid ``PortfolioSettings.allocator`` values
ALLOCATORS = ("bandit", "halving")

#: valid ``PortfolioSettings.fidelity`` values: "analytic" scores with the
#: closed-form cost model only; "measured" adds a final re-scoring phase
#: where the top-K analytic winners are re-ranked under kernel-calibrated
#: tech constants (repro.core.calibration)
FIDELITIES = ("analytic", "measured")


@dataclasses.dataclass(frozen=True)
class PortfolioSettings:
    """Knobs of the portfolio racer (see the module docstring)."""

    #: constituent backends to race (must be registered, non-composite)
    backends: tuple[str, ...] = ("sa", "genetic", "evolution", "sobol")
    #: total objective-evaluation budget per job (~ SA's default 64 x 400)
    total_evals: int = 25_600
    #: fraction of the budget spent racing (the rest goes to the winner)
    race_fraction: float = 0.5
    #: budget granularity: rung count for "halving", pull-count multiplier
    #: for "bandit" (both spend the race budget in ``rungs`` equal waves)
    rungs: int = 2
    #: race-budget allocation strategy: "bandit" (UCB over per-backend
    #: improvement rates) or "halving" (fixed successive-halving rungs)
    allocator: str = "bandit"
    #: UCB exploration constant (bandit allocator only)
    ucb_c: float = 0.5
    seed: int = 0
    #: scoring fidelity: "analytic" (default) or "measured" (two-fidelity
    #: race -- the final phase re-scores the top-K candidates with
    #: kernel-measurement-calibrated tech constants)
    fidelity: str = "analytic"
    #: how many analytic front-runners the measured phase re-scores
    topk: int = 8
    #: cross-job budget flow (bandit allocator only): a job whose last
    #: ``flatline_waves`` consecutive adaptive pulls each earned reward
    #: below ``flatline_eps`` releases its remaining race pulls into a
    #: shared group pool that still-improving jobs drain.  0 disables
    #: reallocation entirely (the bit-for-bit-deterministic default).
    flatline_waves: int = 0
    #: reward threshold below which an adaptive pull counts as flat
    flatline_eps: float = 1e-6
    #: per-constituent device pin: ``device_affinity[b]`` is the race
    #: device slot backend ``b`` runs on every wave (``None`` keeps the
    #: engine's round-robin placement).  Slots index the visible race
    #: devices modulo their count, so a pinning stays valid -- and the
    #: results stay bit-identical -- whatever hardware is present.
    device_affinity: tuple[int, ...] | None = None

    def __post_init__(self):
        # field-local checks fail fast at construction; registry-dependent
        # checks (backend names, composites) stay in _validate so custom
        # backends can be registered after settings are built
        if self.fidelity not in FIDELITIES:
            raise ValueError(
                f"unknown portfolio fidelity {self.fidelity!r}; "
                f"valid: {FIDELITIES}")
        if self.topk < 1:
            raise ValueError("portfolio topk must be >= 1")
        if self.allocator not in ALLOCATORS:
            raise ValueError(
                f"unknown portfolio allocator {self.allocator!r}; "
                f"valid: {ALLOCATORS}")
        if self.flatline_waves < 0:
            raise ValueError("portfolio flatline_waves must be >= 0")
        if self.flatline_waves and self.allocator != "bandit":
            raise ValueError(
                "budget flow (flatline_waves > 0) needs the bandit "
                "allocator: rewards come from its pull traces")
        if self.flatline_eps < 0:
            raise ValueError("portfolio flatline_eps must be >= 0")
        if self.device_affinity is not None:
            if len(self.device_affinity) != len(self.backends):
                raise ValueError(
                    f"device_affinity length {len(self.device_affinity)} "
                    f"!= backend count {len(self.backends)}")
            if any(int(d) < 0 for d in self.device_affinity):
                raise ValueError("device_affinity slots must be >= 0")


def derived_seed(seed: int, backend_index: int, rung: int) -> int:
    """Per-(backend, pull) seed; primes keep distinct slots distinct."""
    return int(seed) + 7919 * (backend_index + 1) + 104_729 * rung


def _validate(settings: PortfolioSettings) -> None:
    if not settings.backends:
        raise ValueError("portfolio needs at least one constituent backend")
    if settings.allocator not in ALLOCATORS:
        raise ValueError(
            f"unknown portfolio allocator {settings.allocator!r}; "
            f"valid: {ALLOCATORS}")
    if settings.fidelity not in FIDELITIES:
        raise ValueError(
            f"unknown portfolio fidelity {settings.fidelity!r}; "
            f"valid: {FIDELITIES}")
    if settings.topk < 1:
        raise ValueError("portfolio topk must be >= 1")
    for name in settings.backends:
        b = get_backend(name)
        if b.composite:
            raise ValueError(
                f"portfolio constituent {name!r} is itself composite")
        if settings.allocator == "bandit" and not b.seed_free_run:
            # adaptive pulls reseed via the keys argument (per-job pull
            # counters diverge); a backend reading settings.seed inside
            # run() would silently replay its first pull instead
            raise ValueError(
                f"bandit allocator requires seed-free constituents; "
                f"{name!r} declares seed_free_run=False")


def _race_budget(settings: PortfolioSettings) -> int:
    return int(settings.total_evals * settings.race_fraction)


# --------------------------------------------------------------------- #
# fixed successive-halving schedule
# --------------------------------------------------------------------- #
def race_plan(settings: PortfolioSettings) -> list[dict]:
    """Per-rung ``{backend name: scaled settings}`` of the halving
    schedule.  Each rung splits an equal share of the race budget among
    that rung's survivor count (``ceil(n / 2**rung)``), so every surviving
    backend gets the same number of evaluations per rung regardless of
    which ones survived.  Rung 0 doubles as the bandit allocator's
    initialization pull (identical budget slice and derived seed)."""
    _validate(settings)
    n = len(settings.backends)
    race = _race_budget(settings)
    plans = []
    for r in range(settings.rungs):
        alive = max(1, -(-n // (2 ** r)))                # ceil(n / 2^r)
        per_backend = max(1, race // (settings.rungs * alive))
        rung = {}
        for b_idx, name in enumerate(settings.backends):
            b = get_backend(name)
            scaled = b.with_budget(b.default_settings(), per_backend)
            rung[name] = b.reseed(scaled, derived_seed(settings.seed, b_idx, r))
        plans.append(rung)
    return plans


def final_plan(settings: PortfolioSettings) -> dict:
    """``{backend name: settings}`` for the post-race exploitation phase
    (the remaining budget, spent entirely on each job's winner).  The
    final seed slot sits past every race pull's, so exploitation never
    replays a race run."""
    _validate(settings)
    remaining = max(1, settings.total_evals - _race_budget(settings))
    final_rung = settings.rungs if settings.allocator == "halving" \
        else bandit_rounds(settings) + 1
    out = {}
    for b_idx, name in enumerate(settings.backends):
        b = get_backend(name)
        scaled = b.with_budget(b.default_settings(), remaining)
        out[name] = b.reseed(
            scaled, derived_seed(settings.seed, b_idx, final_rung))
    return out


# --------------------------------------------------------------------- #
# bandit (UCB) schedule
# --------------------------------------------------------------------- #
def bandit_rounds(settings: PortfolioSettings) -> int:
    """Total race pulls per job: one initialization pull per backend plus
    ``n * (rungs - 1)`` adaptive pulls -- the same pull count (and hence
    the same per-pull budget) as halving's rung structure."""
    return len(settings.backends) * max(1, settings.rungs)


def bandit_slice(settings: PortfolioSettings) -> int:
    """Evaluation budget of ONE bandit pull; equals halving's rung-0
    per-backend slice, so the two allocators are eval-for-eval
    comparable (and the init pulls replay halving's rung 0)."""
    return max(1, _race_budget(settings) // bandit_rounds(settings))


def bandit_pull_plan(settings: PortfolioSettings, backend_index: int,
                     pull: int):
    """Scaled + reseeded settings of one backend's ``pull``-th race pull
    (pull 0 is the initialization pull == halving's rung 0 entry).
    Running a constituent standalone with this plan entry reproduces the
    portfolio's pull bit-for-bit."""
    _validate(settings)
    name = settings.backends[backend_index]
    b = get_backend(name)
    scaled = b.with_budget(b.default_settings(), bandit_slice(settings))
    return b.reseed(scaled, derived_seed(settings.seed, backend_index, pull))


def pull_reward(incumbent_before: float, trace: np.ndarray) -> float:
    """Reward of one pull: the normalized improvement it achieved.

    ``trace`` is the run's best-so-far trace (``[steps]``, the jittable
    diagnostic every backend already returns); the run best is its min.
    The reference point is the job's incumbent before the pull, or the
    run's own starting best for initialization pulls (incumbent still
    inf).  Clipped to [0, 1] so one lucky pull cannot dominate the mean.
    """
    trace = np.asarray(trace, dtype=np.float64)
    run_best = float(np.min(trace))
    ref = float(incumbent_before)
    if not np.isfinite(ref):
        ref = float(trace.flat[0])
    gain = max(0.0, ref - run_best)
    return float(min(1.0, gain / (abs(ref) + 1e-30)))


def constituent_devices(settings: PortfolioSettings,
                        devices: list) -> list:
    """The race device each constituent backend runs on, as a list
    aligned with ``settings.backends``.  ``device_affinity`` pins
    constituents to explicit slots (e.g. SA on device 0, Sobol on device
    1); ``None`` keeps the historical round-robin over the visible race
    devices.  Either way slots wrap modulo ``len(devices)``, so a pinned
    settings object runs unchanged on any machine (device placement
    never feeds the RNG, so results are identical regardless)."""
    aff = settings.device_affinity
    if aff is None:
        return [devices[b % len(devices)]
                for b in range(len(settings.backends))]
    return [devices[int(slot) % len(devices)] for slot in aff]


def ucb_scores(mean_reward: np.ndarray, pulls: np.ndarray,
               c: float) -> np.ndarray:
    """Deterministic UCB index per (job, backend): ``mean + c *
    sqrt(ln(total pulls of the job) / pulls)``.  Unpulled arms score +inf
    so every backend is tried before any is repeated; ties resolve to the
    lower backend index via the caller's stable argmax."""
    mean_reward = np.asarray(mean_reward, dtype=np.float64)
    pulls = np.asarray(pulls, dtype=np.float64)
    total = pulls.sum(axis=-1, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        bonus = c * np.sqrt(np.log(np.maximum(total, 1.0)) /
                            np.maximum(pulls, 1e-12))
    return np.where(pulls > 0, mean_reward + bonus, math.inf)


class PortfolioBackend(SearchBackend):
    """The composite racing backend registered as ``"portfolio"``."""

    name = "portfolio"
    settings_cls = PortfolioSettings
    composite = True

    def budget(self, settings: PortfolioSettings) -> int:
        """Total objective evaluations one portfolio run spends."""
        return settings.total_evals

    def with_budget(self, settings: PortfolioSettings, n_evals: int):
        """Settings rescaled to roughly ``n_evals`` total evaluations."""
        return dataclasses.replace(settings, total_evals=max(8, int(n_evals)))

    def run(self, objective_fn, mat, lens, bw, settings, keys):
        """Composite backends have no jitted core -- the engine races the
        constituents instead; calling this directly is an error."""
        raise NotImplementedError(
            "the portfolio is composite: the engine orchestrates it over "
            "the constituent backends' executables")


register_backend(PortfolioBackend())
