"""Batched serving engine: prefill + jit'd decode loop with KV/state caches.

Requests are padded-left into a fixed batch (static shapes keep one compiled
decode executable alive).  Greedy or temperature sampling; per-row EOS
tracking; ring caches (SWA) and O(1) SSM states come for free through the
model factory's cache machinery -- the same decode_step the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import sharding as sh
from repro.models.model import build_model


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0       # 0 => greedy
    eos_id: int = -1               # -1 => never stops early
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, params=None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.model = build_model(cfg, shard_act=sh.make_shard_act(mesh))
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(seed))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def _pad_batch(self, prompts: list[list[int]]) -> np.ndarray:
        width = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            out[i, width - len(p):] = p       # left padding
        return out

    def generate(self, prompts: list[list[int]],
                 gen: GenerationConfig = GenerationConfig(),
                 memory: np.ndarray | None = None) -> dict:
        t0 = time.perf_counter()
        tokens = jnp.asarray(self._pad_batch(prompts))
        b, t = tokens.shape
        batch = {"tokens": tokens,
                 "caches": self.model.init_cache(
                     b, t + gen.max_new_tokens)}
        if memory is not None:
            batch["memory"] = jnp.asarray(memory)
        elif self.cfg.n_memory:
            batch["memory"] = jnp.zeros(
                (b, self.cfg.n_memory, self.cfg.d_model), jnp.bfloat16)

        logits, caches = self._prefill(self.params, batch)
        t_prefill = time.perf_counter() - t0

        key = jax.random.PRNGKey(gen.seed)
        out = np.zeros((b, gen.max_new_tokens), np.int32)
        done = np.zeros((b,), bool)
        last = logits[:, -1]
        t1 = time.perf_counter()
        for i in range(gen.max_new_tokens):
            if gen.temperature > 0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(k, last / gen.temperature, axis=-1)
            else:
                nxt = jnp.argmax(last, axis=-1)
            nxt = np.asarray(nxt, np.int32)
            out[:, i] = np.where(done, gen.eos_id, nxt)
            done |= nxt == gen.eos_id
            if done.all():
                out = out[:, : i + 1]
                break
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(nxt[:, None]))
            last = logits[:, -1]
        t_decode = time.perf_counter() - t1
        n_new = out.shape[1]
        return {
            "tokens": out,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": b * n_new / max(t_decode, 1e-9),
        }
