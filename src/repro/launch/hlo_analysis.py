"""Call-graph-aware analysis of post-SPMD compiled HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified: a
2-layer and an 8-layer ``lax.scan`` report identical flops), which would
corrupt the roofline for scanned models.  XLA annotates each while op with
``backend_config={"known_trip_count":{"n":...}}``, so this module parses the
computation call graph and walks it with multipliers:

  * dot FLOPs  = 2 * prod(output dims) * prod(lhs contracting dims)
  * collective operand bytes per opcode (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute)

both multiplied by the product of enclosing-loop trip counts.  The compiled
module is the per-device SPMD program, so totals are per-device; multiply by
device count for aggregates.
"""
from __future__ import annotations

import dataclasses
import re

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_ONE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->")
# type part matched lazily so tuple types with {layout} braces work; the
# opcode is the first bare word followed by '(' after the type
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_WHILE_REFS = re.compile(r"(?:condition|body)=%?([\w.\-]+)")


def _shape_dims(type_str: str):
    m = _SHAPE_ONE.search(type_str)
    if not m:
        return None, ()
    dt = m.group(1)
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return dt, dims


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ONE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


#: ops that move no HBM bytes of their own
_FREE_OPS = frozenset((
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
))


@dataclasses.dataclass
class Comp:
    name: str
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    hbm_bytes: float = 0.0        # operand+output bytes (per-consumer reads)
    hbm_write_bytes: float = 0.0  # output bytes only (unique materializations)
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})
    # (callee, multiplier) edges: fusions x1, while body x trip_count
    edges: list = dataclasses.field(default_factory=list)
    interior: bool = False     # fusion/reduce interior: no HBM accounting


def parse_module(text: str) -> tuple[dict[str, Comp], str]:
    comps: dict[str, Comp] = {}
    entry = None
    cur: Comp | None = None
    symbols: dict[str, str] = {}   # local instr name -> type string

    for raw in text.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr and raw.rstrip().endswith("{"):
            cur = Comp(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            symbols = {}
            # header params: "name: type, name: type"
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[a-z0-9]+\[[0-9,]*\]\S*)",
                                  hdr.group(3)):
                symbols[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, out_type, opcode, rest = m.groups()
        symbols[name] = out_type

        if opcode == "dot":
            # flops = 2 * prod(out dims) * prod(lhs contracting dims)
            _, out_dims = _shape_dims(out_type)
            ops = re.findall(r"%([\w.\-]+)", rest[: rest.find(")") + 1])
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", raw)
            if ops and cm:
                _, lhs_dims = _shape_dims(symbols.get(ops[0], ""))
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs_dims):
                        k *= lhs_dims[int(idx)]
            n = 1
            for d in out_dims:
                n *= d
            cur.dot_flops += 2.0 * n * k
        elif opcode.startswith("convolution"):
            _, out_dims = _shape_dims(out_type)
            n = 1
            for d in out_dims:
                n *= d
            cur.conv_flops += 2.0 * n  # lower bound; convs are rare here
        else:
            for c in COLLECTIVES:
                if opcode == c or opcode.startswith(c + "-start"):
                    ops = re.findall(r"%([\w.\-]+)",
                                     rest[: rest.find(")") + 1])
                    b = sum(_type_bytes(symbols.get(o, "")) for o in ops)
                    if b == 0:
                        b = _type_bytes(out_type)
                    cur.coll_bytes[c] += b
                    cur.coll_counts[c] += 1
                    break

        if opcode not in _FREE_OPS:
            ops = re.findall(r"%([\w.\-]+)",
                             rest[: rest.find(")") + 1] if ")" in rest
                             else rest)
            out_b = _type_bytes(out_type)
            cur.hbm_write_bytes += out_b
            cur.hbm_bytes += out_b + sum(
                _type_bytes(symbols.get(o, "")) for o in set(ops))

        if opcode == "while":
            trip = 1
            tm = _TRIP.search(raw)
            if tm:
                trip = int(tm.group(1))
            for ref in _WHILE_REFS.findall(raw):
                cur.edges.append((ref, trip))
        else:
            for callee in _CALLS.findall(raw):
                cur.edges.append((callee, 1))
            if opcode == "conditional":
                for ref in re.findall(r"branch_computations=\{([^}]*)\}", raw):
                    for c2 in re.findall(r"%?([\w.\-]+)", ref):
                        cur.edges.append((c2, 1))

    # fusion / to_apply interiors don't touch HBM themselves (the fusion op
    # at its call site carries the operand/output traffic); while bodies are
    # referenced via body=/condition= and stay accountable
    for raw in text.splitlines():
        for callee in _CALLS.findall(raw):
            if callee in comps:
                comps[callee].interior = True
    return comps, entry or ""


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    totals = {
        "dot_flops": 0.0,
        "conv_flops": 0.0,
        "hbm_bytes": 0.0,
        "hbm_write_bytes": 0.0,
        "collective_bytes": {c: 0.0 for c in COLLECTIVES},
        "collective_counts": {c: 0.0 for c in COLLECTIVES},
        "max_loop_depth_mult": 1.0,
    }

    seen_stack: set[str] = set()

    def walk(name: str, mult: float):
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        seen_stack.add(name)
        totals["dot_flops"] += comp.dot_flops * mult
        totals["conv_flops"] += comp.conv_flops * mult
        if not comp.interior:
            totals["hbm_bytes"] += comp.hbm_bytes * mult
            totals["hbm_write_bytes"] += comp.hbm_write_bytes * mult
        for c in COLLECTIVES:
            totals["collective_bytes"][c] += comp.coll_bytes[c] * mult
            totals["collective_counts"][c] += comp.coll_counts[c] * mult
        totals["max_loop_depth_mult"] = max(
            totals["max_loop_depth_mult"], mult)
        for callee, m in comp.edges:
            walk(callee, mult * m)
        seen_stack.discard(name)

    walk(entry, 1.0)
    totals["collective_total_bytes"] = sum(
        totals["collective_bytes"].values())
    return totals
