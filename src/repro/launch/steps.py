"""Step builders + abstract input specs for every (arch x shape) dry-run cell.

``input_specs`` returns weak-type-correct ShapeDtypeStruct stand-ins (no
device allocation); the same builders are used with real arrays by the
trainer and the serving engine.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import Model, build_model
from repro.models import sharding as sh
from repro.optim import AdamW


# ---------------------------------------------------------------------- #
# abstract inputs
# ---------------------------------------------------------------------- #
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, t = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((b, t), jnp.int32),
        "labels": sds((b, t), jnp.int32),
    }
    if cfg.n_memory:
        batch["memory"] = sds((b, cfg.n_memory, cfg.d_model), jnp.bfloat16)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, t = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, t), jnp.int32)}
    if cfg.n_memory:
        batch["memory"] = sds((b, cfg.n_memory, cfg.d_model), jnp.bfloat16)
    return batch


def decode_specs(model: Model, shape: ShapeSpec) -> tuple[Any, Any]:
    """(abstract caches at seq_len occupancy, next-token spec)."""
    b = shape.global_batch
    caches = model.abstract_cache(b, shape.seq_len)
    tokens = sds((b, 1), jnp.int32)
    return caches, tokens


def input_specs(cfg: ArchConfig, shape: ShapeSpec, model: Model | None = None
                ) -> dict:
    """All abstract inputs of the cell's step function, keyed by arg name."""
    model = model or build_model(cfg)
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    caches, tokens = decode_specs(model, shape)
    return {"caches": caches, "tokens": tokens}


# ---------------------------------------------------------------------- #
# step functions
# ---------------------------------------------------------------------- #
def make_train_step(model: Model, optimizer: AdamW, microbatches: int = 1):
    """Jittable train step; ``microbatches > 1`` scans gradient accumulation
    over batch slices, dividing activation temp memory ~linearly (the
    dry-run's temp-pressure mitigation, EXPERIMENTS Sec. Dry-run)."""
    if microbatches == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            new_params, new_state, stats = optimizer.update(
                grads, opt_state, params)
            metrics = dict(metrics, loss=loss, **stats)
            return new_params, new_state, metrics
        return train_step

    def train_step(params, opt_state, batch):
        def split(x):
            assert x.shape[0] % microbatches == 0, (
                f"global batch {x.shape[0]} not divisible by "
                f"{microbatches} microbatches")
            return x.reshape((microbatches, x.shape[0] // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(acc, mb_batch):
            gsum, loss_sum = acc
            (loss, _m), g = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb_batch)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, loss_sum + loss), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), mb)
        grads = jax.tree.map(lambda g: g / microbatches, gsum)
        loss = loss_sum / microbatches
        new_params, new_state, stats = optimizer.update(
            grads, opt_state, params)
        metrics = dict(stats, loss=loss,
                       tokens=jnp.asarray(
                           batch["tokens"].size, jnp.float32))
        return new_params, new_state, metrics
    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, caches, tokens):
        return model.decode(params, caches, tokens)
    return decode_step


# ---------------------------------------------------------------------- #
# jitted + sharded cell assembly (used by dryrun, trainer, server)
# ---------------------------------------------------------------------- #
def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, *,
               optimizer: AdamW | None = None, sp_seq: bool = False,
               microbatches: int = 1):
    """Returns (jitted_fn, abstract_args) for one (arch x shape x mesh)."""
    shard_act = sh.make_shard_act(mesh, sp_seq=sp_seq)
    model = build_model(cfg, shard_act=shard_act)
    a_params = model.abstract_params()
    p_sh = sh.param_shardings(cfg, a_params, mesh)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        optimizer = optimizer or AdamW()
        a_opt = jax.eval_shape(optimizer.init, a_params)
        o_sh = sh.tree_shardings(
            a_opt, mesh, lambda n, s: sh.param_rule(cfg, n, s, mesh))
        batch = train_batch_specs(cfg, shape)
        b_sh = sh.batch_shardings(batch, mesh)
        step = make_train_step(model, optimizer, microbatches=microbatches)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, rep),
            donate_argnums=(0, 1),
        )
        return jitted, (a_params, a_opt, batch)

    if shape.kind == "prefill":
        batch = prefill_batch_specs(cfg, shape)
        b_sh = sh.batch_shardings(batch, mesh)
        a_cache = model.abstract_cache(shape.global_batch, shape.seq_len)
        c_sh = sh.cache_shardings(cfg, a_cache, mesh)
        dp = sh.dp_axes(mesh)
        tp = "model" if "model" in mesh.axis_names else None
        logits_sh = NamedSharding(mesh, sh._fit(
            (dp, None, tp),
            (shape.global_batch, shape.seq_len, cfg.vocab), mesh))
        step = make_prefill_step(model)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                         out_shardings=(logits_sh, c_sh))
        return jitted, (a_params, batch)

    # decode
    a_cache, tokens = decode_specs(model, shape)
    c_sh = sh.cache_shardings(cfg, a_cache, mesh)
    t_sh = sh.batch_shardings({"tokens": tokens}, mesh)["tokens"]
    dp = sh.dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    logits_sh = NamedSharding(mesh, sh._fit(
        (dp, None, tp), (shape.global_batch, 1, cfg.vocab), mesh))
    step = make_decode_step(model)
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(1,))
    return jitted, (a_params, a_cache, tokens)
