"""Serving entry point: batched generation with the reduced or full config.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --smoke \
        --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch.mesh import make_debug_mesh
    from repro.serve.engine import GenerationConfig, ServeEngine

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    nd, nm = (int(x) for x in args.mesh.split("x"))
    engine = ServeEngine(cfg, make_debug_mesh(nd, nm))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab, rng.integers(
        args.prompt_len // 2, args.prompt_len + 1)))
        for _ in range(args.batch)]
    out = engine.generate(prompts, GenerationConfig(
        max_new_tokens=args.new_tokens, temperature=args.temperature))
    print(f"prefill {out['prefill_s']*1e3:.1f} ms, "
          f"decode {out['decode_s']*1e3:.1f} ms, "
          f"{out['tokens_per_s']:.1f} tok/s")
    print("sampled tokens:\n", out["tokens"])


if __name__ == "__main__":
    main()
