import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import: jax locks the device
# count at first backend initialization.  (DRYRUN_XLA_FLAGS exists so tests
# can run the same driver with 8 fake devices.)

"""Multi-pod dry-run driver.

For every (architecture x input shape x mesh) cell:
    jit(step, in_shardings, out_shardings).lower(*input_specs).compile()
then record memory_analysis(), cost_analysis() and the collective-traffic
breakdown parsed from the post-SPMD compiled HLO.  Success here proves the
distribution config is coherent: sharding mismatches, compile-time OOMs and
unsupported collectives all surface as hard failures.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]
"""
import argparse
import json
import re
import time
import traceback

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([\w\-]+)")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand sizes of every collective op in post-SPMD HLO.

    Two passes: build a name -> output-bytes table, then for each collective
    line sum the sizes of its referenced operands.
    """
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))
    out = {c: 0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        opcode = m.group(3)
        base = None
        for c in COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-start") or \
                    opcode.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        # operands: %name tokens inside the call parens
        call = line[line.find(opcode) + len(opcode):]
        operands = re.findall(r"%?([\w.\-]+)(?=[,)])",
                              call[: call.find(")") + 1])
        op_bytes = sum(sizes.get(o, 0) for o in operands)
        if op_bytes == 0:
            op_bytes = _type_bytes(m.group(2))  # fallback: output size
        out[base] += op_bytes
        counts[base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


#: perf-variant switches for the hillclimb iterations (EXPERIMENTS Sec. Perf);
#: each maps to ArchConfig overrides so baseline-vs-variant is a pure A/B
VARIANTS: dict[str, dict] = {
    "moe-row": dict(moe_row_dispatch=True),
    "fsdp": dict(fsdp=True),
    "bf16p": dict(cast_params_bf16=True),
    "remat-dots": dict(remat_policy="dots"),
    "ssm-fused": dict(ssm_fused_coeffs=True),
    "ssm-chunk64": dict(ssm_chunk=64),
    "ssm-fused64": dict(ssm_fused_coeffs=True, ssm_chunk=64),
    "moe-row-bf16p": dict(moe_row_dispatch=True, cast_params_bf16=True),
    "moe-row-seqattn": dict(moe_row_dispatch=True, seq_shard_attn=True),
    "ssm-fused512": dict(ssm_fused_coeffs=True, ssm_chunk=512),
    "ssm-fused1024": dict(ssm_fused_coeffs=True, ssm_chunk=1024),
    "ssm-fused2048": dict(ssm_fused_coeffs=True, ssm_chunk=2048),
    "granite-opt": dict(moe_row_dispatch=True, seq_shard_attn=True,
                        fsdp=True),
    "yi-opt": dict(fsdp=True, cast_params_bf16=True),
    "yi-opt-dots": dict(fsdp=True, cast_params_bf16=True,
                        remat_policy="dots"),
    "ssm-full-opt": dict(ssm_fused_coeffs=True, ssm_chunk=64,
                         cast_params_bf16=True),
}


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, sp_seq: bool = False,
             variant: str | None = None, microbatches: int = 1,
             extra: dict | None = None) -> dict:
    import dataclasses

    import jax
    from repro.configs import SHAPES, get_arch
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    cfg = get_arch(arch_id)
    if variant:
        cfg = dataclasses.replace(cfg, **VARIANTS[variant])
    shape = SHAPES[shape_id]
    rec: dict = {
        "arch": arch_id, "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": len(jax.devices()),
        "variant": variant or "baseline",
    }
    if shape_id in cfg.skip_shapes:
        rec["status"] = "SKIP"
        rec["reason"] = ("full-attention arch: 500k-token decode requires "
                        "sub-quadratic attention (DESIGN.md)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    jitted, args = build_cell(cfg, shape, mesh, sp_seq=sp_seq,
                              microbatches=microbatches)
    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.perf_counter() - t0, 2)

    t0 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 2)

    mem = compiled.memory_analysis()
    if mem is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(mem, f, None)
            if v is not None:
                rec[f] = int(v)
    from repro.compat import compiled_cost_analysis
    cost = compiled_cost_analysis(compiled)
    if cost:
        # NOTE: XLA cost analysis counts while-loop bodies ONCE; kept for
        # reference.  The loop-corrected numbers come from hlo_analysis.
        rec["xla_flops_per_device_loopbody_once"] = float(
            cost.get("flops", 0.0))
        rec["xla_bytes_per_device_loopbody_once"] = float(
            cost.get("bytes accessed", 0.0))
    text = compiled.as_text()
    from repro.launch import hlo_analysis
    g = hlo_analysis.analyze(text)
    rec["dot_flops_per_device"] = g["dot_flops"]
    rec["hbm_bytes_per_device"] = g["hbm_bytes"]          # per-consumer reads
    rec["hbm_write_bytes_per_device"] = g["hbm_write_bytes"]
    rec["collectives"] = {
        "bytes": g["collective_bytes"],
        "counts": g["collective_counts"],
        "total_bytes": g["collective_total_bytes"],
    }
    xf = rec.get("xla_flops_per_device_loopbody_once", 0.0)
    if xf > 0 and g["dot_flops"] > 0:
        rec["loop_correction"] = max(1.0, g["dot_flops"] / xf)
    rec["hlo_size_chars"] = len(text)
    rec["status"] = "OK"
    if extra:
        rec.update(extra)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--sp-seq", action="store_true",
                    help="sequence-parallel residuals (perf variant)")
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS),
                    help="ArchConfig perf-variant overrides")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation slices for train cells")
    ap.add_argument("--tag", default="", help="suffix for result files")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS, SHAPES

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out_dir, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                tag = ("multi" if multi else "single") + args.tag
                if args.variant:
                    tag += f".{args.variant}"
                path = os.path.join(args.out_dir, f"{arch}_{shape}_{tag}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-existing] {path}")
                    continue
                print(f"=== {arch} x {shape} x "
                      f"{'2x16x16' if multi else '16x16'}"
                      f"{' [' + args.variant + ']' if args.variant else ''}"
                      " ===", flush=True)
                try:
                    rec = run_cell(arch, shape, multi, sp_seq=args.sp_seq,
                                   variant=args.variant,
                                   microbatches=args.microbatches)
                except Exception as e:  # noqa: BLE001 -- record & continue
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAIL",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                print(f"--> {status} "
                      + (f"(compile {rec.get('compile_s')}s, "
                         f"flops/dev {rec.get('hlo_flops_per_device', 0):.3g}, "
                         f"coll {rec.get('collectives', {}).get('total_bytes', 0):.3g}B)"
                         if status == "OK" else rec.get("reason", rec.get("error", ""))),
                      flush=True)


if __name__ == "__main__":
    main()
