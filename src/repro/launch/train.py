"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \
        --steps 50 --batch 8 --seq 256

``--smoke`` uses the family-faithful reduced config (CPU-runnable); omit it
on real hardware for the full architecture.  Any ArchConfig field can be
overridden with ``--set field=value``.
"""
from __future__ import annotations

import argparse
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data-file", default=None,
                    help="flat int32 token file (default: synthetic stream)")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 16x16 on a pod")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override field=value")
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    for ov in args.set:
        k, v = ov.split("=", 1)
        cur = getattr(cfg, k)
        cfg = dataclasses.replace(cfg, **{k: type(cur)(v) if cur is not None
                                          else eval(v)})  # noqa: S307

    nd, nm = (int(x) for x in args.mesh.split("x"))
    mesh = make_debug_mesh(nd, nm)
    tcfg = TrainerConfig(
        steps=args.steps, seq_len=args.seq, global_batch=args.batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        optimizer=AdamWConfig(peak_lr=args.lr, warmup_steps=args.steps // 10,
                              total_steps=args.steps),
    )
    stream = None
    if args.data_file:
        from repro.data.pipeline import DataConfig, TokenFileStream
        stream = TokenFileStream(
            DataConfig(seq_len=args.seq, global_batch=args.batch,
                       vocab=cfg.vocab), args.data_file)
    trainer = Trainer(cfg, tcfg, mesh, stream=stream)
    trainer.train()
    print(f"straggler steps: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
