"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips ("data", "model").
Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model") -- the "pod" axis is
an extra data-parallel dimension crossing the inter-pod DCN.

Mesh creation goes through ``repro.compat.make_mesh`` so the ``axis_types``
API difference between jax releases is handled in one place.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for CPU tests (1 device unless XLA_FLAGS raised it)."""
    return make_mesh((n_data, n_model), ("data", "model"))
