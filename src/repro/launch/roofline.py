"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md Sec. Roofline).

Three terms per (arch x shape) on the single-pod 16x16 mesh, TPU v5e-class
constants:

    compute    = HLO_dot_FLOPs_total / (chips * 197 TFLOP/s)
    memory     = HBM_bytes_per_device / 819 GB/s
                 (band: lower = 2 * unique-materialization writes,
                        upper = per-consumer operand+output traffic --
                  TPUs have no cache between VMEM and HBM, so the upper
                  bound is the physical model; both reported)
    collective = collective_operand_bytes_per_device / 50 GB/s (1 ICI link,
                 conservative)

plus MODEL_FLOPS = 6*N*D (train) / 2*N*D (prefill/decode), N = active
params, D = tokens -- and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

The projected roofline fraction (the Perf score driver) is
    frac = compute_term / max(all terms)
i.e. how much of the step's bound time the MXUs could be busy.

``--cim-sweep`` additionally routes every architecture's GEMM mix through
the async DSE service (``repro.service``): per-arch EE/Th co-explorations
stream out incrementally as their executable buckets finish, giving the
CIM-side counterpart of the roofline table without blocking on the slowest
network.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link


def model_flops(arch_id: str, shape_id: str) -> float:
    from repro.configs import SHAPES, get_arch
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_id]
    n = cfg.active_params_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch        # decode: one token / request


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "OK":
        return None
    n_dev = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec.get("dot_flops_per_device", 0.0)
    t_comp = flops_dev / PEAK_FLOPS
    up = rec.get("hbm_bytes_per_device", 0.0)
    lo = 2.0 * rec.get("hbm_write_bytes_per_device", 0.0)
    t_mem_hi = up / HBM_BW
    t_mem_lo = lo / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / LINK_BW
    bound = max(t_comp, t_mem_hi, t_coll, 1e-30)
    dominant = ("compute" if bound == t_comp else
                "memory" if bound == t_mem_hi else "collective")
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops_dev * n_dev
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_lo_s": t_mem_lo,
        "t_memory_hi_s": t_mem_hi, "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": t_comp / bound,
        "roofline_fraction_memlo": t_comp / max(t_comp, t_mem_lo, t_coll,
                                                1e-30),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "collective_bytes_per_dev": rec["collectives"]["total_bytes"],
        "coll_breakdown": rec["collectives"]["bytes"],
        "compile_s": rec.get("compile_s"),
    }


def hint(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("shrink/overlap collectives: reduce-scatter grads, bf16 "
                "sync, overlap TP all-reduce with the next matmul")
    if d == "memory":
        if row["shape"].startswith("decode") or row["shape"].startswith("long"):
            return ("weight/cache reads bound one-token decode: raise batch "
                    "per chip, quantize KV, fuse cache update")
        return ("cut activation traffic: fuse elementwise chains, less "
                "remat recompute, bf16 master grads")
    return "compute-bound: raise per-chip utilization (larger tiles / fusion)"


def build(out_dir: str = "experiments/dryrun", mesh: str = "16x16",
          tag: str = "single") -> list[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(out_dir, f"*_{tag}.json"))):
        rec = json.load(open(p))
        row = analyze_cell(rec)
        if row and row["mesh"] == mesh:
            row["hint"] = hint(row)
            rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s (lo-hi) | collective s | "
           "dominant | roofline frac | 6ND/HLO |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_lo_s']:.3g}-{r['t_memory_hi_s']:.3g} | "
            f"{r['t_collective_s']:.3g} | {r['dominant']} | "
            f"{r['roofline_fraction']:.3f} | {r['useful_ratio']:.2f} |")
    return hdr + "\n".join(lines) + "\n"


def cim_sweep(
    arch_ids: list[str],
    area_budget_mm2: float = 5.0,
    macro_name: str = "vanilla-dcim",
    seq: int = 512,
    method: str = "exhaustive",
    emit=None,
) -> list[dict]:
    """Stream per-arch CIM co-exploration rows through the DSE service.

    Submits ``2 x len(arch_ids)`` jobs (best-EE and best-Th per network) in
    one shot; ``emit`` fires a formatted row the moment BOTH of a network's
    jobs complete, so fast executable buckets report while slow ones still
    sweep.  Returns the per-arch records in completion order."""
    from repro.configs import get_arch
    from repro.core.engine import ExploreJob
    from repro.core.macro import get_macro
    from repro.service import as_completed, default_service

    if emit is None:
        emit = lambda s: print(s, flush=True)
    svc = default_service()
    macro = get_macro(macro_name)
    t0 = time.perf_counter()
    futures = []
    for arch in arch_ids:
        wl = get_arch(arch).workload(seq=seq)
        for obj in ("ee", "th"):
            futures.append(svc.submit(
                ExploreJob(macro, wl, area_budget_mm2, objective=obj),
                method=method, meta=(arch, obj)))

    done: dict[str, dict] = {a: {} for a in arch_ids}
    rows: list[dict] = []
    for fut in as_completed(futures):
        arch, obj = fut.meta
        done[arch][obj] = fut.result()
        if len(done[arch]) < 2:
            continue
        ee, th = done[arch]["ee"], done[arch]["th"]
        row = {
            "arch": arch, "macro": macro_name,
            "budget_mm2": area_budget_mm2,
            "best_ee_cfg": ee.config.as_tuple(),
            "tops_w": ee.metrics["tops_w"],
            "best_th_cfg": th.config.as_tuple(),
            "gops": th.metrics["gops"],
            "elapsed_s": time.perf_counter() - t0,
            "cached": ee.search.get("cache") == "store",
        }
        rows.append(row)
        emit(f"| {arch} | {macro_name} | {row['best_ee_cfg']} | "
             f"{row['tops_w']:.2f} TOPS/W | {row['best_th_cfg']} | "
             f"{row['gops']:.0f} GOPS | {row['elapsed_s']:.1f}s"
             f"{' (cached)' if row['cached'] else ''} |")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="single")
    ap.add_argument("--json", default="experiments/roofline.json")
    ap.add_argument("--md", default="experiments/roofline.md")
    ap.add_argument("--cim-sweep", default=None, metavar="ARCHS",
                    help="comma-separated arch ids (or 'all'): stream CIM "
                         "co-exploration rows via the DSE service instead "
                         "of analyzing dry-run artifacts")
    ap.add_argument("--cim-budget", type=float, default=5.0)
    ap.add_argument("--cim-macro", default="vanilla-dcim")
    args = ap.parse_args()

    if args.cim_sweep:
        from repro.configs import ARCH_IDS
        archs = list(ARCH_IDS) if args.cim_sweep == "all" \
            else args.cim_sweep.split(",")
        print("| arch | macro | best-EE cfg | TOPS/W | best-Th cfg | GOPS "
              "| elapsed |", flush=True)
        rows = cim_sweep(archs, args.cim_budget, args.cim_macro)
        out_dir = os.path.dirname(args.json)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=list)
        return

    rows = build(args.out_dir, tag=args.tag)
    with open(args.json, "w") as f:
        json.dump(rows, f, indent=1)
    md = to_markdown(rows)
    with open(args.md, "w") as f:
        f.write(md)
    print(md)


if __name__ == "__main__":
    main()
