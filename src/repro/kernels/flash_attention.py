"""Flash attention (streaming softmax) Pallas kernel for the 32k-prefill
cells: O(T * block) VMEM instead of the O(T^2) score matrix.

Grid (batch*heads, q_blocks, kv_blocks); running max / denominator / f32
accumulator live in VMEM scratch across the kv axis; causal masking prunes
nothing structurally (blocks above the diagonal are masked, not skipped --
skipping is a recorded perf lever for real TPU runs).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, n_kv: int,
            kv_len: int):
    kv_step = pl.program_id(2)

    @pl.when(kv_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # [bq, d]
    k = k_ref[0].astype(jnp.float32)               # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T) * scale                           # [bq, bk]

    kpos = kv_step * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < kv_len                          # right-padded keys
    if causal:
        qi = pl.program_id(1)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(kv_step == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,             # [BH, T, d]
    k: jax.Array,             # [BH, S, d]
    v: jax.Array,
    *,
    causal: bool = True,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, t, d = q.shape
    s_len = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    pq, pk = (-t) % bq, (-s_len) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    gq, gk_ = q.shape[1] // bq, k.shape[1] // bk

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, bq=bq, bk=bk,
        n_kv=gk_, kv_len=s_len)
    out = pl.pallas_call(
        kern,
        grid=(bh, gq, gk_),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :t]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
