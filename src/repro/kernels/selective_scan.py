"""Fused selective-scan (Mamba-1) Pallas kernel — the TPU-native answer to
the roofline finding that mamba prefill/train is bound by materializing
[B, T, I, S] recurrence coefficients in HBM (EXPERIMENTS Perf cell B).

Layout: grid (B, I_tiles, T_chunks), T innermost.  The hidden state
h [I_TILE, S] lives in VMEM scratch for the *entire* sequence of one
(batch, channel-tile): coefficients da = exp(dt*a) and dbx = dt*B*x are
computed on the fly from the [CT, I_TILE] / [CT, S] chunk inputs and never
touch HBM.  HBM traffic is exactly inputs (xi, dt, b, c) + outputs (y) --
the information-theoretic minimum -- versus the jnp path's
O(T*I*S)-per-level associative-scan materializations.

The recurrence is sequential over time inside the chunk (lax.fori_loop on
[I_TILE, S] VPU ops); TPU grid steps along the last axis are sequential, so
the scratch legally carries state across T-chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CT = 128       # timesteps per grid step
DEFAULT_CI = 256       # channel tile


def _kernel(xi_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hlast_ref, h_ref, *, n_tchunks: int, ct: int):
    t_step = pl.program_id(2)

    @pl.when(t_step == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)       # [CI, S]

    a = a_ref[...].astype(jnp.float32)                   # [CI, S]
    xi = xi_ref[0].astype(jnp.float32)                   # [CT, CI]
    dt = dt_ref[0].astype(jnp.float32)                   # [CT, CI]
    bm = b_ref[0].astype(jnp.float32)                    # [CT, S]
    cm = c_ref[0].astype(jnp.float32)                    # [CT, S]

    def step(t, carry):
        h, y = carry
        da = jnp.exp(dt[t][:, None] * a)                 # [CI, S]
        dbx = (dt[t] * xi[t])[:, None] * bm[t][None, :]  # [CI, S]
        h = da * h + dbx
        y = y.at[t].set(h @ cm[t])                       # [CI]
        return h, y

    h0 = h_ref[...]
    y0 = jnp.zeros((ct, xi.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, ct, step, (h0, y0))
    h_ref[...] = h
    y_ref[0, ...] = y.astype(y_ref.dtype)

    @pl.when(t_step == n_tchunks - 1)
    def _emit_state():
        hlast_ref[0, ...] = h.astype(hlast_ref.dtype)


def selective_scan(
    xi: jax.Array,       # [B, T, I]
    dt: jax.Array,       # [B, T, I]
    bmat: jax.Array,     # [B, T, S]
    cmat: jax.Array,     # [B, T, S]
    a: jax.Array,        # [I, S]
    h0: jax.Array,       # [B, I, S]
    *,
    ct: int = DEFAULT_CT,
    ci: int = DEFAULT_CI,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, T, I], h_last [B, I, S])."""
    b, t, i = xi.shape
    s = a.shape[1]
    ci = min(ci, i)
    pad_t = (-t) % ct
    pad_i = (-i) % ci
    if pad_t:
        # dt = 0 padding makes the extra steps identity (da=1, dbx=0)
        xi = jnp.pad(xi, ((0, 0), (0, pad_t), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad_t), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad_t), (0, 0)))
    if pad_i:
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, pad_i)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad_i)))
        a = jnp.pad(a, ((0, pad_i), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_i), (0, 0)))
    tp, ip = xi.shape[1], xi.shape[2]
    grid = (b, ip // ci, tp // ct)

    y, hlast = pl.pallas_call(
        functools.partial(_kernel, n_tchunks=tp // ct, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, ci), lambda bb, ii, tt: (bb, tt, ii)),  # xi
            pl.BlockSpec((1, ct, ci), lambda bb, ii, tt: (bb, tt, ii)),  # dt
            pl.BlockSpec((1, ct, s), lambda bb, ii, tt: (bb, tt, 0)),    # b
            pl.BlockSpec((1, ct, s), lambda bb, ii, tt: (bb, tt, 0)),    # c
            pl.BlockSpec((ci, s), lambda bb, ii, tt: (ii, 0)),           # a
            pl.BlockSpec((1, ci, s), lambda bb, ii, tt: (bb, ii, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, ct, ci), lambda bb, ii, tt: (bb, tt, ii)),  # y
            pl.BlockSpec((1, ci, s), lambda bb, ii, tt: (bb, ii, 0)),    # h
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, tp, ip), xi.dtype),
            jax.ShapeDtypeStruct((b, ip, s), h0.dtype),
        ],
        scratch_shapes=[_vmem((ci, s), jnp.float32)],
        interpret=interpret,
    )(xi, dt, bmat, cmat, a, h0)
    return y[:, :t, :i], hlast[:, :i]


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
