"""Pallas TPU kernels for the perf-critical compute layers.

* ``cim_matmul``     -- the paper's AF/PF macro-tiling insight mapped onto
  TPU loop order / BlockSpec residency (VMEM = IS/OS, SCR = co-resident
  K-blocks).  See DESIGN.md Sec. 2.
* ``strategy_eval``  -- the DSE hot loop (candidates x ops x 8 strategies)
  as a VPU kernel.
* ``flash_attention``-- streaming-softmax attention for the 32k-prefill
  cells.
* ``selective_scan`` -- fused Mamba-1 scan: hidden state resident in VMEM
  across the sequence, coefficients computed in-kernel (the TPU answer to
  the Perf-cell-B memory wall).

Each kernel ships ``<name>.py`` (pl.pallas_call + BlockSpec), a jit'd
wrapper in ``ops.py`` and a pure-jnp oracle in ``ref.py``; kernels are
validated in interpret mode on CPU (the TPU custom-call path cannot compile
on this host -- the dry-run lowers the jnp path instead).
"""
