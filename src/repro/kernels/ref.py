"""Pure-jnp oracles for every kernel (the correctness ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.calibration import resolve_tech


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Naive softmax attention.  q,k,v: [BH, T|S, d]."""
    d = q.shape[-1]
    s = jnp.einsum("btd,bsd->bts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        t, s_len = s.shape[-2], s.shape[-1]
        mask = jnp.arange(s_len)[None, :] <= jnp.arange(t)[:, None]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsd->btd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def strategy_eval_ref(candidates, ops_arr, macro, *, objective="ee",
                      strategy_set="st", tech=None):
    """Identical math to the kernel, no pallas_call."""
    tech = resolve_tech(tech)
    from repro.kernels.strategy_eval import _objective_block, _strat_tables
    bits, allowed = _strat_tables(strategy_set)
    return _objective_block(
        jnp.asarray(candidates, jnp.float32),
        jnp.asarray(ops_arr, jnp.float32),
        jnp.asarray(bits), jnp.asarray(allowed), macro, tech, objective)


def selective_scan_ref(xi, dt, bmat, cmat, a, h0, chunk: int = 64):
    """Oracle via the model's chunked associative linear scan."""
    from repro.models.ssm import linear_scan
    da = jnp.exp(dt[..., None] * a[None, None])
    dbx = (dt * xi)[..., None] * bmat[:, :, None, :]
    hs = jax.vmap(lambda aa, bb, h: linear_scan(aa, bb, h, chunk=chunk))(
        da, dbx, h0)
    y = jnp.einsum("btis,bts->bti", hs, cmat)
    return y, hs[:, -1]
