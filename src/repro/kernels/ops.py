"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU hosts (the TPU custom-call path can't
compile here); on a TPU runtime pass interpret=False (or set
REPRO_PALLAS_COMPILE=1) for the real kernels.

With ``CIM_TUNER_PROFILE`` set, every call is timed to completion and
recorded into the ``cim_kernel_*`` metric families per (kernel, shape
bucket) -- see ``repro.obs.profile``.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cim_matmul as _cm
from repro.kernels import flash_attention as _fa
from repro.kernels import selective_scan as _ss
from repro.kernels import strategy_eval as _se
from repro.obs import profile as _profile


def _default_interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("tiling", "bm", "bn", "bk", "interpret"))
def _cim_matmul(a, b, *, tiling="AF", bm=_cm.DEFAULT_BM, bn=_cm.DEFAULT_BN,
                bk=_cm.DEFAULT_BK, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _cm.cim_matmul(a, b, tiling=tiling, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def _flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                     interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=interpret)


def _strategy_eval(candidates, ops_arr, macro, *, objective="ee",
                   interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    fn = partial(_se.strategy_eval, macro=macro, objective=objective,
                 interpret=interpret)
    return jax.jit(fn)(jnp.asarray(candidates, jnp.float32),
                       jnp.asarray(ops_arr, jnp.float32))


@partial(jax.jit, static_argnames=("ct", "ci", "interpret"))
def _selective_scan(xi, dt, bmat, cmat, a, h0, *, ct=_ss.DEFAULT_CT,
                    ci=_ss.DEFAULT_CI, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ss.selective_scan(xi, dt, bmat, cmat, a, h0, ct=ct, ci=ci,
                              interpret=interpret)


# shape-bucket labels for the cim_kernel_* series (bounded cardinality:
# real callers reuse a handful of canonical shapes per kernel)
def _matmul_bucket(a, b, **kw):
    return f"{a.shape[0]}x{b.shape[1]}x{a.shape[1]}"


def _attn_bucket(q, k, v, **kw):
    return f"{q.shape[0]}x{q.shape[1]}x{k.shape[1]}x{q.shape[2]}"


def _strat_bucket(candidates, ops_arr, macro, **kw):
    return f"C{len(candidates)}xP{len(ops_arr)}"


def _scan_bucket(xi, dt, bmat, cmat, a, h0, **kw):
    return f"{xi.shape[0]}x{xi.shape[1]}x{xi.shape[2]}x{a.shape[1]}"


cim_matmul = _profile.instrument("cim_matmul", _cim_matmul,
                                 _matmul_bucket)
flash_attention = _profile.instrument("flash_attention", _flash_attention,
                                      _attn_bucket)
strategy_eval = _profile.instrument("strategy_eval", _strategy_eval,
                                    _strat_bucket)
selective_scan = _profile.instrument("selective_scan", _selective_scan,
                                     _scan_bucket)
