"""Batched CIM-Tuner cost-model evaluation as a Pallas VPU kernel.

The DSE hot loop evaluates candidates x operators x 8 strategies of pure
elementwise arithmetic -- bandwidth-light, VPU-bound.  This kernel tiles the
candidate axis into VMEM blocks and reuses the *same* closed-form cost model
(``core.cost_model.workload_cost_core``) inside the kernel body, so kernel
and oracle can never drift: ref.py is the identical computation without
pallas_call.  The strategy-bit and mask tables are kernel operands (Pallas
kernels may not capture array constants).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import cost_model
from repro.core.calibration import resolve_tech
from repro.core.macro import MacroSpec
from repro.core.strategies import ALL_STRATEGIES, STRATEGY_SETS

CAND_TILE = 256


def _strat_tables(strategy_set: str) -> tuple[np.ndarray, np.ndarray]:
    bits = np.array(
        [[float(s.spatial == "R"), float(s.temporal == "WP"),
          float(s.tiling == "PF")] for s in ALL_STRATEGIES], np.float32)
    allowed = np.array(
        [1.0 if s in STRATEGY_SETS[strategy_set] else 0.0
         for s in ALL_STRATEGIES], np.float32)
    return bits, allowed


def _objective_block(cfg_block, ops_arr, bits, allowed, macro, tech,
                     objective):
    """[T, 6] candidate block -> [T] best-strategy objective values."""
    def per_candidate(cfg_row):
        lat, en, _ = cost_model.workload_cost_core(
            ops_arr, cfg_row, bits, allowed, macro, tech, objective)
        val = cost_model.objective_value(lat, en, objective)
        return jnp.where(
            cost_model.bandwidth_ok_jnp(cfg_row, macro), val,
            cost_model.INFEASIBLE)
    return jax.vmap(per_candidate)(cfg_block)


def _kernel(cfg_ref, ops_ref, bits_ref, allowed_ref, o_ref, *, macro, tech,
            objective):
    o_ref[...] = _objective_block(
        cfg_ref[...], ops_ref[...], bits_ref[...], allowed_ref[...],
        macro, tech, objective).astype(o_ref.dtype)


def strategy_eval(
    candidates: jax.Array,      # [C, 6] (mr, mc, scr, is_kb, os_kb, bw)
    ops_arr: jax.Array,         # [P, 5]
    macro: MacroSpec,
    *,
    objective: str = "ee",
    strategy_set: str = "st",
    tech=None,
    tile: int = CAND_TILE,
    interpret: bool = False,
) -> jax.Array:
    tech = resolve_tech(tech)
    c = candidates.shape[0]
    pad = (-c) % tile
    if pad:
        candidates = jnp.pad(candidates, ((0, pad), (0, 0)),
                             constant_values=1.0)
    bits, allowed = _strat_tables(strategy_set)
    grid = (candidates.shape[0] // tile,)
    out = pl.pallas_call(
        functools.partial(_kernel, macro=macro, tech=tech,
                          objective=objective),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 6), lambda i: (i, 0)),
            pl.BlockSpec(ops_arr.shape, lambda i: (0, 0)),   # replicated
            pl.BlockSpec(bits.shape, lambda i: (0, 0)),
            pl.BlockSpec(allowed.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((candidates.shape[0],),
                                       jnp.float32),
        interpret=interpret,
    )(candidates.astype(jnp.float32), ops_arr.astype(jnp.float32),
      jnp.asarray(bits), jnp.asarray(allowed))
    return out[:c]
