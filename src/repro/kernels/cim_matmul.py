"""AF/PF blocked matmul: the paper's macro-level tiling on TPU.

The CIM macro's Accumulation-First vs Parallel-First choice (paper Fig. 6) is
exactly the loop-order choice of a blocked matmul:

  AF  -- grid (m, n, k), K innermost: one output tile stays in the VMEM
         accumulator while SCR consecutive K-blocks stream through (psum
         register reuse); input blocks are re-fetched per output column.
  PF  -- grid (m, k, n), N innermost: one input block stays VMEM-resident
         while SCR consecutive N-blocks compute (input reuse); the output
         tile is revisited across the K grid axis, so partial sums make
         extra HBM round-trips -- the Output-SRAM pressure of the paper.

Both orders produce identical numerics (tests assert allclose against the
jnp.dot oracle across shape/dtype sweeps); they differ in traffic, which is
what CIM-Tuner's cost model trades off.  Block shapes are MXU-aligned
(multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel_af(a_ref, b_ref, o_ref, acc_ref, *, n_contract: int):
    """AF body: K innermost; the f32 VMEM scratch plays the CIM psum
    register -- one output tile accumulates fully before a single HBM emit."""
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(step == n_contract - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_pf(a_ref, b_ref, o_ref):
    """PF body: N innermost; the input block stays VMEM-resident while the
    output tile is read-modify-written across the K grid axis -- the psum
    HBM round-trips that CIM-Tuner charges the PF strategy (paper Fig. 8).
    Accumulation happens at the output dtype, mirroring dw_psum."""
    step = pl.program_id(1)
    partial_ = jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = partial_

    @pl.when(step > 0)
    def _rmw():
        o_ref[...] += partial_


def cim_matmul(
    a: jax.Array,              # [M, K]
    b: jax.Array,              # [K, N]
    *,
    tiling: str = "AF",        # "AF" | "PF"
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype

    pm, pk, pn = (-m) % bm, (-k) % bk, (-n) % bn
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    gm, gk, gn = a.shape[0] // bm, a.shape[1] // bk, b.shape[1] // bn

    if tiling == "AF":
        grid = (gm, gn, gk)                  # K innermost: psum reuse
        out = pl.pallas_call(
            functools.partial(_kernel_af, n_contract=gk),
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
                pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
            out_shape=jax.ShapeDtypeStruct(
                (a.shape[0], b.shape[1]), out_dtype),
            scratch_shapes=[_vmem_scratch((bm, bn))],
            interpret=interpret,
        )(a, b)
    elif tiling == "PF":
        grid = (gm, gk, gn)                  # N innermost: input reuse
        out = pl.pallas_call(
            _kernel_pf,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, s, j: (i, s)),
                pl.BlockSpec((bk, bn), lambda i, s, j: (s, j)),
            ],
            # output revisited across the K grid axis: psum traffic
            out_specs=pl.BlockSpec((bm, bn), lambda i, s, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct(
                (a.shape[0], b.shape[1]), out_dtype),
            interpret=interpret,
        )(a, b)
    else:
        raise ValueError(f"tiling must be AF or PF, got {tiling!r}")
    return out[:m, :n]


def _vmem_scratch(shape):
    """f32 VMEM accumulator tile (the psum register of the CIM analogy)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, jnp.float32)
