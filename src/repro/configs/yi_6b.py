"""Yi-6B [arXiv:2403.04652]: llama-arch GQA decoder."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, vocab=64000,
    mlp_act="swiglu", rope_theta=5e6,
    skip_shapes=("long_500k",),   # pure full attention (see DESIGN.md)
)
