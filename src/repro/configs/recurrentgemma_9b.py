"""RecurrentGemma-9B [arXiv:2402.19427]: Griffin hybrid, RG-LRU + local
attention 1:2 pattern; 38 = 12 x (rglru, rglru, local_attn) + 2 remainder
rglru layers.  Sub-quadratic: runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000,
    mlp_act="geglu", rope_theta=1e4, window=2048,
    pattern=("rglru", "rglru", "local_attn"),
    d_inner=4096, ssm_conv=4,
    tie_embeddings=True, emb_scale=True,
)
