"""H2O-Danube3-4B [arXiv:2401.16818]: llama+mistral mix with sliding-window
attention -> the 500k-decode cell runs (O(window) cache)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab=32000,
    mlp_act="swiglu", rope_theta=1e4,
    window=4096,
)
