"""Mixtral-8x7B [arXiv:2401.04088]: 8-expert top-2 MoE with sliding-window
attention -> runs long_500k (O(window) cache).  FSDP on: 47B params."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    mlp_act="swiglu", rope_theta=1e6, window=4096,
    pattern=("moe",),
    n_experts=8, moe_top_k=2,
    fsdp=True,
)
