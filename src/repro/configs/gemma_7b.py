"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256, MHA (kv=16), 256k vocab,
tied + scaled embeddings."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    mlp_act="geglu", rope_theta=1e4,
    tie_embeddings=True, emb_scale=True,
    skip_shapes=("long_500k",),
)
