"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407]: 128k-context GQA,
head_dim 128 (not d_model/n_heads), 131k vocab.  FSDP on: 12B params."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131072,
    mlp_act="swiglu", rope_theta=1e6,
    fsdp=True,
    skip_shapes=("long_500k",),
)
