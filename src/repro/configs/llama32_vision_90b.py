"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision]: text backbone
with gated cross-attention image layers every 5th layer; the vision tower is
a STUB (input_specs provides projected patch embeddings).  FSDP on: 90B."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    mlp_act="swiglu", rope_theta=5e5,
    pattern=("cross", "self", "self", "self", "self"),
    n_memory=1024,
    fsdp=True,
    skip_shapes=("long_500k",),
)
