"""Architecture config system: one frozen dataclass per assigned arch,
a registry (``--arch <id>``), the assigned input-shape set, reduced smoke
configs, and the CIM-Tuner workload extraction bridge.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

from repro.core.ir import (
    MatmulOp,
    Workload,
    lm_head_ops,
    ssm_layer_ops,
    transformer_layer_ops,
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape (a dry-run cell column)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    # backbone
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # variants
    mlp_act: str = "swiglu"        # swiglu | geglu | gelu
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    rope_theta: Optional[float] = 1e4
    window: Optional[int] = None   # sliding-window attention
    tie_embeddings: bool = False
    emb_scale: bool = False        # gemma: embed * sqrt(d)
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    # SSM (mamba)
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner: int = 0
    dt_rank: int = 0
    # hybrid (griffin): block pattern, e.g. ("rglru", "rglru", "local_attn")
    pattern: tuple[str, ...] = ("dense",)
    # cross-attention memory (vlm / audio encoder output)
    n_memory: int = 0              # stub tokens provided by input_specs
    encoder_layers: int = 0        # audio enc-dec
    max_decode_len: int = 32768    # learned-position table size (audio)
    # training/runtime policy
    fsdp: bool = False             # shard params over the data axis too
    shard_attn: bool = True        # head-shard attention over "model"
    remat: bool = True
    scan_layers: bool = True
    # ---- perf-variant switches (EXPERIMENTS.md Sec. Perf levers) ----
    moe_row_dispatch: bool = False   # per-batch-row-local MoE dispatch
    cast_params_bf16: bool = False   # one-time bf16 weight cast per step
    remat_policy: str = "full"       # "full" | "dots" (save matmul outputs)
    ssm_fused_coeffs: bool = False   # compute scan coeffs inside the chunk
    ssm_chunk: int = 256             # linear-scan chunk length
    seq_shard_attn: bool = False     # context-parallel attention (q-seq over
                                     # "model") for archs whose head count
                                     # doesn't divide the TP axis
    # which assigned shapes run (long_500k only for sub-quadratic archs)
    skip_shapes: tuple[str, ...] = ()

    # ------------------------------------------------------------------ #
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def group_pattern(self) -> tuple[str, ...]:
        return self.pattern

    def n_groups(self) -> tuple[int, int]:
        """(full scanned groups, remainder layers)."""
        g = len(self.pattern)
        return self.n_layers // g, self.n_layers % g

    def _layer_params(self, kind: str) -> int:
        d = self.d_model
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        if kind in ("dense", "local_attn", "self", "enc_self"):
            return attn + self._ffn_params()
        if kind == "moe":
            return attn + d * self.n_experts + \
                self.n_experts * self._ffn_params()
        if kind == "mamba":
            i = self.d_inner
            return (d * 2 * i + i * (self.dt_rank + 2 * self.ssm_state)
                    + self.dt_rank * i + i * d + i * self.ssm_state)
        if kind == "rglru":
            i = self.d_inner
            return d * 2 * i + 2 * i * i + i * d + self._ffn_params()
        if kind == "cross":
            return attn + self._ffn_params()
        if kind == "dec_self_cross":
            return 2 * attn + self._ffn_params()
        raise ValueError(f"unknown block kind {kind}")

    def _layer_counts(self) -> dict[str, int]:
        """Layers per block kind (full scanned groups + remainder prefix)."""
        full, rem = self.n_groups()
        counts: dict[str, int] = {}
        for i, kind in enumerate(self.pattern):
            counts[kind] = counts.get(kind, 0) + full + (1 if i < rem else 0)
        return counts

    def params_estimate(self) -> int:
        """Parameter count (drives roofline MODEL_FLOPS = 6*N*D)."""
        n = sum(self._layer_params(k) * c
                for k, c in self._layer_counts().items())
        n += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            n += self.encoder_layers * self._layer_params("enc_self")
        return n

    def _ffn_params(self) -> int:
        gated = self.mlp_act in ("swiglu", "geglu")
        return self.d_model * self.d_ff * (3 if gated else 2)

    def active_params_estimate(self) -> int:
        """MoE: only top-k experts count toward MODEL_FLOPS."""
        if not self.n_experts:
            return self.params_estimate()
        full = self.params_estimate()
        inactive = (self.n_experts - self.moe_top_k) * self._ffn_params() \
            * self.n_layers
        return full - inactive

    # ------------------------------------------------------------------ #
    def reduced(self) -> "ArchConfig":
        """Family-faithful small config for CPU smoke tests."""
        g = len(self.pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(g, 2 if g == 1 else g),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2) if self.moe_top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            d_inner=128 if self.d_inner else 0,
            dt_rank=8 if self.dt_rank else 0,
            window=min(self.window, 32) if self.window else None,
            n_memory=16 if self.n_memory else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            max_decode_len=128,
            fsdp=False,
        )

    # ------------------------------------------------------------------ #
    # CIM-Tuner bridge: extract the matmul operator mix of one forward pass
    # ------------------------------------------------------------------ #
    def workload(self, seq: int = 512, include_lm_head: bool = True) -> Workload:
        ops: list[MatmulOp] = []
        for kind, cnt in self._layer_counts().items():
            layer = self._layer_ops(kind, seq)
            ops.extend(
                dataclasses.replace(o, count=o.count * cnt) for o in layer
            )
        if self.encoder_layers:
            enc = transformer_layer_ops(
                seq=self.n_memory or 1500, d_model=self.d_model,
                n_heads=self.n_heads, n_kv_heads=self.n_kv_heads,
                head_dim=self.head_dim, d_ff=self.d_ff,
                gated_ffn=self.mlp_act in ("swiglu", "geglu"),
                prefix="enc_")
            ops.extend(
                dataclasses.replace(o, count=o.count * self.encoder_layers)
                for o in enc)
        if include_lm_head:
            ops.extend(lm_head_ops(seq=seq, d_model=self.d_model,
                                   vocab=self.vocab))
        return Workload(self.name, tuple(ops)).merged()

    def _layer_ops(self, kind: str, seq: int) -> list[MatmulOp]:
        gated = self.mlp_act in ("swiglu", "geglu")
        common = dict(
            seq=seq, d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            gated_ffn=gated,
        )
        if kind in ("dense", "self", "enc_self"):
            return transformer_layer_ops(
                d_ff=self.d_ff, window=self.window, **common)
        if kind == "local_attn":
            return transformer_layer_ops(
                d_ff=self.d_ff, window=self.window or 2048, **common)
        if kind == "moe":
            return transformer_layer_ops(
                d_ff=self.d_ff, n_experts=self.n_experts,
                top_k=self.moe_top_k, window=self.window, **common)
        if kind == "mamba":
            return ssm_layer_ops(
                seq=seq, d_model=self.d_model, d_inner=self.d_inner,
                d_state=self.ssm_state, dt_rank=self.dt_rank)
        if kind == "rglru":
            i = self.d_inner
            ffn = transformer_layer_ops(d_ff=self.d_ff, **common)[-2:]
            return [
                MatmulOp(seq, self.d_model, 2 * i, name="rg_in"),
                MatmulOp(seq, i, i, count=2, name="rg_gates"),
                MatmulOp(seq, i, self.d_model, name="rg_out"),
            ] + ffn
        if kind in ("cross", "dec_self_cross"):
            return transformer_layer_ops(
                d_ff=self.d_ff, window=self.window,
                cross_attn_src=self.n_memory or 1500, **common)
        raise ValueError(f"unknown block kind {kind}")


# ---------------------------------------------------------------------- #
# registry
# ---------------------------------------------------------------------- #
ARCH_IDS = (
    "yi-6b", "gemma-7b", "mistral-nemo-12b", "h2o-danube-3-4b",
    "recurrentgemma-9b", "falcon-mamba-7b", "llama-3.2-vision-90b",
    "granite-moe-3b-a800m", "mixtral-8x7b", "whisper-small",
)

_MODULES = {
    "yi-6b": "yi_6b",
    "gemma-7b": "gemma_7b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-small": "whisper_small",
}


def get_arch(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_archs() -> dict[str, ArchConfig]:
    return {a: get_arch(a) for a in ARCH_IDS}
