"""Whisper-small [arXiv:2212.04356]: encoder-decoder; the conv audio
frontend is a STUB (input_specs provides 1500 precomputed frame embeddings).
Decoder shapes run mechanically at the assigned 32k even though the real
model caps at 448 positions (dry-run exercises sharding, not semantics)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865,
    mlp_act="gelu", norm="layernorm", rope_theta=None,
    pattern=("dec_self_cross",),
    n_memory=1500, encoder_layers=12, max_decode_len=32768,
    shard_attn=False,
    skip_shapes=("long_500k",),
)
