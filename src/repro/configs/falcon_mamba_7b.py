"""Falcon-Mamba-7B [arXiv:2410.05355]: pure Mamba-1, attention-free,
O(1)-state decode -> runs long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, head_dim=1,
    d_ff=0, vocab=65024,
    rope_theta=None,
    pattern=("mamba",),
    ssm_state=16, ssm_conv=4, d_inner=8192, dt_rank=256,
)
