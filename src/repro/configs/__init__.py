"""Per-architecture configs (``--arch <id>``).  See base.py for the registry."""
from repro.configs.base import ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, all_archs, get_arch

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeSpec", "all_archs", "get_arch"]
