"""Granite-MoE-3B-A800M [hf:ibm-granite]: 40-expert top-8 MoE with tiny
(512) expert FFNs -- the operator-merging showcase.  24 heads don't divide
the 16-wide model axis -> attention replicated (shard_attn=False)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    mlp_act="swiglu", rope_theta=1e4,
    pattern=("moe",),
    n_experts=40, moe_top_k=8,
    tie_embeddings=True,
    shard_attn=False,
    skip_shapes=("long_500k",),
)
