"""Model zoo substrate: the 10 assigned architectures as native JAX models.

Every architecture is a functional module (explicit param pytrees, scan over
stacked layers, remat) built from the shared blocks in ``layers.py`` /
``moe.py`` / ``ssm.py``.  ``model.py`` exposes the uniform factory consumed
by the trainer, the serving engine and the multi-pod dry-run.
"""
from repro.models.model import Model, build_model

__all__ = ["Model", "build_model"]
