"""Uniform model factory: ArchConfig -> (init, loss, prefill, decode, caches).

The same object drives the trainer, the serving engine, the smoke tests and
the multi-pod dry-run (which builds everything abstractly via eval_shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.layers import COMPUTE_DTYPE

MOE_AUX_COEF = 0.01


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Any]
    loss: Callable[[Any, dict], tuple[jax.Array, dict]]
    prefill: Callable[[Any, dict], tuple[jax.Array, Any]]
    decode: Callable[[Any, Any, jax.Array], tuple[jax.Array, Any]]
    init_cache: Callable[[int, int], Any]

    def abstract_params(self, seed: int = 0):
        return jax.eval_shape(self.init, jax.random.PRNGKey(seed))

    def abstract_cache(self, batch: int, max_len: int):
        # batch/max_len are shape parameters: close over them so eval_shape
        # never traces them as values
        return jax.eval_shape(lambda: self.init_cache(batch, max_len))

    def param_count(self, seed: int = 0) -> int:
        leaves = jax.tree.leaves(self.abstract_params(seed))
        return sum(int(jnp.prod(jnp.asarray(l.shape))) if l.shape else 1
                   for l in leaves)


def build_model(cfg: ArchConfig, shard_act: Callable = tf.Identity) -> Model:
    is_encdec = cfg.encoder_layers > 0
    has_memory = cfg.n_memory > 0

    def init(key):
        return tf.lm_init(key, cfg)

    def _memory(params, batch):
        if not has_memory:
            return None
        mem = batch["memory"].astype(COMPUTE_DTYPE)
        if is_encdec:
            mem = tf.encode_memory(params, cfg, mem, shard_act=shard_act)
        return mem

    def loss(params, batch):
        logits, _, aux = tf.lm_apply(
            params, cfg, batch["tokens"],
            memory=_memory(params, batch), shard_act=shard_act)
        l, metrics = tf.lm_loss(logits, batch["labels"])
        if cfg.n_experts:
            l = l + MOE_AUX_COEF * aux
            metrics = dict(metrics, moe_aux=aux)
        return l, metrics

    def init_cache(batch_size: int, max_len: int):
        caches = tf.stack_cache(cfg, cfg.pattern, cfg.n_layers, batch_size,
                                max_len)
        return {"stack": caches, "step": jnp.zeros((), jnp.int32)}

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, t = tokens.shape
        caches = batch.get("caches")
        if caches is None:
            caches = init_cache(b, t)   # fresh cache sized to the prompt
        logits, new_stack, _ = tf.lm_apply(
            params, cfg, tokens,
            caches=caches["stack"],
            memory=_memory(params, batch),
            pos_offset=0,
            shard_act=shard_act)
        return logits, {"stack": new_stack,
                        "step": caches["step"] + t}

    def decode(params, caches, tokens):
        logits, new_stack, _ = tf.lm_apply(
            params, cfg, tokens,
            caches=caches["stack"],
            memory=None,
            pos_offset=caches["step"],
            shard_act=shard_act)
        return logits, {"stack": new_stack,
                        "step": caches["step"] + tokens.shape[1]}

    return Model(cfg=cfg, init=init, loss=loss, prefill=prefill,
                 decode=decode, init_cache=init_cache)
