"""Generic stacked-architecture assembly.

An architecture is a repeating ``pattern`` of block kinds (ArchConfig.pattern)
-- e.g. dense LMs repeat ("dense",), RecurrentGemma repeats
("rglru", "rglru", "local_attn"), Llama-3.2-Vision repeats
("cross", "self", "self", "self", "self"), Whisper stacks an encoder
("enc_self",) and a decoder ("dec_self_cross",).

Full pattern groups are *scanned* (params stacked [G, ...], ``lax.scan`` +
``jax.checkpoint`` on the group body) which keeps HLO size O(1) in depth --
that is what makes the 100-layer 90B dry-run compile -- and doubles as the
production activation-checkpoint policy.  Layers left over when n_layers %
len(pattern) != 0 run unscanned with their own params ("remainder" prefix of
the pattern, e.g. RecurrentGemma-9B's 38 = 12x3 + 2).

Caches: every block kind defines its own decode cache (KV ring buffer for
sliding-window attention, full KV for dense attention, conv+state for
Mamba/RG-LRU, cross-KV for cross-attention) so ``decode_step`` is O(1) in
generated tokens for every family.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    COMPUTE_DTYPE,
    NEG_INF,
    dense_attention,
    embed_init,
    mlp_apply,
    mlp_params,
    norm_params,
    apply_norm,
    rope,
    _expand_kv,
)

Identity = lambda x, name: x
CACHE_DTYPE = jnp.bfloat16


# ====================================================================== #
# caches
# ====================================================================== #
def _attn_cache(batch: int, cache_len: int, n_kv: int, head_dim: int) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), CACHE_DTYPE),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), CACHE_DTYPE),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


def block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int) -> Any:
    """Decode-cache pytree for one block (zeros; dry-run uses eval_shape)."""
    w = cfg.window
    if kind in ("dense", "self", "moe"):
        clen = min(max_len, w) if w else max_len
        return _attn_cache(batch, clen, cfg.n_kv_heads, cfg.head_dim)
    if kind == "local_attn":
        clen = min(max_len, cfg.window or 2048)
        return _attn_cache(batch, clen, cfg.n_kv_heads, cfg.head_dim)
    if kind == "mamba":
        return {
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                              jnp.float32),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state),
                             jnp.float32),
        }
    if kind == "rglru":
        return {
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                              jnp.float32),
            "h": jnp.zeros((batch, cfg.d_inner), jnp.float32),
        }
    if kind == "cross":
        return {"xk": jnp.zeros((batch, cfg.n_memory, cfg.n_kv_heads,
                                 cfg.head_dim), CACHE_DTYPE),
                "xv": jnp.zeros((batch, cfg.n_memory, cfg.n_kv_heads,
                                 cfg.head_dim), CACHE_DTYPE)}
    if kind == "dec_self_cross":
        return {
            "self": _attn_cache(batch, max_len, cfg.n_kv_heads, cfg.head_dim),
            "cross": {"xk": jnp.zeros((batch, cfg.n_memory, cfg.n_kv_heads,
                                       cfg.head_dim), CACHE_DTYPE),
                      "xv": jnp.zeros((batch, cfg.n_memory, cfg.n_kv_heads,
                                       cfg.head_dim), CACHE_DTYPE)},
        }
    if kind == "enc_self":
        return None
    raise ValueError(f"unknown block kind {kind}")


# ====================================================================== #
# cached attention primitives (slot-based: ring buffer for SWA)
# ====================================================================== #
def _project_qkv(p, x, cfg: ArchConfig, memory=None):
    xc = x.astype(COMPUTE_DTYPE)
    src = memory.astype(COMPUTE_DTYPE) if memory is not None else xc
    q = jnp.einsum("btd,dhk->bthk", xc, p["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(COMPUTE_DTYPE))
    return q, k, v


def _attn_out(p, out, b, t):
    y = jnp.einsum("bthk,hkd->btd", out.astype(COMPUTE_DTYPE),
                   p["wo"].astype(COMPUTE_DTYPE))
    return y


def attn3_params(key, cfg: ArchConfig) -> dict:
    """Attention params in head-major 3D layout [D, H, dh] so head sharding
    never crosses a reshape (see DESIGN.md sharding plan)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = 1.0 / math.sqrt(d)
    return {
        "wq": jax.random.normal(k1, (d, h, dh), jnp.float32) * s,
        "wk": jax.random.normal(k2, (d, kh, dh), jnp.float32) * s,
        "wv": jax.random.normal(k3, (d, kh, dh), jnp.float32) * s,
        "wo": jax.random.normal(k4, (h, dh, d), jnp.float32)
        * (1.0 / math.sqrt(h * dh)),
    }


def self_attention(
    p: dict, x: jax.Array, cfg: ArchConfig, *,
    causal: bool = True,
    window: int | None = None,
    cache: dict | None = None,
    shard_act: Callable = Identity,
) -> tuple[jax.Array, dict | None]:
    b, t, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    if cfg.seq_shard_attn and not cfg.shard_attn:
        # context parallelism: replicated-head archs (24 heads vs 16-wide
        # model axis) otherwise recompute the quadratic attention on every
        # model-axis device; shard the q-sequence instead
        q = shard_act(q, "attn_q_seq")

    if cache is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
        if cfg.rope_theta is not None:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        from repro.models.layers import attention_any
        out = attention_any(q, k, v, causal=causal, window=window)
        return _attn_out(p, out, b, t), None

    # ---- cached path ----
    cur = cache["len"]
    positions = cur + jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    if cfg.rope_theta is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    clen = cache["k"].shape[1]

    if t > 1:
        # prefill into a (possibly ring) cache: attention over the fresh
        # sequence itself (streaming/local for long T), then store the last
        # `clen` keys/values.  Assumes prefill starts from an empty cache.
        from repro.models.layers import attention_any
        out = attention_any(q, k, v, causal=causal, window=window)
        if t >= clen:
            k_w, v_w = k[:, -clen:], v[:, -clen:]
            pos_w = positions[:, -clen:]
            slots = (cur + t - clen + jnp.arange(clen)) % clen
        else:
            k_w, v_w, pos_w = k, v, positions
            slots = (cur + jnp.arange(t)) % clen
        k_all = cache["k"].at[:, slots].set(k_w.astype(CACHE_DTYPE))
        v_all = cache["v"].at[:, slots].set(v_w.astype(CACHE_DTYPE))
        pos_all = cache["pos"].at[:, slots].set(pos_w.astype(jnp.int32))
        new_cache = {"k": k_all, "v": v_all, "pos": pos_all, "len": cur + t}
        return _attn_out(p, out, b, t), new_cache

    # single-token decode: scatter into the slot, slot-position masking
    slots = (cur + jnp.arange(t)) % clen
    k_all = cache["k"].at[:, slots].set(k.astype(CACHE_DTYPE))
    v_all = cache["v"].at[:, slots].set(v.astype(CACHE_DTYPE))
    pos_all = cache["pos"].at[:, slots].set(positions.astype(jnp.int32))
    new_cache = {"k": k_all, "v": v_all, "pos": pos_all, "len": cur + t}

    h = q.shape[2]
    kk = _expand_kv(k_all, h)
    vv = _expand_kv(v_all, h)
    sc = jnp.einsum("bthd,bshd->bhts", q.astype(COMPUTE_DTYPE),
                    kk.astype(COMPUTE_DTYPE)).astype(jnp.float32)
    sc = sc / math.sqrt(cfg.head_dim)
    qpos = positions                                           # [b, t]
    kpos = pos_all                                             # [b, clen]
    valid = (kpos[:, None, :] >= 0) & (
        kpos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        valid &= kpos[:, None, :] > qpos[:, :, None] - window
    sc = jnp.where(valid[:, None], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhts,bshd->bthd", pr, vv.astype(COMPUTE_DTYPE))
    return _attn_out(p, out, b, t), new_cache


def cross_attention(
    p: dict, x: jax.Array, cfg: ArchConfig, *,
    memory: jax.Array | None,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    b, t, _ = x.shape
    if cache is not None and memory is None:
        # decode: cross-KV precomputed at prefill
        xc = x.astype(COMPUTE_DTYPE)
        q = jnp.einsum("btd,dhk->bthk", xc, p["wq"].astype(COMPUTE_DTYPE))
        k, v = cache["xk"], cache["xv"]
        out = dense_attention(q, k, v, causal=False)
        return _attn_out(p, out, b, t), cache
    q, k, v = _project_qkv(p, x, cfg, memory=memory)
    out = dense_attention(q, k, v, causal=False)
    new_cache = None
    if cache is not None:
        new_cache = {"xk": k.astype(CACHE_DTYPE), "xv": v.astype(CACHE_DTYPE)}
    return _attn_out(p, out, b, t), new_cache


# ====================================================================== #
# blocks
# ====================================================================== #
def block_init(key, cfg: ArchConfig, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    gated = cfg.mlp_act in ("swiglu", "geglu")
    p: dict = {}
    if kind in ("dense", "self", "local_attn", "enc_self", "moe"):
        p["ln_attn"] = norm_params(cfg.norm, d)
        p["attn"] = attn3_params(ks[0], cfg)
        if kind == "moe":
            p["ln_moe"] = norm_params(cfg.norm, d)
            p["moe"] = moe_lib.moe_params(
                ks[1], d, cfg.d_ff, cfg.n_experts, gated)
        else:
            p["ln_mlp"] = norm_params(cfg.norm, d)
            p["mlp"] = mlp_params(ks[1], d, cfg.d_ff, gated)
    elif kind == "mamba":
        p["ln"] = norm_params(cfg.norm, d)
        p["mamba"] = ssm_lib.mamba_params(
            ks[0], d, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv)
    elif kind == "rglru":
        p["ln_rec"] = norm_params(cfg.norm, d)
        p["rglru"] = ssm_lib.rglru_params(ks[0], d, cfg.d_inner, cfg.ssm_conv)
        p["ln_mlp"] = norm_params(cfg.norm, d)
        p["mlp"] = mlp_params(ks[1], d, cfg.d_ff, gated)
    elif kind == "cross":
        p["ln_x"] = norm_params(cfg.norm, d)
        p["xattn"] = attn3_params(ks[0], cfg)
        p["xgate"] = jnp.zeros((), jnp.float32)   # llama-vision gated cross
        p["ln_mlp"] = norm_params(cfg.norm, d)
        p["mlp"] = mlp_params(ks[1], d, cfg.d_ff, gated)
    elif kind == "dec_self_cross":
        p["ln_attn"] = norm_params(cfg.norm, d)
        p["attn"] = attn3_params(ks[0], cfg)
        p["ln_x"] = norm_params(cfg.norm, d)
        p["xattn"] = attn3_params(ks[1], cfg)
        p["ln_mlp"] = norm_params(cfg.norm, d)
        p["mlp"] = mlp_params(ks[2], d, cfg.d_ff, gated)
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def block_apply(
    p: dict, x: jax.Array, cfg: ArchConfig, kind: str, *,
    cache: Any = None,
    memory: jax.Array | None = None,
    shard_act: Callable = Identity,
) -> tuple[jax.Array, Any, jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "self", "local_attn", "moe", "enc_self"):
        window = cfg.window if kind != "enc_self" else None
        causal = kind != "enc_self"
        h, new_cache = self_attention(
            p["attn"], apply_norm(cfg.norm, p["ln_attn"], x), cfg,
            causal=causal, window=window, cache=cache, shard_act=shard_act)
        x = shard_act(x + h, "resid")
        if kind == "moe":
            if cfg.moe_row_dispatch:
                h, aux = moe_lib.moe_apply_row(
                    p["moe"], apply_norm(cfg.norm, p["ln_moe"], x),
                    top_k=cfg.moe_top_k, act=cfg.mlp_act,
                    shard_act=shard_act)
            else:
                h, aux = moe_lib.moe_apply(
                    p["moe"], apply_norm(cfg.norm, p["ln_moe"], x),
                    top_k=cfg.moe_top_k, act=cfg.mlp_act)
        else:
            h = mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln_mlp"], x),
                          cfg.mlp_act)
        x = shard_act(x + h, "resid")
        return x, new_cache, aux
    if kind == "mamba":
        h, new_cache = ssm_lib.mamba_apply(
            p["mamba"], apply_norm(cfg.norm, p["ln"], x),
            d_state=cfg.ssm_state, dt_rank=cfg.dt_rank, cache=cache,
            chunk=cfg.ssm_chunk, fused=cfg.ssm_fused_coeffs)
        return shard_act(x + h, "resid"), new_cache, aux
    if kind == "rglru":
        h, new_cache = ssm_lib.rglru_apply(
            p["rglru"], apply_norm(cfg.norm, p["ln_rec"], x), cache=cache)
        x = shard_act(x + h, "resid")
        h = mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln_mlp"], x),
                      cfg.mlp_act)
        return shard_act(x + h, "resid"), new_cache, aux
    if kind == "cross":
        h, new_cache = cross_attention(
            p["xattn"], apply_norm(cfg.norm, p["ln_x"], x), cfg,
            memory=memory, cache=cache)
        x = shard_act(x + jnp.tanh(p["xgate"]).astype(h.dtype) * h, "resid")
        h = mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln_mlp"], x),
                      cfg.mlp_act)
        return shard_act(x + h, "resid"), new_cache, aux
    if kind == "dec_self_cross":
        self_cache = cache["self"] if cache is not None else None
        cross_cache = cache["cross"] if cache is not None else None
        h, new_self = self_attention(
            p["attn"], apply_norm(cfg.norm, p["ln_attn"], x), cfg,
            causal=True, window=None, cache=self_cache, shard_act=shard_act)
        x = shard_act(x + h, "resid")
        h, new_cross = cross_attention(
            p["xattn"], apply_norm(cfg.norm, p["ln_x"], x), cfg,
            memory=memory, cache=cross_cache)
        x = shard_act(x + h, "resid")
        h = mlp_apply(p["mlp"], apply_norm(cfg.norm, p["ln_mlp"], x),
                      cfg.mlp_act)
        x = shard_act(x + h, "resid")
        new_cache = None
        if cache is not None:
            new_cache = {"self": new_self, "cross": new_cross}
        return x, new_cache, aux
    raise ValueError(f"unknown block kind {kind}")


# ====================================================================== #
# stacks (scan over pattern groups + remainder layers)
# ====================================================================== #
def group_init(key, cfg: ArchConfig, pattern: tuple[str, ...]) -> dict:
    ks = jax.random.split(key, len(pattern))
    return {f"b{i}_{kind}": block_init(ks[i], cfg, kind)
            for i, kind in enumerate(pattern)}


def group_apply(p, x, cfg, pattern, *, caches=None, memory=None,
                shard_act=Identity):
    new_caches = {}
    aux_tot = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(pattern):
        key = f"b{i}_{kind}"
        c = caches[key] if caches is not None else None
        x, nc, aux = block_apply(
            p[key], x, cfg, kind, cache=c, memory=memory,
            shard_act=shard_act)
        aux_tot = aux_tot + aux
        if caches is not None:
            new_caches[key] = nc
    return x, (new_caches if caches is not None else None), aux_tot


def stack_init(key, cfg: ArchConfig, pattern: tuple[str, ...],
               n_layers: int) -> dict:
    full = n_layers // len(pattern)
    rem = n_layers % len(pattern)
    kf, kr = jax.random.split(key)
    out: dict = {}
    if full:
        out["groups"] = jax.vmap(
            lambda k: group_init(k, cfg, pattern))(jax.random.split(kf, full))
    if rem:
        out["rem"] = group_init(kr, cfg, pattern[:rem])
    return out


def stack_cache(cfg: ArchConfig, pattern, n_layers, batch, max_len):
    full = n_layers // len(pattern)
    rem = n_layers % len(pattern)
    out: dict = {}

    def group_cache(pat):
        return {f"b{i}_{kind}": block_cache(cfg, kind, batch, max_len)
                for i, kind in enumerate(pat)}

    if full:
        one = group_cache(pattern)
        out["groups"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (full,) + a.shape).copy(), one)
    if rem:
        out["rem"] = group_cache(pattern[:rem])
    return out


def stack_apply(
    params: dict, x: jax.Array, cfg: ArchConfig, pattern, n_layers, *,
    caches: dict | None = None,
    memory: jax.Array | None = None,
    shard_act: Callable = Identity,
):
    aux_tot = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        h, aux = carry
        if caches is not None:
            gp, gc = xs
        else:
            gp, gc = xs, None
        h, nc, a = group_apply(gp, h, cfg, pattern, caches=gc,
                               memory=memory, shard_act=shard_act)
        return (h, aux + a), nc

    new_caches: dict = {}
    if "groups" in params:
        if cfg.remat and cfg.remat_policy == "dots":
            # save matmul outputs across the remat boundary: trades group
            # memory for not recomputing the heavy dots in backward
            wrapped = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_saveable)
        elif cfg.remat:
            wrapped = jax.checkpoint(body)
        else:
            wrapped = body
        xs = (params["groups"], caches["groups"]) if caches is not None \
            else params["groups"]
        if cfg.scan_layers:
            (x, aux_tot), ncs = jax.lax.scan(wrapped, (x, aux_tot), xs)
        else:
            full = n_layers // len(pattern)
            ncs_list = []
            for i in range(full):
                gxs = jax.tree.map(lambda a: a[i], xs)
                (x, aux_tot), nc = wrapped((x, aux_tot), gxs)
                ncs_list.append(nc)
            ncs = jax.tree.map(lambda *a: jnp.stack(a), *ncs_list) \
                if ncs_list and ncs_list[0] is not None else None
        if caches is not None:
            new_caches["groups"] = ncs
    if "rem" in params:
        rem = n_layers % len(pattern)
        rc = caches["rem"] if caches is not None else None
        x, nrc, a = group_apply(params["rem"], x, cfg, pattern[:rem],
                                caches=rc, memory=memory, shard_act=shard_act)
        aux_tot = aux_tot + a
        if caches is not None:
            new_caches["rem"] = nrc
    return x, (new_caches if caches is not None else None), aux_tot


# ====================================================================== #
# full models
# ====================================================================== #
def lm_init(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 6)
    p: dict = {
        "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
        "stack": stack_init(ks[1], cfg, cfg.pattern, cfg.n_layers),
        "ln_final": norm_params(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(
            ks[2], (cfg.d_model, cfg.vocab), jnp.float32) \
            / math.sqrt(cfg.d_model)
    if cfg.encoder_layers:
        p["encoder"] = {
            "pos": jax.random.normal(
                ks[3], (cfg.n_memory, cfg.d_model), jnp.float32) * 0.02,
            "stack": stack_init(ks[4], cfg, ("enc_self",),
                                cfg.encoder_layers),
            "ln_final": norm_params(cfg.norm, cfg.d_model),
        }
        p["dec_pos"] = jax.random.normal(
            ks[5], (cfg.max_decode_len, cfg.d_model), jnp.float32) * 0.02
    return p


def encode_memory(params, cfg: ArchConfig, frames: jax.Array,
                  shard_act=Identity) -> jax.Array:
    """Audio encoder (stub frontend supplies ``frames`` [B, n_mem, D])."""
    enc = params["encoder"]
    x = frames + enc["pos"][None]
    x = x.astype(COMPUTE_DTYPE)
    x, _, _ = stack_apply(enc["stack"], x, cfg, ("enc_self",),
                          cfg.encoder_layers, shard_act=shard_act)
    return apply_norm(cfg.norm, enc["ln_final"], x)


def lm_apply(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,                 # [B, T] int32
    *,
    caches: dict | None = None,
    memory: jax.Array | None = None,   # [B, n_mem, D] stub embeddings
    pos_offset: jax.Array | int = 0,   # decode: absolute position of t=0
    shard_act: Callable = Identity,
) -> tuple[jax.Array, dict | None, jax.Array]:
    """Returns (logits [B, T, V], new_caches, aux_loss)."""
    b, t = tokens.shape
    if cfg.cast_params_bf16:
        # one-time bf16 copy of the big weights per step: the scanned layer
        # bodies then read 2-byte weights instead of re-reading fp32 and
        # casting per layer (fp32 masters stay in the optimizer)
        def _cast(path, leaf):
            name = str(getattr(path[-1], "key", "")) if path else ""
            if (leaf.dtype == jnp.float32 and leaf.ndim >= 2
                    and name not in ("a_log", "conv_w")):
                return leaf.astype(jnp.bfloat16)
            return leaf
        params = jax.tree_util.tree_map_with_path(_cast, params)
    x = params["embed"][tokens]
    if cfg.emb_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.encoder_layers:
        dp = jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos_offset, t,
                                          axis=0)
        x = x + dp[None]
    x = shard_act(x.astype(COMPUTE_DTYPE), "resid")

    x, new_caches, aux = stack_apply(
        params["stack"], x, cfg, cfg.pattern, cfg.n_layers,
        caches=caches, memory=memory, shard_act=shard_act)

    x = apply_norm(cfg.norm, params["ln_final"], x)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum(
        "btd,dv->btv", x.astype(COMPUTE_DTYPE), head.astype(COMPUTE_DTYPE))
    logits = shard_act(logits.astype(jnp.float32), "logits")
    return logits, new_caches, aux


def lm_loss(logits: jax.Array, labels: jax.Array,
            z_loss: float = 1e-4) -> tuple[jax.Array, dict]:
    """Next-token CE (labels already shifted; -1 = masked) + z-loss."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, labels_safe[..., None], axis=-1)[..., 0] - logz
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / denom
    zl = z_loss * ((logz ** 2) * mask).sum() / denom
    return ce + zl, {"ce": ce, "z_loss": zl,
                     "tokens": mask.sum()}
