"""Mixture-of-Experts block: top-k router + capacity-based scatter dispatch.

Dispatch is O(T * k * d) (scatter/gather, *not* the quadratic one-hot-einsum
GShard dispatch): tokens are scattered into a per-expert slot buffer
[E, C, d] (C = capacity), experts run as one batched einsum, and results are
gathered back with router weights.  Overflow tokens beyond capacity are
dropped (standard capacity-factor semantics; the residual path carries them).

Sharding: experts stay where the tokens are (no all-to-all); tensor
parallelism shards the expert hidden dimension (expert counts 40/8 do not
divide the 16-wide model axis -- see DESIGN.md).  The token-exchange (EP)
variant is a recorded hillclimb lever.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, dense_init


def moe_params(key, d_model: int, d_ff: int, n_experts: int,
               gated: bool = True) -> dict:
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_ff = 1.0 / math.sqrt(d_ff)
    p = {
        "router": dense_init(ks[0], d_model, n_experts),
        "w_up": jax.random.normal(
            ks[1], (n_experts, d_model, d_ff), jnp.float32) * s_in,
        "w_down": jax.random.normal(
            ks[2], (n_experts, d_ff, d_model), jnp.float32) * s_ff,
    }
    if gated:
        p["w_gate"] = jax.random.normal(
            ks[3], (n_experts, d_model, d_ff), jnp.float32) * s_in
    return p


def moe_apply(
    p: dict,
    x: jax.Array,                 # [B, T, D]
    *,
    top_k: int,
    act: str = "swiglu",
    capacity_factor: float = 1.25,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B, T, D], aux load-balancing loss scalar)."""
    b, t, d = x.shape
    n_exp = p["router"].shape[1]
    xt = x.reshape(b * t, d)
    tokens = b * t

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # [T, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=0)
    one_hot_top1 = jax.nn.one_hot(gate_idx[:, 0], n_exp)
    ce = one_hot_top1.mean(axis=0)
    aux = n_exp * jnp.sum(me * ce)

    # capacity floor keeps small token counts (decode steps, CPU tests)
    # fully dropless -- worst case all tokens route to one expert, needing
    # capacity == tokens; at production token counts the capacity-factor
    # term dominates and this floor is inert
    capacity = max(int(capacity_factor * tokens * top_k / n_exp),
                   min(tokens, 64), 1)

    # position of each (token, choice) within its expert queue
    flat_exp = gate_idx.reshape(-1)                         # [T*k]
    onehot = jax.nn.one_hot(flat_exp, n_exp, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                    # arrival order
    pos_in_expert = jnp.take_along_axis(
        pos, flat_exp[:, None], axis=1)[:, 0]               # [T*k]
    keep = pos_in_expert < capacity
    slot = flat_exp * capacity + pos_in_expert              # [T*k]
    slot = jnp.where(keep, slot, n_exp * capacity)          # drop -> OOB

    # scatter tokens into expert slots [E*C, D]
    xk = jnp.repeat(xt, top_k, axis=0)                      # token order
    buf = jnp.zeros((n_exp * capacity + 1, d), xt.dtype)
    buf = buf.at[slot].set(xk, mode="drop")
    buf = buf[:-1].reshape(n_exp, capacity, d).astype(COMPUTE_DTYPE)

    # batched expert FFN
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(COMPUTE_DTYPE))
    if "w_gate" in p:
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(COMPUTE_DTYPE))
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * up
    else:
        h = jax.nn.gelu(up)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(COMPUTE_DTYPE))

    # gather back with router weights
    out_flat = out_e.reshape(n_exp * capacity, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((1, d), out_flat.dtype)], axis=0)
    gathered = out_flat[slot]                               # [T*k, D]
    w = (gate_vals.reshape(-1) * keep).astype(gathered.dtype)
    y = (gathered * w[:, None]).reshape(tokens, top_k, d).sum(axis=1)
    return y.reshape(b, t, d).astype(x.dtype), aux


def moe_apply_row(
    p: dict,
    x: jax.Array,                 # [B, T, D]
    *,
    top_k: int,
    act: str = "swiglu",
    capacity_factor: float = 1.25,
    shard_act=lambda x, name: x,
) -> tuple[jax.Array, jax.Array]:
    """Per-batch-row-local dispatch (perf variant; EXPERIMENTS Sec. Perf).

    ``moe_apply`` computes arrival-order positions with a cumsum over the
    *globally flattened* token axis; under GSPMD that axis is sharded over
    the data mesh dimensions, so the cumsum (and the following scatter)
    serializes across shards through enormous collectives.  Keeping the
    batch dimension separate and running dispatch per row makes every step
    shard-local: capacity becomes per-row (cf * T * k / E), which is the
    same per-shard-capacity semantics every production MoE system uses.
    """
    b, t, d = x.shape
    n_exp = p["router"].shape[1]

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                 # [B, T, E]
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)       # [B, T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=(0, 1))
    ce = jax.nn.one_hot(gate_idx[..., 0], n_exp).mean(axis=(0, 1))
    aux = n_exp * jnp.sum(me * ce)

    capacity = max(int(capacity_factor * t * top_k / n_exp),
                   min(t, 64), 1)

    flat_exp = gate_idx.reshape(b, t * top_k)               # [B, T*k]
    onehot = jax.nn.one_hot(flat_exp, n_exp, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1                    # per-row order
    pos_in_expert = jnp.take_along_axis(
        pos, flat_exp[..., None], axis=2)[..., 0]           # [B, T*k]
    keep = pos_in_expert < capacity
    slot = flat_exp * capacity + pos_in_expert
    slot = jnp.where(keep, slot, n_exp * capacity)

    # gather-based dispatch: scatter only the int32 assignment ids into the
    # slot table, then gather token rows -- avoids materializing the
    # [B, T*k, D] repeat (12.9 GB/layer for granite train_4k; Perf A4)
    n_assign = t * top_k
    def ids_row(slots_r):
        ids = jnp.full((n_exp * capacity + 1,), n_assign, jnp.int32)
        return ids.at[slots_r].set(jnp.arange(n_assign, dtype=jnp.int32),
                                   mode="drop")[:-1]
    slot_assign = jax.vmap(ids_row)(slot)                   # [B, E*C]
    token_of_slot = jnp.minimum(slot_assign // top_k, t - 1)
    slot_valid = slot_assign < n_assign
    buf = jnp.take_along_axis(
        x.astype(COMPUTE_DTYPE), token_of_slot[..., None], axis=1)
    buf = jnp.where(slot_valid[..., None], buf, 0)
    buf = buf.reshape(b, n_exp, capacity, d)
    # pin the expert buffer batch-sharded: without this the partitioner
    # replicates it across the data axes (observed: per-layer f32
    # [B_glob, E*C, D] all-gathers + [E,D,B,C]-sized wgrad all-reduces)
    buf = shard_act(buf, "moe_buf")

    up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(COMPUTE_DTYPE))
    if "w_gate" in p:
        g = jnp.einsum("becd,edf->becf", buf,
                       p["w_gate"].astype(COMPUTE_DTYPE))
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * up
    else:
        h = jax.nn.gelu(up)
    out_e = jnp.einsum("becf,efd->becd", h,
                       p["w_down"].astype(COMPUTE_DTYPE))
    out_e = shard_act(out_e, "moe_buf")

    out_flat = out_e.reshape(b, n_exp * capacity, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((b, 1, d), out_flat.dtype)], axis=1)
    gathered = jnp.take_along_axis(out_flat, slot[..., None], axis=1)
    w = (gate_vals.reshape(b, t * top_k) * keep).astype(gathered.dtype)
    y = (gathered * w[..., None]).reshape(b, t, top_k, d).sum(axis=2)
    return y.astype(x.dtype), aux
