"""State-space blocks: Mamba-1 (selective scan) and RG-LRU (RecurrentGemma).

Both are linear recurrences h_t = a_t * h_{t-1} + b_t evaluated with a
chunked associative scan: the outer ``lax.scan`` carries only chunk-boundary
states (memory O(T/chunk)), the inner ``associative_scan`` is remat-ed so the
backward pass recomputes chunk internals -- this is what keeps the 4k-train
and 500k-decode cells within budget.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import COMPUTE_DTYPE, dense_init


# ---------------------------------------------------------------------- #
# chunked linear scan: h_t = a_t * h_{t-1} + b_t
# ---------------------------------------------------------------------- #
def _assoc(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                chunk: int = 256) -> jax.Array:
    """a, b: [T, ...] coefficients; h0: [...] initial state.
    Returns h: [T, ...] (all states)."""
    t = a.shape[0]
    if t <= 4:
        # decode fast path: unrolled recurrence, no chunk padding
        hs = []
        h = h0
        for i in range(t):
            h = a[i] * h + b[i]
            hs.append(h)
        return jnp.stack(hs)
    pad = (-t) % chunk
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad,) + a.shape[1:], a.dtype)])
        b = jnp.concatenate([b, jnp.zeros((pad,) + b.shape[1:], b.dtype)])
    nc = a.shape[0] // chunk
    ac = a.reshape((nc, chunk) + a.shape[1:])
    bc = b.reshape((nc, chunk) + b.shape[1:])

    @jax.checkpoint
    def body(h, xs):
        a_i, b_i = xs
        # fold carry into the first element, then scan the chunk
        b0 = b_i.at[0].add(a_i[0] * h)
        aa, bb = jax.lax.associative_scan(_assoc, (a_i, b0), axis=0)
        return bb[-1], bb

    h_last, hs = jax.lax.scan(body, h0, (ac, bc))
    hs = hs.reshape((nc * chunk,) + h0.shape)
    return hs[:t]


# ---------------------------------------------------------------------- #
# Mamba-1
# ---------------------------------------------------------------------- #
def mamba_params(key, d_model: int, d_inner: int, d_state: int,
                 dt_rank: int, conv_width: int = 4) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner),
        "conv_w": jax.random.normal(
            ks[1], (conv_width, d_inner), jnp.float32) / math.sqrt(conv_width),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus ~ 0.01
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, d_state + 1, dtype=jnp.float32)[None], (d_inner, 1))),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d_model),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv1d.  x: [B, T, C]; w: [K, C].
    Returns (y [B, T, C], new_state [B, K-1, C])."""
    kw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xx = jnp.concatenate([state, x], axis=1)
    y = sum(xx[:, i: i + x.shape[1]] * w[i][None, None] for i in range(kw))
    new_state = xx[:, -(kw - 1):] if kw > 1 else state
    return y + b[None, None], new_state


def selective_scan_fused(xi, dt, bmat, cmat, a, h0, chunk: int):
    """Chunk-fused selective scan (perf variant; EXPERIMENTS Sec. Perf).

    The baseline materializes the full [B, T, I, S] coefficient tensors
    (da, dt*B*x) in HBM before scanning.  Here they are computed *inside*
    the remat-ed chunk body, so only [B, chunk, I, S] ever materializes --
    cutting the dominant HBM term of the mamba prefill/train cells.

    xi, dt: [B, T, I]; bmat, cmat: [B, T, S]; a: [I, S]; h0: [B, I, S].
    Returns (y [B, T, I], h_last [B, I, S]).
    """
    b, t, i = xi.shape
    s = a.shape[1]
    pad = (-t) % chunk
    if pad:
        z = lambda x_, w: jnp.pad(x_, ((0, 0), (0, w), (0, 0)))
        xi, dt = z(xi, pad), z(dt, pad)       # dt=0 -> da=1, dbx=0: identity
        bmat, cmat = z(bmat, pad), z(cmat, pad)
    nc = xi.shape[1] // chunk

    def chunked(x_):
        return x_.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def body(h, xs):
        xi_c, dt_c, b_c, c_c = xs              # [B, chunk, ...]
        da = jnp.exp(dt_c[..., None] * a[None, None])      # [B,c,I,S]
        dbx = (dt_c * xi_c)[..., None] * b_c[:, :, None, :]
        dbx = dbx.at[:, 0].add(da[:, 0] * h)
        _aa, hh = jax.lax.associative_scan(_assoc, (da, dbx), axis=1)
        y_c = jnp.einsum("bcis,bcs->bci", hh, c_c)
        return hh[:, -1], y_c

    h_last, ys = jax.lax.scan(
        body, h0, (chunked(xi), chunked(dt), chunked(bmat), chunked(cmat)))
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * chunk, i)[:, :t]
    return y, h_last


def mamba_apply(
    p: dict,
    x: jax.Array,                  # [B, T, D]
    *,
    d_state: int,
    dt_rank: int,
    cache: dict | None = None,     # {"conv": [B,K-1,I], "ssm": [B,I,S]}
    chunk: int = 256,
    fused: bool = False,
) -> tuple[jax.Array, dict | None]:
    b, t, d = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    xz = xc @ p["in_proj"].astype(COMPUTE_DTYPE)
    xi, z = jnp.split(xz, 2, axis=-1)            # [B, T, I]
    d_inner = xi.shape[-1]

    conv_state = cache["conv"] if cache else None
    xi, new_conv = _causal_conv(
        xi.astype(jnp.float32), p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    proj = xi.astype(COMPUTE_DTYPE) @ p["x_proj"].astype(COMPUTE_DTYPE)
    dt_in, bmat, cmat = jnp.split(
        proj.astype(jnp.float32), [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(
        dt_in @ p["dt_proj"] + p["dt_bias"][None, None])     # [B, T, I]
    a = -jnp.exp(p["a_log"])                                 # [I, S]

    h0 = cache["ssm"] if cache else jnp.zeros((b, d_inner, d_state),
                                              jnp.float32)
    if fused and t > 4:
        y, h_last = selective_scan_fused(xi, dt, bmat, cmat, a, h0, chunk)
    else:
        da = jnp.exp(dt[..., None] * a[None, None])          # [B, T, I, S]
        dbx = (dt * xi)[..., None] * bmat[:, :, None, :]      # [B, T, I, S]
        # linear_scan is time-major; vmap over the batch axis
        hs = jax.vmap(lambda aa, bb, h: linear_scan(aa, bb, h, chunk=chunk))(
            da, dbx, h0)
        y = jnp.einsum("btis,bts->bti", hs, cmat)            # C_t . h_t
        h_last = hs[:, -1]
    y = y + xi * p["d_skip"][None, None]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(COMPUTE_DTYPE) @ p["out_proj"].astype(COMPUTE_DTYPE)

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": h_last.astype(cache["ssm"].dtype)}
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------- #
# RG-LRU (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------- #
def rglru_params(key, d_model: int, d_inner: int, conv_width: int = 4) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner),
        "conv_w": jax.random.normal(
            ks[1], (conv_width, d_inner), jnp.float32) / math.sqrt(conv_width),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "w_a": dense_init(ks[2], d_inner, d_inner),   # recurrence gate
        "w_i": dense_init(ks[3], d_inner, d_inner),   # input gate
        "lambda_p": jnp.full((d_inner,), 2.0, jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, d_model),
    }


RGLRU_C = 8.0


def rglru_apply(
    p: dict,
    x: jax.Array,                  # [B, T, D]
    *,
    cache: dict | None = None,     # {"conv": [B,K-1,I], "h": [B,I]}
    chunk: int = 256,
) -> tuple[jax.Array, dict | None]:
    b, t, d = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    xz = xc @ p["in_proj"].astype(COMPUTE_DTYPE)
    xi, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache else None
    xi, new_conv = _causal_conv(
        xi.astype(jnp.float32), p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(xi.astype(COMPUTE_DTYPE) @ p["w_a"].astype(COMPUTE_DTYPE))
    i_g = jax.nn.sigmoid(xi.astype(COMPUTE_DTYPE) @ p["w_i"].astype(COMPUTE_DTYPE))
    log_a = -RGLRU_C * jax.nn.softplus(p["lambda_p"])[None, None] * \
        r.astype(jnp.float32)
    a = jnp.exp(log_a)                                        # [B, T, I]
    gated_x = xi * i_g.astype(jnp.float32)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated_x

    h0 = cache["h"] if cache else jnp.zeros((b, xi.shape[-1]), jnp.float32)
    hs = jax.vmap(lambda aa, bb, h: linear_scan(aa, bb, h, chunk=chunk))(
        a, bterm, h0)

    y = hs * jax.nn.gelu(z.astype(jnp.float32))
    out = y.astype(COMPUTE_DTYPE) @ p["out_proj"].astype(COMPUTE_DTYPE)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "h": hs[:, -1].astype(cache["h"].dtype)}
    return out.astype(x.dtype), new_cache
