"""GSPMD sharding rules for params, activations, batches and caches.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
  * batch            -> ("pod","data")   (data parallel)
  * TP over "model"  -> attention heads (3D weights [D, H, dh] so head
    sharding never crosses a reshape), FFN hidden, vocab, expert-internal
    hidden, SSM inner channels
  * FSDP over "data" -> param dim 0 of big archs (cfg.fsdp); optimizer state
    inherits (ZeRO-3-like), GSPMD inserts the per-layer all-gathers
  * big KV caches    -> sequence axis over "model" (GQA kv-head counts 1/4/8
    don't divide 16); softmax over the sharded axis lowers to small
    all-reduces (flash-decoding-like)

Every rule passes through a divisibility guard: a dim that an axis does not
divide is replicated instead (e.g. whisper's 12 heads, batch=1 long-decode).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

BIG_CACHE = 16384          # seq >= this -> shard cache seq over "model"


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _fit(spec: tuple, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Left-pad with None to ndim and drop axes that don't divide."""
    spec = (None,) * (len(shape) - len(spec)) + tuple(spec)
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_rule(cfg: ArchConfig, name: str, shape: tuple[int, ...],
               mesh: Mesh) -> P:
    fsdp = "data" if (cfg.fsdp and "data" in mesh.axis_names) else None
    tp = "model" if "model" in mesh.axis_names else None
    attn_tp = tp if cfg.shard_attn else None
    rules: dict[str, tuple] = {
        "wq": (fsdp, attn_tp, None),
        "wk": (fsdp, attn_tp, None),
        "wv": (fsdp, attn_tp, None),
        "wo": (attn_tp, None, fsdp),
        "w_up": (fsdp, tp),
        "w_gate": (fsdp, tp),
        "w_down": (tp, fsdp),
        "in_proj": (fsdp, tp),
        "out_proj": (tp, fsdp),
        "x_proj": (tp, fsdp),
        "dt_proj": (fsdp, tp),
        "w_a": (None, tp),
        "w_i": (None, tp),
        "router": (fsdp, None),
        "embed": (tp, fsdp),
        "lm_head": (fsdp, tp),
        "conv_w": (None, tp),
        "conv_b": (tp,),
        "dt_bias": (tp,),
        "d_skip": (tp,),
        "lambda_p": (tp,),
        "a_log": (tp, None),
    }
    spec = rules.get(name, ())
    return _fit(spec, shape, mesh)


def cache_rule(cfg: ArchConfig, name: str, shape: tuple[int, ...],
               mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None
    if name in ("k", "v"):           # [B, C, KH, dh]
        seq_ax = tp if shape[-3] >= BIG_CACHE else None
        return _fit((dp, seq_ax, None, None), shape, mesh)
    if name == "pos":                # [B, C]
        seq_ax = tp if shape[-1] >= BIG_CACHE else None
        return _fit((dp, seq_ax), shape, mesh)
    if name in ("len", "step"):
        return P()
    if name == "conv":               # [B, K-1, I]
        return _fit((dp, None, tp), shape, mesh)
    if name == "ssm":                # [B, I, S]
        return _fit((dp, tp, None), shape, mesh)
    if name == "h":                  # [B, I]
        return _fit((dp, tp), shape, mesh)
    if name in ("xk", "xv"):         # [B, n_mem, KH, dh]
        return _fit((dp, None, None, None), shape, mesh)
    return _fit((dp,), shape, mesh)


def batch_rule(name: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    if name in ("tokens", "labels"):
        return _fit((dp, None), shape, mesh)
    if name == "memory":             # stub frontend embeddings [B, n, D]
        return _fit((dp, None, None), shape, mesh)
    return _fit((dp,), shape, mesh)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def tree_shardings(tree: Any, mesh: Mesh, rule) -> Any:
    """Map a pytree of arrays/ShapeDtypeStructs to NamedShardings."""
    def one(path, leaf):
        name = _leaf_name(path)
        return NamedSharding(mesh, rule(name, tuple(leaf.shape)))
    return jax.tree_util.tree_map_with_path(one, tree)


def param_shardings(cfg: ArchConfig, params: Any, mesh: Mesh) -> Any:
    return tree_shardings(
        params, mesh, lambda n, s: param_rule(cfg, n, s, mesh))


def cache_shardings(cfg: ArchConfig, caches: Any, mesh: Mesh) -> Any:
    return tree_shardings(
        caches, mesh, lambda n, s: cache_rule(cfg, n, s, mesh))


def batch_shardings(batch: Any, mesh: Mesh) -> Any:
    return tree_shardings(batch, mesh, lambda n, s: batch_rule(n, s, mesh))


def make_shard_act(mesh: Mesh, sp_seq: bool = False):
    """Activation sharding-constraint hook.  ``sp_seq`` enables sequence
    parallelism for residuals (hillclimb lever)."""
    dp = dp_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None

    def shard_act(x, name):
        if mesh.empty or x.ndim < 2:
            return x
        if name == "resid":
            seq_ax = tp if sp_seq else None
            spec = _fit((dp, seq_ax, None), x.shape, mesh)
        elif name == "moe_buf":          # [B, E, C, D]: batch-local experts
            spec = _fit((dp, None, None, None), x.shape, mesh)
        elif name == "attn_q_seq":       # [B, T, H, dh]: context parallel
            spec = _fit((dp, tp, None, None), x.shape, mesh)
        elif name == "logits":
            spec = _fit((dp, None, tp), x.shape, mesh)
        else:
            spec = _fit((dp,), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard_act
