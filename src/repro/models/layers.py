"""Shared neural blocks: norms, RoPE, GQA attention (dense / streaming /
local-window / decode), gated MLPs, embeddings.

Conventions:
  * params are plain dict pytrees of jnp arrays (fp32 storage);
  * activations compute in bf16 with fp32 softmax/norm statistics;
  * tensor layouts: activations [B, T, D]; attention heads [B, T, H, dh];
    KV caches [B, S, KH, dh].
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16
NEG_INF = -1e30


# ---------------------------------------------------------------------- #
# init helpers
# ---------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s)


def embed_init(key, vocab: int, d: int):
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


# ---------------------------------------------------------------------- #
# norms
# ---------------------------------------------------------------------- #
def rmsnorm_params(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


def layernorm_params(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return y.astype(x.dtype)


def apply_norm(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def norm_params(kind: str, d: int) -> dict:
    return rmsnorm_params(d) if kind == "rmsnorm" else layernorm_params(d)


# ---------------------------------------------------------------------- #
# rotary position embeddings
# ---------------------------------------------------------------------- #
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, dh]; positions: [B, T] (absolute)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # [B, T, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- #
# attention cores
# ---------------------------------------------------------------------- #
def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """[B, S, KH, dh] -> [B, S, H, dh] by repeating each kv head."""
    b, s, kh, dh = k.shape
    rep = n_heads // kh
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


def dense_attention(
    q: jax.Array,            # [B, T, H, dh]
    k: jax.Array,            # [B, S, KH, dh]
    v: jax.Array,
    *,
    causal: bool,
    window: int | None = None,
    q_offset: int = 0,       # absolute position of q[0] (decode: S-1)
) -> jax.Array:
    """Materialized-scores attention; use for T*S small enough (<= ~4k x 4k
    per head shard) and for single-token decode."""
    h = q.shape[2]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    dh = q.shape[-1]
    scores = jnp.einsum(
        "bthd,bshd->bhts", q.astype(COMPUTE_DTYPE), k.astype(COMPUTE_DTYPE)
    ).astype(jnp.float32) / math.sqrt(dh)
    t, s = scores.shape[-2], scores.shape[-1]
    qpos = jnp.arange(t) + q_offset
    kpos = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhts,bshd->bthd", p, v.astype(COMPUTE_DTYPE))
    return out


def streaming_attention(
    q: jax.Array,            # [B, T, H, dh]
    k: jax.Array,            # [B, S, KH, dh]
    v: jax.Array,
    *,
    causal: bool,
    kv_block: int = 1024,
    q_offset: int = 0,
) -> jax.Array:
    """Flash-style streaming softmax over KV blocks (pure jnp, lax.scan).

    Keeps the [T, S] score matrix off-HBM: memory is O(T * kv_block) per
    head shard, which is what makes the 32k-prefill cells compilable.  This
    is also the jnp oracle shape for kernels/flash_attention.py.
    """
    b, t, h, dh = q.shape
    s = k.shape[1]
    kh = k.shape[2]
    pad = (-s) % kv_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = k.shape[1] // kv_block
    kb = k.reshape(b, nblk, kv_block, kh, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, kv_block, kh, dh).transpose(1, 0, 2, 3, 4)

    qf = q.astype(COMPUTE_DTYPE)
    qpos = jnp.arange(t) + q_offset
    scale = 1.0 / math.sqrt(dh)

    def step(carry, xs):
        m, l, acc = carry                      # [B,H,T], [B,H,T], [B,T,H,dh]
        kblk, vblk, blk_idx = xs               # [B,blk,KH,dh] x2, scalar
        kblk = _expand_kv(kblk, h)
        vblk = _expand_kv(vblk, h)
        sc = jnp.einsum("bthd,bshd->bhts", qf, kblk.astype(COMPUTE_DTYPE))
        sc = sc.astype(jnp.float32) * scale
        kpos = blk_idx * kv_block + jnp.arange(kv_block)
        mask = kpos[None, :] < s               # padding
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum(
            "bhts,bshd->bthd", p.astype(COMPUTE_DTYPE),
            vblk.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, t), -jnp.inf, jnp.float32),
        jnp.zeros((b, h, t), jnp.float32),
        jnp.zeros((b, t, h, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kb, vb, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(COMPUTE_DTYPE)


def local_chunk_attention(
    q: jax.Array,            # [B, T, H, dh]
    k: jax.Array,            # [B, T, KH, dh]
    v: jax.Array,
    *,
    window: int,
) -> jax.Array:
    """Causal sliding-window attention in O(T * window): chunk the sequence
    into window-sized blocks, each attending to itself + the previous block
    (banded attention; exact for window <= chunk)."""
    b, t, h, dh = q.shape
    kh = k.shape[2]
    w = window
    pad = (-t) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tp = q.shape[1]
    nc = tp // w
    qc = q.reshape(b, nc, w, h, dh)
    kc = k.reshape(b, nc, w, kh, dh)
    vc = v.reshape(b, nc, w, kh, dh)
    # previous chunk (zeros before the first)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kk = jnp.concatenate([k_prev, kc], axis=2)       # [B, nc, 2w, KH, dh]
    vv = jnp.concatenate([v_prev, vc], axis=2)
    kk = _expand_kv(kk.reshape(b * nc, 2 * w, kh, dh), h)
    vv = _expand_kv(vv.reshape(b * nc, 2 * w, kh, dh), h)
    qq = qc.reshape(b * nc, w, h, dh)

    sc = jnp.einsum(
        "bthd,bshd->bhts", qq.astype(COMPUTE_DTYPE), kk.astype(COMPUTE_DTYPE)
    ).astype(jnp.float32) / math.sqrt(dh)
    qpos = jnp.arange(w) + w                          # within the 2w slab
    kpos = jnp.arange(2 * w)
    mask = (kpos[None, :] <= qpos[:, None]) & (
        kpos[None, :] > qpos[:, None] - w
    )
    # first chunk has no previous block
    first = (jnp.arange(b * nc) % nc) == 0
    mask_first = mask & (kpos[None, :] >= w)
    full_mask = jnp.where(first[:, None, None, None],
                          mask_first[None, None], mask[None, None])
    sc = jnp.where(full_mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1).astype(COMPUTE_DTYPE)
    out = jnp.einsum("bhts,bshd->bthd", p, vv.astype(COMPUTE_DTYPE))
    out = out.reshape(b, nc, w, h, dh).reshape(b, tp, h, dh)
    return out[:, :t]


def attention_any(
    q, k, v, *, causal: bool, window: int | None, q_offset: int = 0,
    dense_limit: int = 8192,
) -> jax.Array:
    """Dispatch to the right attention core for the shapes at hand."""
    t, s = q.shape[1], k.shape[1]
    if window is not None and t == s and t > window:
        return local_chunk_attention(q, k, v, window=window)
    if t == 1 or (t * s) <= dense_limit * dense_limit // 4:
        return dense_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    return streaming_attention(q, k, v, causal=causal, q_offset=q_offset)


# ---------------------------------------------------------------------- #
# attention block (projections + cache handling)
# ---------------------------------------------------------------------- #
def attn_params(key, d_model, n_heads, n_kv_heads, head_dim) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim),
        "wo": dense_init(k4, n_heads * head_dim, d_model,
                         scale=1.0 / math.sqrt(n_heads * head_dim)),
    }


def attn_apply(
    p: dict,
    x: jax.Array,                     # [B, T, D]
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float | None,
    causal: bool = True,
    window: int | None = None,
    positions: jax.Array | None = None,
    cache: dict | None = None,        # {"k": [B,S,KH,dh], "v":..., "len": i32}
    xattn_src: jax.Array | None = None,   # cross-attention memory [B, S, D]
) -> tuple[jax.Array, dict | None]:
    b, t, d = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    q = (xc @ p["wq"].astype(COMPUTE_DTYPE)).reshape(b, t, n_heads, head_dim)
    kv_in = xattn_src.astype(COMPUTE_DTYPE) if xattn_src is not None else xc
    k = (kv_in @ p["wk"].astype(COMPUTE_DTYPE)).reshape(
        b, -1, n_kv_heads, head_dim)
    v = (kv_in @ p["wv"].astype(COMPUTE_DTYPE)).reshape(
        b, -1, n_kv_heads, head_dim)

    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    if rope_theta is not None and xattn_src is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)

    q_offset = 0
    new_cache = None
    if cache is not None and xattn_src is None:
        # decode: append this step's k/v at position cache["len"]
        s = cache["k"].shape[1]
        idx = cache["len"]
        k_all = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
        new_cache = {"k": k_all, "v": v_all, "len": idx + t}
        k, v = k_all, v_all
        q_offset = idx
        # mask out not-yet-written positions via the causal mask with
        # absolute offset (q_offset handles it)
        out = dense_attention(q, k, v, causal=True, window=window,
                              q_offset=q_offset)
    else:
        out = attention_any(q, k, v, causal=causal and xattn_src is None,
                            window=window)

    out = out.reshape(b, t, n_heads * head_dim)
    y = out @ p["wo"].astype(COMPUTE_DTYPE)
    return y.astype(x.dtype), new_cache


# decode with rope: positions for cached decode
def decode_positions(cache_len, b, t):
    return cache_len + jnp.broadcast_to(jnp.arange(t)[None], (b, t))


# ---------------------------------------------------------------------- #
# MLPs
# ---------------------------------------------------------------------- #
def mlp_params(key, d_model: int, d_ff: int, gated: bool) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff),
         "w_down": dense_init(ks[1], d_ff, d_model)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff)
    return p


def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    xc = x.astype(COMPUTE_DTYPE)
    up = xc @ p["w_up"].astype(COMPUTE_DTYPE)
    if "w_gate" in p:
        g = xc @ p["w_gate"].astype(COMPUTE_DTYPE)
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * up
    else:
        h = jax.nn.gelu(up)
    y = h @ p["w_down"].astype(COMPUTE_DTYPE)
    return y.astype(x.dtype)
