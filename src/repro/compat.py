"""JAX version-compatibility shims.

The repo targets a range of JAX releases; a handful of APIs moved between
them.  Everything version-dependent is resolved here once so the rest of the
codebase (and the tests) can import stable names:

* ``enable_x64`` -- the x64 context manager.  ``jax.experimental.enable_x64``
  is the long-stable spelling; newer releases re-export it at top level.
  Falls back to a config-flipping context manager if neither exists.
* ``make_mesh(shape, axis_names)`` -- ``jax.make_mesh`` grew an
  ``axis_types`` kwarg (``jax.sharding.AxisType``) in newer releases; on
  older ones the kwarg (and the enum) don't exist.  We always request
  ``Auto`` axes when the enum is available, which matches the legacy default.
* ``shard_map`` -- promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map``.
* ``compiled_cost_analysis`` -- ``Compiled.cost_analysis()`` returned a
  one-element list of dicts before returning a flat dict.
"""
from __future__ import annotations

import contextlib

import jax

# --------------------------------------------------------------------- #
# x64 context manager
# --------------------------------------------------------------------- #
if hasattr(jax, "enable_x64"):                      # jax >= 0.5-ish
    enable_x64 = jax.enable_x64
else:
    try:
        from jax.experimental import enable_x64     # 0.4.x spelling
    except ImportError:                             # pragma: no cover
        @contextlib.contextmanager
        def enable_x64(new_val: bool = True):
            old = jax.config.jax_enable_x64
            jax.config.update("jax_enable_x64", bool(new_val))
            try:
                yield
            finally:
                jax.config.update("jax_enable_x64", old)


# --------------------------------------------------------------------- #
# mesh construction (AxisType appeared in jax.sharding later)
# --------------------------------------------------------------------- #
try:
    from jax.sharding import AxisType               # newer jax
except ImportError:                                 # older jax: no enum
    AxisType = None


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with ``Auto`` axis types where supported."""
    kw = {"devices": devices} if devices is not None else {}
    if AxisType is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(AxisType.Auto,) * len(axis_names), **kw)
        except TypeError:                           # pragma: no cover
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, **kw)
    # pre-0.4.35 fallback: hand-build the Mesh          # pragma: no cover
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    devs = mesh_utils.create_device_mesh(tuple(axis_shapes),
                                         devices=devices)
    return Mesh(devs, tuple(axis_names))


# --------------------------------------------------------------------- #
# shard_map
# --------------------------------------------------------------------- #
if hasattr(jax, "shard_map"):                       # newer jax
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401


# --------------------------------------------------------------------- #
# Compiled.cost_analysis() normalization
# --------------------------------------------------------------------- #
def compiled_cost_analysis(compiled) -> dict:
    """Flat cost-analysis dict across jax versions (list-of-dicts vs dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
