"""Silicon-calibrated technology constants for the CIM-Tuner PPA models.

The paper fits an instruction-level power model and an area model from 28 nm
DC-synthesis + PTPX runs of the parameterized accelerator template (Sec. IV-A)
and verifies them against a prototype chip (Sec. IV-E, <10 % error).  No
synthesis tools exist in this environment, so the constants below play that
role: they are chosen from published 28 nm SRAM-CIM numbers and then *fitted*
so the two SOTA baselines of Table II land at their published areas:

    TranCIM-Base  (MR,MC,SCR,IS,OS) = (3,1,1,64,128)  ->  3.52 mm^2
    TP-DCIM-Base  (MR,MC,SCR,IS,OS) = (2,4,1,16,16)   ->  2.23 mm^2

With the macro geometries in ``macro.py`` (TranCIM: AL=128, PC=16; TP-DCIM:
AL=64, PC=8) the 2x2 linear system in (A_CU, A_FIXED) solves to

    3072+3072  CU units ... 6144*a_cu + a_fix = 3.52 - 0.375  - 0.0177
    8*512      CU units ... 4096*a_cu + a_fix = 2.23 - 0.0625 - 0.0118

    => A_CU ~ 497 um^2 / MAC unit,  A_FIXED ~ 0 (absorbed into per-instance
       fixed terms).  Energy constants are likewise fitted so the two
       baselines land at their published TOPS/W (2.54 / 1.89) on Bert-large:
       EMA dominates (>90 %), so e_ema acts as the master scale -- 1.2 pJ/bit
       models the *interface-only* energy at standard test conditions (the
       paper's template likewise excludes board-level DRAM core energy).

Changing any constant re-scales absolute PPA but not the *ordering* of
configurations explored by CIM-Tuner (see tests/test_calibration.py for the
sensitivity check).

The second half of this module is the paper's *measurement* loop
(Sec. IV-E): :func:`fit_corrections` solves per-term
:class:`CorrectionFactors` from measured Pallas-kernel timings
(``repro.obs.profile.run_microbench``), :meth:`TechConstants.with_corrections`
applies them, and :class:`CostModel` is the one facade every consumer
reaches the calibrated (or analytic) constants through.  Corrections scale
ONLY the energy/leakage constants -- the area model (and therefore
feasibility and pruning) is untouched, so a calibrated re-score ranks the
same feasible set the analytic search explored.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import random
import threading
import typing

#: environment variable naming a pinned calibration artifact
#: (written by ``repro-service calibrate -o ...`` / :func:`save_calibration`)
CALIBRATION_ENV = "CIM_TUNER_CALIBRATION"

#: bump when the calibration artifact layout changes meaning
CALIBRATION_SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class TechConstants:
    """28 nm-class energy/area/leakage constants (pJ, mm^2, mW)."""

    # --- per-instruction energies (pJ) -----------------------------------
    e_mac_pj: float = 0.08            # one INT8 MAC inside a DCIM macro
    e_sram_rd_pj_bit: float = 0.12    # IS/OS SRAM read, per bit
    e_sram_wr_pj_bit: float = 0.14    # IS/OS SRAM write, per bit
    e_cim_update_pj_bit: float = 0.20 # CIM weight-update write path, per bit
    e_ema_pj_bit: float = 1.2         # external memory interface, per bit (see note)
    # System-level overhead multiplier on dynamic energy (controller, clock
    # tree, NoC) -- folds the parts of PTPX power the template cannot see.
    sys_energy_overhead: float = 1.3

    # --- leakage ----------------------------------------------------------
    p_leak_mw_mm2: float = 15.0       # leakage power density

    # --- area (um^2 unless noted) ----------------------------------------
    a_cell_um2_bit: float = 0.36      # 6T bit-cell + CIM overhead, per bit
    a_cu_um2: float = 497.0           # one 8b MAC compute unit (fitted)
    a_sram_mm2_per_mb: float = 0.25   # compiled SRAM density
    a_sram_fixed_mm2: float = 0.02    # per-SRAM-instance periphery
    a_macro_fixed_mm2: float = 0.01   # per-macro periphery (drivers, ctrl)
    a_fixed_mm2: float = 0.0          # absorbed into per-macro/SRAM fixed (fit)

    # --- timing -----------------------------------------------------------
    freq_mhz: float = 500.0           # default operating frequency

    # --- data widths (bits) -----------------------------------------------
    dw_in: int = 8
    dw_w: int = 8
    dw_psum: int = 24
    dw_out: int = 8

    def with_corrections(
        self, corrections: "CorrectionFactors | None",
    ) -> "TechConstants":
        """A copy with measured correction factors applied.

        ``compute`` scales the per-MAC energy, ``memory`` scales every
        SRAM/external-interface per-bit energy, ``update`` scales the CIM
        weight-update path and ``leakage`` scales leakage density.  Area
        constants are deliberately NOT touched: feasibility, pruning and
        the snap-verify area check must agree between the analytic and
        calibrated fidelities.  Identity corrections (or ``None``) return
        ``self`` unchanged, bit-for-bit -- so analytic job keys and
        executable-cache entries are unaffected.
        """
        if corrections is None or corrections.is_identity():
            return self
        c = corrections
        return dataclasses.replace(
            self,
            e_mac_pj=self.e_mac_pj * c.compute,
            e_sram_rd_pj_bit=self.e_sram_rd_pj_bit * c.memory,
            e_sram_wr_pj_bit=self.e_sram_wr_pj_bit * c.memory,
            e_ema_pj_bit=self.e_ema_pj_bit * c.memory,
            e_cim_update_pj_bit=self.e_cim_update_pj_bit * c.update,
            p_leak_mw_mm2=self.p_leak_mw_mm2 * c.leakage,
        )


DEFAULT_TECH = TechConstants()


def resolve_tech(tech: "TechConstants | None" = None) -> TechConstants:
    """THE default-tech rule, in one place: an explicit ``tech`` wins,
    ``None`` means the analytic :data:`DEFAULT_TECH`.  Every module that
    used to spell ``tech=DEFAULT_TECH`` in its signature now spells
    ``tech=None`` and resolves here, so calibrated technologies enter
    through :class:`CostModel` / :meth:`TechConstants.with_corrections`
    only -- never ambiently via an environment variable."""
    return tech if tech is not None else DEFAULT_TECH


# --------------------------------------------------------------------- #
# measured correction factors
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class CorrectionFactors:
    """Per-term multipliers fitted from measured kernel timings.

    The fit model is a two-term roofline in microseconds::

        t_us ~ compute * (flops / peak_flops) * 1e6
             + memory  * (bytes / peak_bw)    * 1e6

    ``update`` rides the memory term (CIM updates are write traffic) and
    ``leakage`` stays 1.0 -- the microbench cannot observe static power.
    ``fitted_on`` / ``residual_us`` are diagnostics of the fit that
    produced the factors (0 / 0.0 for hand-built factors).
    """

    compute: float = 1.0
    memory: float = 1.0
    update: float = 1.0
    leakage: float = 1.0
    fitted_on: int = 0                # measurement records used by the fit
    residual_us: float = 0.0          # RMS error of the fit on its train set

    def is_identity(self) -> bool:
        """True when applying these factors is a no-op."""
        return (self.compute == 1.0 and self.memory == 1.0
                and self.update == 1.0 and self.leakage == 1.0)

    def as_dict(self) -> dict:
        """JSON-able field dict (the artifact / HTTP payload form)."""
        return dataclasses.asdict(self)


def calibration_version(
    corrections: CorrectionFactors | None,
) -> str:
    """Stable content hash of a set of correction factors.

    ``"uncalibrated"`` for ``None``/identity; otherwise a 16-hex-digit
    digest over the factor floats (hex-encoded, so the version is
    bit-exact, not repr-approximate).  Folded into ``job_key`` for
    measured-fidelity jobs, so warm analytic results never answer
    calibrated queries and two differently-calibrated runs never share
    a store record.
    """
    if corrections is None or corrections.is_identity():
        return "uncalibrated"
    payload = {
        "schema": CALIBRATION_SCHEMA,
        "factors": [float(x).hex() for x in (
            corrections.compute, corrections.memory,
            corrections.update, corrections.leakage)],
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# --------------------------------------------------------------------- #
# the fitting pass
# --------------------------------------------------------------------- #
def _features(record: typing.Mapping) -> tuple[float, float] | None:
    """(compute_us, memory_us) roofline features of one measurement
    record, or ``None`` when the record carries no cost analysis."""
    flops = record.get("flops")
    nbytes = record.get("bytes")
    if not flops and not nbytes:
        return None
    from repro.obs import profile as _profile

    t_c = float(flops or 0.0) / _profile.peak_flops() * 1e6
    t_m = float(nbytes or 0.0) / _profile.peak_bw() * 1e6
    return t_c, t_m


def _usable(records: typing.Iterable[typing.Mapping]) -> list[tuple[
        float, float, float]]:
    rows = []
    for r in records:
        feats = _features(r)
        if feats is None or r.get("us") is None:
            continue
        rows.append((feats[0], feats[1], float(r["us"])))
    return rows


_FACTOR_MIN, _FACTOR_MAX = 1e-3, 1e3


def _clamp(x: float) -> float:
    if not math.isfinite(x) or x <= 0.0:
        return 1.0
    return min(max(x, _FACTOR_MIN), _FACTOR_MAX)


def fit_corrections(
    records: typing.Sequence[typing.Mapping],
) -> CorrectionFactors:
    """Least-squares fit of :class:`CorrectionFactors` from measurement
    records (the :class:`repro.obs.profile.MeasurementRecord` schema:
    ``kernel, bucket, tiling, us, flops, bytes, seed``).

    Solves the 2x2 normal equations of ``us ~ compute*t_c + memory*t_m``;
    a singular/ill-conditioned system falls back to independent per-term
    1-D fits.  Factors are clamped to ``[1e-3, 1e3]``; ``update`` follows
    ``memory`` (CIM updates are write traffic) and ``leakage`` stays 1.0.
    Raises ``ValueError`` when no record carries both a timing and a cost
    analysis.
    """
    rows = _usable(records)
    if not rows:
        raise ValueError(
            "no usable measurement records (need 'us' plus a "
            "flops/bytes cost analysis; run with CIM_TUNER_PROFILE=1)")
    s_cc = sum(tc * tc for tc, _tm, _us in rows)
    s_mm = sum(tm * tm for _tc, tm, _us in rows)
    s_cm = sum(tc * tm for tc, tm, _us in rows)
    s_cy = sum(tc * us for tc, _tm, us in rows)
    s_my = sum(tm * us for _tc, tm, us in rows)
    det = s_cc * s_mm - s_cm * s_cm
    # relative-determinant test: collinear features (every kernel at the
    # same flops:bytes ratio) make the joint solve meaningless
    if det > 1e-12 * max(s_cc * s_mm, 1e-300):
        compute = (s_cy * s_mm - s_my * s_cm) / det
        memory = (s_my * s_cc - s_cy * s_cm) / det
    else:                                      # fall back to 1-D solves
        compute = s_cy / s_cc if s_cc > 0.0 else 1.0
        memory = s_my / s_mm if s_mm > 0.0 else 1.0
    compute, memory = _clamp(compute), _clamp(memory)
    fitted = dataclasses.replace(
        CorrectionFactors(), compute=compute, memory=memory, update=memory,
        fitted_on=len(rows))
    return dataclasses.replace(
        fitted, residual_us=evaluate_corrections(records, fitted))


def predict_us(record: typing.Mapping,
               corrections: CorrectionFactors | None = None) -> float | None:
    """Model-predicted kernel time (us) for one measurement record;
    ``None`` when the record has no cost analysis.  ``corrections=None``
    is the *uncalibrated* roofline prediction (both factors 1.0)."""
    feats = _features(record)
    if feats is None:
        return None
    c = corrections or CorrectionFactors()
    return c.compute * feats[0] + c.memory * feats[1]


def evaluate_corrections(
    records: typing.Sequence[typing.Mapping],
    corrections: CorrectionFactors | None = None,
) -> float:
    """RMS error (us) of the (possibly uncalibrated) model over the
    records' measured timings."""
    rows = _usable(records)
    if not rows:
        raise ValueError("no usable measurement records to evaluate")
    c = corrections or CorrectionFactors()
    sq = 0.0
    for tc, tm, us in rows:
        err = c.compute * tc + c.memory * tm - us
        sq += err * err
    return math.sqrt(sq / len(rows))


def fit_report(
    records: typing.Sequence[typing.Mapping],
    holdout_fraction: float = 0.25,
    seed: int = 0,
) -> dict:
    """Fit on a deterministic train split, score on the held-out rest.

    Returns a JSON-able report::

        {"corrections": {...}, "version": ..., "train_records": N,
         "holdout_records": M, "uncalibrated_rms_us": ...,
         "calibrated_rms_us": ..., "improvement": ...}

    ``calibrated_rms_us`` is the fitted model's error on the HELD-OUT
    records; ``uncalibrated_rms_us`` is the identity model's error on the
    same records, so ``improvement > 1`` means the fit generalizes.  With
    fewer than 3 usable records the whole set is both train and holdout.
    """
    usable = [r for r in records
              if _features(r) is not None and r.get("us") is not None]
    if not usable:
        raise ValueError("no usable measurement records to fit")
    order = list(range(len(usable)))
    random.Random(seed).shuffle(order)
    n_hold = max(1, int(len(usable) * holdout_fraction))
    if len(usable) - n_hold < 2:                # tiny sets: no split
        train = holdout = usable
        n_hold = len(usable)
    else:
        hold_ix = set(order[:n_hold])
        train = [r for i, r in enumerate(usable) if i not in hold_ix]
        holdout = [r for i, r in enumerate(usable) if i in hold_ix]
    corrections = fit_corrections(train)
    uncal = evaluate_corrections(holdout)
    cal = evaluate_corrections(holdout, corrections)
    return {
        "corrections": corrections.as_dict(),
        "version": calibration_version(corrections),
        "train_records": len(train),
        "holdout_records": len(holdout),
        "uncalibrated_rms_us": uncal,
        "calibrated_rms_us": cal,
        "improvement": (uncal / cal) if cal > 0.0 else math.inf,
    }


# --------------------------------------------------------------------- #
# calibration artifacts (the CIM_TUNER_CALIBRATION pin)
# --------------------------------------------------------------------- #
def save_calibration(
    path: str,
    corrections: CorrectionFactors,
    records: typing.Sequence[typing.Mapping] | None = None,
    report: dict | None = None,
) -> dict:
    """Write a calibration artifact (atomic JSON) and return its payload.

    The artifact pins a fitted model: point :data:`CALIBRATION_ENV` at it
    and every measured-fidelity consumer in the fleet shares one
    calibration version (hence one set of store keys)."""
    payload = {
        "schema": CALIBRATION_SCHEMA,
        "version": calibration_version(corrections),
        "corrections": corrections.as_dict(),
    }
    if report is not None:
        payload["report"] = {k: v for k, v in report.items()
                             if k != "corrections"}
    if records is not None:
        payload["measurements"] = [dict(r) for r in records]
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return payload


def load_calibration(path: str) -> tuple[CorrectionFactors, dict]:
    """Read an artifact written by :func:`save_calibration`; returns the
    parsed :class:`CorrectionFactors` plus the raw payload."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != CALIBRATION_SCHEMA:
        raise ValueError(
            f"calibration artifact {path!r} has schema "
            f"{payload.get('schema')!r}, expected {CALIBRATION_SCHEMA}")
    fields = {f.name for f in dataclasses.fields(CorrectionFactors)}
    raw = payload.get("corrections") or {}
    cf = CorrectionFactors(**{k: v for k, v in raw.items() if k in fields})
    return cf, payload


# --------------------------------------------------------------------- #
# live calibration state (process-cached)
# --------------------------------------------------------------------- #
_cal_lock = threading.Lock()
_live_fit: tuple[CorrectionFactors, list] | None = None
_env_artifact: tuple[str, CorrectionFactors, dict] | None = None


def _pinned_artifact() -> tuple[CorrectionFactors, dict] | None:
    """The :data:`CALIBRATION_ENV` artifact, if set and loadable
    (re-read when the env var changes; unreadable pins are ignored so a
    stale path degrades to live fitting rather than failing the job)."""
    global _env_artifact
    path = os.environ.get(CALIBRATION_ENV)
    if not path:
        _env_artifact = None
        return None
    if _env_artifact is not None and _env_artifact[0] == path:
        return _env_artifact[1], _env_artifact[2]
    try:
        cf, payload = load_calibration(path)
    except (OSError, ValueError, TypeError):
        return None
    _env_artifact = (path, cf, payload)
    return cf, payload


def resolve_corrections() -> tuple[CorrectionFactors, str, list]:
    """The corrections a measured-fidelity run should apply, with
    provenance: ``(factors, source, measurement_records)``.

    Precedence: a pinned :data:`CALIBRATION_ENV` artifact
    (``source="artifact"``; its stored measurements ride along), else a
    process-cached live fit over a fresh
    :func:`repro.obs.profile.run_microbench` sweep (``source="live"``).
    The live fit runs the kernels ONCE per process -- repeated measured
    races reuse it."""
    global _live_fit
    with _cal_lock:
        pinned = _pinned_artifact()
        if pinned is not None:
            cf, payload = pinned
            return cf, "artifact", list(payload.get("measurements") or ())
        if _live_fit is None:
            from repro.obs import profile as _profile

            records = _profile.run_microbench()
            try:
                cf = fit_corrections(records)
            except ValueError:
                # no usable records (cost analysis unavailable on this
                # host): degrade to identity so the measured phase still
                # re-scores -- with uncorrected constants
                cf = CorrectionFactors()
            _live_fit = (cf, list(records))
        return _live_fit[0], "live", list(_live_fit[1])


def active_calibration_version() -> str:
    """The version string folded into measured-fidelity job keys.

    A pinned artifact answers with its stored version (stable across
    processes/hosts -- pin one artifact fleet-wide for shared store
    keys); an already-run live fit answers with its fitted version; a
    process that has not measured yet answers the ``"live"`` sentinel
    (submission-time keys must not trigger a kernel sweep)."""
    with _cal_lock:
        pinned = _pinned_artifact()
        if pinned is not None:
            return calibration_version(pinned[0])
        if _live_fit is not None:
            return calibration_version(_live_fit[0])
    return "live"


def calibration_record() -> dict:
    """JSON-able view of the process's active calibration (the
    ``GET /v1/calibration`` payload and the ``repro-service calibrate``
    summary): source, version, factors, and fit diagnostics when
    available."""
    with _cal_lock:
        pinned = _pinned_artifact()
        if pinned is not None:
            cf, payload = pinned
            out = {
                "source": "artifact",
                "path": os.environ.get(CALIBRATION_ENV),
                "version": calibration_version(cf),
                "corrections": cf.as_dict(),
            }
            if "report" in payload:
                out["report"] = payload["report"]
            return out
        if _live_fit is not None:
            cf = _live_fit[0]
            return {
                "source": "live",
                "version": calibration_version(cf),
                "corrections": cf.as_dict(),
                "measurements": len(_live_fit[1]),
            }
    return {"source": "none", "version": "uncalibrated"}


def reset_calibration_state() -> None:
    """Forget the cached live fit and pinned-artifact read (tests /
    re-pointing :data:`CALIBRATION_ENV`)."""
    global _live_fit, _env_artifact
    with _cal_lock:
        _live_fit = None
        _env_artifact = None
    reset_default_cost_model()


# --------------------------------------------------------------------- #
# the CostModel facade
# --------------------------------------------------------------------- #
class CostModel:
    """ONE front door to the PPA models: base constants + corrections.

    ``CostModel()`` is the analytic model on :data:`DEFAULT_TECH`;
    ``CostModel(corrections=...)`` is the measured-fidelity model.  The
    resolved :attr:`tech` is what every delegate below evaluates with --
    callers that used to import ``DEFAULT_TECH`` directly now construct
    (or receive) a ``CostModel`` and never touch module constants.
    """

    def __init__(
        self,
        tech: TechConstants | None = None,
        corrections: CorrectionFactors | None = None,
    ):
        self.base = resolve_tech(tech)
        self.corrections = corrections
        #: the effective constants (corrections applied; ``is`` the base
        #: object when uncalibrated, so analytic identity is bit-exact)
        self.tech = self.base.with_corrections(corrections)

    @property
    def calibrated(self) -> bool:
        """True when corrections actually change the constants."""
        return self.tech is not self.base

    @property
    def version(self) -> str:
        """Content version of the applied corrections
        (``"uncalibrated"`` for the analytic model)."""
        return calibration_version(self.corrections)

    def __repr__(self) -> str:
        return f"CostModel(version={self.version!r})"

    # -- delegates (lazy imports: cost_model/template import THIS module) --
    def macro_params(self, macro):
        """Traceable macro params under this model's constants."""
        from repro.core import cost_model as _cm

        return _cm.macro_params(macro, self.tech)

    def tech_params(self):
        """Traceable tech params under this model's constants."""
        from repro.core import cost_model as _cm

        return _cm.tech_params(self.tech)

    def workload_metrics(self, ops_arr, cfg_row, macro, objective="ee",
                         strategy_set: str = "st") -> dict:
        """Human-facing PPA metrics (see ``cost_model.workload_metrics``)."""
        from repro.core import cost_model as _cm

        return _cm.workload_metrics(ops_arr, cfg_row, macro, self.tech,
                                    objective, strategy_set)

    def accelerator_area_mm2(self, cfg, macro) -> float:
        """Template area under this model's constants (area is correction-
        invariant by construction, but routed here for API symmetry)."""
        from repro.core.template import accelerator_area_mm2 as _area

        return _area(cfg, macro, self.tech)

    def peak_tops(self, cfg, macro) -> float:
        """Peak throughput of a configured grid under this model."""
        from repro.core.template import peak_tops as _peak

        return _peak(cfg, macro, self.tech)


_default_cost_model: CostModel | None = None
_dcm_lock = threading.Lock()


def default_cost_model() -> CostModel:
    """The process-wide :class:`CostModel`: calibrated from the pinned
    :data:`CALIBRATION_ENV` artifact when set, analytic otherwise.
    Cached; :func:`reset_default_cost_model` (or
    :func:`reset_calibration_state`) re-resolves after env changes."""
    global _default_cost_model
    with _dcm_lock:
        if _default_cost_model is None:
            pinned = _pinned_artifact()
            _default_cost_model = CostModel(
                corrections=pinned[0] if pinned is not None else None)
        return _default_cost_model


def reset_default_cost_model() -> None:
    """Drop the cached process-wide :class:`CostModel` (tests / env
    re-pointing)."""
    global _default_cost_model
    with _dcm_lock:
        _default_cost_model = None
