"""Silicon-calibrated technology constants for the CIM-Tuner PPA models.

The paper fits an instruction-level power model and an area model from 28 nm
DC-synthesis + PTPX runs of the parameterized accelerator template (Sec. IV-A)
and verifies them against a prototype chip (Sec. IV-E, <10 % error).  No
synthesis tools exist in this environment, so the constants below play that
role: they are chosen from published 28 nm SRAM-CIM numbers and then *fitted*
so the two SOTA baselines of Table II land at their published areas:

    TranCIM-Base  (MR,MC,SCR,IS,OS) = (3,1,1,64,128)  ->  3.52 mm^2
    TP-DCIM-Base  (MR,MC,SCR,IS,OS) = (2,4,1,16,16)   ->  2.23 mm^2

With the macro geometries in ``macro.py`` (TranCIM: AL=128, PC=16; TP-DCIM:
AL=64, PC=8) the 2x2 linear system in (A_CU, A_FIXED) solves to

    3072+3072  CU units ... 6144*a_cu + a_fix = 3.52 - 0.375  - 0.0177
    8*512      CU units ... 4096*a_cu + a_fix = 2.23 - 0.0625 - 0.0118

    => A_CU ~ 497 um^2 / MAC unit,  A_FIXED ~ 0 (absorbed into per-instance
       fixed terms).  Energy constants are likewise fitted so the two
       baselines land at their published TOPS/W (2.54 / 1.89) on Bert-large:
       EMA dominates (>90 %), so e_ema acts as the master scale -- 1.2 pJ/bit
       models the *interface-only* energy at standard test conditions (the
       paper's template likewise excludes board-level DRAM core energy).

Changing any constant re-scales absolute PPA but not the *ordering* of
configurations explored by CIM-Tuner (see tests/test_calibration.py for the
sensitivity check).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TechConstants:
    """28 nm-class energy/area/leakage constants (pJ, mm^2, mW)."""

    # --- per-instruction energies (pJ) -----------------------------------
    e_mac_pj: float = 0.08            # one INT8 MAC inside a DCIM macro
    e_sram_rd_pj_bit: float = 0.12    # IS/OS SRAM read, per bit
    e_sram_wr_pj_bit: float = 0.14    # IS/OS SRAM write, per bit
    e_cim_update_pj_bit: float = 0.20 # CIM weight-update write path, per bit
    e_ema_pj_bit: float = 1.2         # external memory interface, per bit (see note)
    # System-level overhead multiplier on dynamic energy (controller, clock
    # tree, NoC) -- folds the parts of PTPX power the template cannot see.
    sys_energy_overhead: float = 1.3

    # --- leakage ----------------------------------------------------------
    p_leak_mw_mm2: float = 15.0       # leakage power density

    # --- area (um^2 unless noted) ----------------------------------------
    a_cell_um2_bit: float = 0.36      # 6T bit-cell + CIM overhead, per bit
    a_cu_um2: float = 497.0           # one 8b MAC compute unit (fitted)
    a_sram_mm2_per_mb: float = 0.25   # compiled SRAM density
    a_sram_fixed_mm2: float = 0.02    # per-SRAM-instance periphery
    a_macro_fixed_mm2: float = 0.01   # per-macro periphery (drivers, ctrl)
    a_fixed_mm2: float = 0.0          # absorbed into per-macro/SRAM fixed (fit)

    # --- timing -----------------------------------------------------------
    freq_mhz: float = 500.0           # default operating frequency

    # --- data widths (bits) -----------------------------------------------
    dw_in: int = 8
    dw_w: int = 8
    dw_psum: int = 24
    dw_out: int = 8


DEFAULT_TECH = TechConstants()
