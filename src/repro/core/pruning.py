"""Hardware design-space enumeration and pruning (paper Sec. III-D).

Constraints applied:
  1. power-of-two SCR / IS_SIZE / OS_SIZE (address-decoding alignment);
  2. internal bandwidth (aggregate ICW, WUW) >= external bus BW;
  3. area(cfg) <= budget.

The pruned fraction is reported by benchmarks/fig9_runtime.py (paper: >35 %).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.calibration import TechConstants, resolve_tech
from repro.core.macro import MacroSpec

MR_CHOICES = (1, 2, 3, 4, 6, 8)
MC_CHOICES = (1, 2, 3, 4, 6, 8)
SCR_CHOICES = (1, 2, 4, 8, 16, 32, 64)
IS_KB_CHOICES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
OS_KB_CHOICES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    mr: tuple[int, ...] = MR_CHOICES
    mc: tuple[int, ...] = MC_CHOICES
    scr: tuple[int, ...] = SCR_CHOICES
    is_kb: tuple[int, ...] = IS_KB_CHOICES
    os_kb: tuple[int, ...] = OS_KB_CHOICES

    def axes(self) -> tuple[tuple[int, ...], ...]:
        return (self.mr, self.mc, self.scr, self.is_kb, self.os_kb)

    @property
    def size(self) -> int:
        return int(np.prod([len(a) for a in self.axes()]))

    def fix(self, **fixed: int) -> "DesignSpace":
        """Pin axes to single values (Table II: 'other parameters fixed')."""
        kw = {}
        for name in ("mr", "mc", "scr", "is_kb", "os_kb"):
            kw[name] = (fixed[name],) if name in fixed else getattr(self, name)
        return DesignSpace(**kw)


def enumerate_space(space: DesignSpace) -> np.ndarray:
    """All raw candidate tuples as an int array [C, 5]."""
    return np.array(
        list(itertools.product(*space.axes())), dtype=np.int64
    )


def prune_space(
    space: DesignSpace,
    macro: MacroSpec,
    area_budget_mm2: float,
    bw: int = 256,
    tech: TechConstants | None = None,
) -> tuple[np.ndarray, dict]:
    """Returns ([C_valid, 5] candidates, stats) after bandwidth+area pruning.

    Vectorized (the same closed-form area/bandwidth rules as template.py --
    pinned against the scalar path in tests/test_explorer.py)."""
    tech = resolve_tech(tech)
    raw = enumerate_space(space)
    mr, mc, scr, is_kb, os_kb = (raw[:, i].astype(np.float64)
                                 for i in range(5))
    bw_ok = (macro.icw * mr >= bw) & (macro.wuw * mr * mc >= bw)
    cells = macro.al * macro.pc * scr * macro.dw_w * tech.a_cell_um2_bit
    cus = macro.al * macro.pc * tech.a_cu_um2
    macro_area = (cells + cus) * 1e-6 + tech.a_macro_fixed_mm2
    sram = lambda kb: kb * 8.0 / 1024.0 * tech.a_sram_mm2_per_mb \
        + tech.a_sram_fixed_mm2
    area = mr * mc * macro_area + sram(is_kb) + sram(os_kb) + tech.a_fixed_mm2
    area_ok = area <= area_budget_mm2
    keep = bw_ok & area_ok
    stats = {
        "raw": len(raw),
        "kept": int(keep.sum()),
        "bandwidth_pruned": int((~bw_ok).sum()),
        "area_pruned": int((bw_ok & ~area_ok).sum()),
        "pruned_fraction": 1.0 - keep.sum() / max(1, len(raw)),
    }
    return raw[keep], stats


def candidates_with_bw(cands: np.ndarray, bw: int) -> np.ndarray:
    """Append the bus-bandwidth column -> cfg rows for the jnp cost model."""
    col = np.full((len(cands), 1), bw, dtype=np.int64)
    return np.concatenate([cands, col], axis=1).astype(np.float64)
