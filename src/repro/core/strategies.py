"""The fine-grained two-level mapping-strategy space (paper Sec. III-C).

Accelerator level (scheduling):
  * spatial  -- NR (non-reversed: activations stream from IS, weights live in
    CIM = weight-stationary) vs R (reversed: activations live in CIM,
    weights stream = input-stationary).
  * temporal -- IP (input-priority update: IS contents cycle while CIM
    planes stay resident as long as possible) vs WP (weight-priority update:
    CIM planes cycle while IS rows stay resident).

Macro level (tiling):
  * AF (accumulation-first): the SCR resident planes cover consecutive
    K-tiles of the same output channels -> partial sums accumulate in the
    psum register across consecutive cycles, but each plane needs a distinct
    input chunk.
  * PF (parallel-first): the SCR resident planes cover consecutive N-tiles of
    the same input channels -> the input vector is reused across consecutive
    cycles, but SCR distinct partial-sum groups must be buffered in the
    Output SRAM (and spill to external memory when it overflows).

The full space is the 2 x 2 x 2 = 8-point cross product (Fig. 6b).  The
spatial-only subset {NR, R} x {IP} x {AF} reproduces the prior-work mapping
space of [19] and is the Fig. 7 baseline ("SO").
"""
from __future__ import annotations

import dataclasses
import itertools


@dataclasses.dataclass(frozen=True)
class Strategy:
    spatial: str   # "NR" | "R"
    temporal: str  # "IP" | "WP"
    tiling: str    # "AF" | "PF"

    def __post_init__(self) -> None:
        if self.spatial not in ("NR", "R"):
            raise ValueError(f"bad spatial {self.spatial}")
        if self.temporal not in ("IP", "WP"):
            raise ValueError(f"bad temporal {self.temporal}")
        if self.tiling not in ("AF", "PF"):
            raise ValueError(f"bad tiling {self.tiling}")

    @property
    def index(self) -> int:
        return (
            ("NR", "R").index(self.spatial) * 4
            + ("IP", "WP").index(self.temporal) * 2
            + ("AF", "PF").index(self.tiling)
        )

    def __str__(self) -> str:
        return f"{self.spatial}-{self.temporal}-{self.tiling}"

    @staticmethod
    def from_index(i: int) -> "Strategy":
        if not 0 <= i < 8:
            raise ValueError(f"strategy index out of range: {i}")
        return Strategy(
            spatial=("NR", "R")[i // 4],
            temporal=("IP", "WP")[(i // 2) % 2],
            tiling=("AF", "PF")[i % 2],
        )

    @staticmethod
    def parse(s: str) -> "Strategy":
        sp, t, f = s.upper().split("-")
        return Strategy(sp, t, f)


ALL_STRATEGIES: tuple[Strategy, ...] = tuple(
    Strategy(sp, t, f)
    for sp, t, f in itertools.product(("NR", "R"), ("IP", "WP"), ("AF", "PF"))
)

# Spatial-only baseline space of [19]: weight/input stationary selection with
# conventional input-priority updates and no SCR-aware tiling.
SPATIAL_ONLY: tuple[Strategy, ...] = (
    Strategy("NR", "IP", "AF"),
    Strategy("R", "IP", "AF"),
)

STRATEGY_SETS: dict[str, tuple[Strategy, ...]] = {
    "st": ALL_STRATEGIES,   # scheduling + tiling (CIM-Tuner)
    "so": SPATIAL_ONLY,     # spatial scheduling only (prior work [19])
}
