"""Generalized three-stage-pipeline SRAM-CIM accelerator template (Sec. III-B).

Stage 1 buffers input data in the Input SRAM (``IS_SIZE``), stage 2 stores
weights and computes in an ``MR x MC`` grid of CIM macros (outputs accumulate
along the row direction, inputs broadcast along the column direction), and
stage 3 accumulates/buffers partial sums in the Output SRAM (``OS_SIZE``).
The accelerator talks to external memory over a bus of ``BW`` bits/cycle.

SCR is an *accelerator-level* parameter here: the number of resident
``AL x PC`` weight planes per macro chosen by the co-exploration.
"""
from __future__ import annotations

import dataclasses

from repro.core.calibration import TechConstants, resolve_tech
from repro.core.macro import MacroSpec


@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    """The explored hardware point: (MR, MC, SCR, IS_SIZE, OS_SIZE [, BW])."""

    mr: int           # macro rows   (accumulation / K direction)
    mc: int           # macro cols   (parallel / N direction)
    scr: int          # resident weight planes per macro
    is_kb: int        # input SRAM size  [KB]
    os_kb: int        # output SRAM size [KB]
    bw: int = 256     # external bus bandwidth [bits / cycle]

    def __post_init__(self) -> None:
        for f in ("mr", "mc", "scr", "is_kb", "os_kb", "bw"):
            if getattr(self, f) <= 0:
                raise ValueError(f"{f} must be positive, got {getattr(self, f)}")

    # physical tile the macro grid covers per plane
    def kp(self, macro: MacroSpec) -> int:
        return self.mr * macro.al

    def np_(self, macro: MacroSpec) -> int:
        return self.mc * macro.pc

    @property
    def is_bits(self) -> int:
        return self.is_kb * 1024 * 8

    @property
    def os_bits(self) -> int:
        return self.os_kb * 1024 * 8

    def as_tuple(self) -> tuple[int, int, int, int, int]:
        return (self.mr, self.mc, self.scr, self.is_kb, self.os_kb)


def sram_area_mm2(kb: int, tech: TechConstants | None = None) -> float:
    tech = resolve_tech(tech)
    mb = kb * 8 / 1024.0  # KB -> Mb
    return mb * tech.a_sram_mm2_per_mb + tech.a_sram_fixed_mm2


def accelerator_area_mm2(
    cfg: AcceleratorConfig,
    macro: MacroSpec,
    tech: TechConstants | None = None,
) -> float:
    """Area model: macros (cells scale with SCR) + IS + OS + fixed overhead."""
    tech = resolve_tech(tech)
    macros = cfg.mr * cfg.mc * macro.area_mm2(cfg.scr, tech)
    return (
        macros
        + sram_area_mm2(cfg.is_kb, tech)
        + sram_area_mm2(cfg.os_kb, tech)
        + tech.a_fixed_mm2
    )


def internal_input_bandwidth(cfg: AcceleratorConfig, macro: MacroSpec) -> int:
    """Aggregate input-feed bandwidth: MR macro rows consume distinct input
    vectors (columns share via broadcast)."""
    return macro.icw * cfg.mr


def internal_update_bandwidth(cfg: AcceleratorConfig, macro: MacroSpec) -> int:
    """Aggregate weight-update bandwidth across the grid."""
    return macro.wuw * cfg.mr * cfg.mc


def bandwidth_ok(cfg: AcceleratorConfig, macro: MacroSpec) -> bool:
    """Paper Sec. III-D: prune designs whose internal bandwidth (ICW or WUW
    aggregate) falls below the external bus bandwidth BW."""
    return (
        internal_input_bandwidth(cfg, macro) >= cfg.bw
        and internal_update_bandwidth(cfg, macro) >= cfg.bw
    )


def peak_tops(cfg: AcceleratorConfig, macro: MacroSpec,
              tech: TechConstants | None = None) -> float:
    """Peak INT8 throughput (TOPS, 1 MAC = 2 OPs) of the configured grid."""
    macs_per_s = macro.peak_macs_per_cycle(cfg.mr, cfg.mc) * macro.freq_mhz * 1e6
    return 2.0 * macs_per_s / 1e12
