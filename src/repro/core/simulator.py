"""Instruction-driven cycle simulator for the generalized accelerator
template (paper Sec. III-A: "cycle-accurate performance and power
simulations ... driven by instruction flows").

Consumes the per-resident-set schedule emitted by ``compiler.compile_schedule``
and plays it through a three-resource pipeline:

    BUS  -- external memory traffic (ema bits / BW per set)
    CIM  -- plane updates + plane computes
    (IS/OS are bandwidth-matched by the Sec. III-D pruning rule and are not
     separately modeled)

Dependency model (double-buffered pipeline):

    bus_done[i]    = bus_done[i-1] + ema_cyc[i]
    upd_start[i]   = max(upd_done[i-1], bus_done[i])                (overlap)
                     max(cmp_done[i-1], bus_done[i])             (no overlap)
    upd_done[i]    = upd_start[i] + upd_cyc[i]
    cmp_start[i]   = max(cmp_done[i-1], upd_done[i])
    cmp_done[i]    = cmp_start[i] + cmp_cyc[i]

The closed-form model's overlapped latency max(sum_c, sum_e, sum_u) is a
*lower bound* of this simulation and sum(c+e+u) an upper bound; both bounds
are property-tested, and the typical gap (near zero for the homogeneous
steady-state sets the compiler emits) is reported by the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def simulate_schedule(
    rec: dict[str, np.ndarray],
    bw: int,
    overlap: bool,
) -> dict[str, float]:
    """Cycle simulation of one compiled schedule.  Returns latency and
    per-resource busy/utilization stats."""
    ema_bits = (
        rec["v_bits"] + rec["s_bits"] + rec["spill_bits"] + rec["y_bits"]
    )
    ema_cyc = np.ceil(ema_bits / bw)
    cmp_cyc = rec["compute_cycles"].astype(np.float64)
    upd_cyc = rec["update_cycles"].astype(np.float64)

    # float64 under jax.experimental.enable_x64 (exact), float32 otherwise
    e = jnp.asarray(ema_cyc)
    c = jnp.asarray(cmp_cyc, dtype=e.dtype)
    u = jnp.asarray(upd_cyc, dtype=e.dtype)

    def step(carry, xs):
        bus_done, upd_done, cmp_done = carry
        e_i, u_i, c_i = xs
        bus_done = bus_done + e_i
        upd_start = jnp.maximum(upd_done if overlap else cmp_done, bus_done)
        upd_done = upd_start + u_i
        cmp_start = jnp.maximum(cmp_done, upd_done)
        cmp_done = cmp_start + c_i
        return (bus_done, upd_done, cmp_done), None

    init = (jnp.zeros((), e.dtype),) * 3
    (bus_done, _upd_done, cmp_done), _ = jax.lax.scan(step, init, (e, u, c))
    latency = float(cmp_done)
    total = {
        "latency_cycles": latency,
        "bus_busy": float(e.sum()),
        "compute_busy": float(c.sum()),
        "update_busy": float(u.sum()),
        "n_sets": int(len(ema_cyc)),
    }
    total["compute_utilization"] = total["compute_busy"] / max(latency, 1.0)
    total["bus_utilization"] = total["bus_busy"] / max(latency, 1.0)
    return total


def analytic_latency_bounds(
    rec: dict[str, np.ndarray], bw: int
) -> tuple[float, float]:
    """(lower, upper) bounds that must sandwich the simulated latency."""
    ema_bits = (
        rec["v_bits"] + rec["s_bits"] + rec["spill_bits"] + rec["y_bits"]
    )
    e = float(np.ceil(ema_bits / bw).sum())
    c = float(rec["compute_cycles"].sum())
    u = float(rec["update_cycles"].sum())
    return max(c, e, u), c + e + u
