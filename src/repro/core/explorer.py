"""Top-level hardware-mapping co-exploration API (paper Fig. 3).

``co_explore`` is the tool a designer calls: given a macro, a workload, an
area budget and an optimization target, it returns the optimal accelerator
sizing (MR, MC, SCR, IS_SIZE, OS_SIZE) together with the optimal per-operator
mapping strategy and PPA metrics.  Mapping exploration (the per-operator
8-strategy argmin) runs as a sub-process of hardware exploration, exactly as
in the paper's workflow.

The search method is pluggable (``repro.search``):
  * ``sa``          -- the paper's simulated annealing (vectorized chains);
  * ``genetic``     -- tournament-selection GA with uniform crossover and
    axis-index mutation;
  * ``evolution``   -- discrete differential evolution (rand/1/bin);
  * ``sobol``       -- scrambled quasi-random baseline;
  * ``portfolio``   -- successive-halving race over the backends above,
    per job (winner gets the remaining budget);
  * ``exhaustive``  -- ground truth over the pruned space (feasible because
    the whole evaluation is one vmapped jnp expression); used to validate
    backend quality in tests and available to users for small spaces.

Custom backends registered via ``repro.search.register_backend`` become
valid ``method=`` values immediately.  Backend-specific settings go in
``settings=`` (e.g. ``GASettings``); ``sa_settings`` remains the SA
spelling.

Everything here is a thin synchronous client of the process-wide async DSE
service (``repro.service``): a single call submits a batch of one, so
repeated/interleaved callers share the engine's executable cache, identical
in-flight submissions dedup onto one evaluation, and repeated queries across
processes hit the persistent result store instead of re-annealing.  Passing
``engine=`` explicitly bypasses the service and dispatches directly on that
engine (no queue, no store) -- the escape hatch for benchmarking and for
callers that manage their own batches.  Sweep-style consumers should either
build ``ExploreJob`` lists for ``ExplorationEngine.run`` or submit them to
the service and consume ``repro.service.as_completed`` to stream results.
"""
from __future__ import annotations

import numpy as np

from repro.core.annealing import SASettings
from repro.core.calibration import TechConstants, resolve_tech
from repro.core.engine import (
    ExplorationEngine,
    ExploreJob,
    ExploreResult,
)
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace
from repro.core.strategies import ALL_STRATEGIES
from repro.core.template import AcceleratorConfig

__all__ = [
    "ExploreResult",
    "co_explore",
    "co_explore_macros",
    "pareto_explore",
    "pareto_frontier_from_values",
    "evaluate_config",
]


def _run_jobs(
    jobs: list[ExploreJob],
    method: str,
    sa_settings: SASettings | None,
    engine: ExplorationEngine | None,
    settings=None,
) -> list[ExploreResult]:
    """Dispatch a job list: direct engine call when the caller supplied an
    engine, otherwise through the process-wide service (micro-batching,
    in-flight dedup, persistent result store)."""
    if settings is None and method == "sa":
        settings = sa_settings
    if engine is not None:
        return engine.run(jobs, method=method, settings=settings)
    from repro.service.client import default_service
    return default_service().explore(jobs, method=method, settings=settings)


def co_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    objective: str = "ee",
    strategy_set: str = "st",
    method: str = "sa",
    space: DesignSpace | None = None,
    fixed: dict | None = None,
    bw: int = 256,
    tech: TechConstants | None = None,
    sa_settings: SASettings = SASettings(),
    merge_ops: bool = True,
    engine: ExplorationEngine | None = None,
    settings=None,
) -> ExploreResult:
    """Single-job co-exploration (batch of one on the shared engine).

    ``method`` accepts any registered ``repro.search`` backend name or
    ``"exhaustive"``; ``settings`` carries that backend's settings object
    (``sa_settings`` is the SA-specific spelling, kept for back-compat).
    """
    space = space or DesignSpace()
    if fixed:
        space = space.fix(**fixed)
    tech = resolve_tech(tech)
    job = ExploreJob(
        macro=macro, workload=workload, area_budget_mm2=area_budget_mm2,
        objective=objective, strategy_set=strategy_set, bw=bw, tech=tech,
        space=space, merge_ops=merge_ops, search_method=method,
    )
    return _run_jobs([job], method, sa_settings, engine, settings)[0]


def co_explore_macros(
    macros: list[MacroSpec],
    workload: Workload,
    area_budget_mm2: float,
    engine: ExplorationEngine | None = None,
    **kw,
) -> tuple[ExploreResult, list[ExploreResult]]:
    """Macro-library co-exploration: the paper fixes the macro during
    accelerator exploration; this wrapper additionally selects the best
    macro *family* from a library under the same budget/objective (the
    AutoDCIM-style outer loop the paper cites as complementary).

    The per-macro jobs run as ONE engine batch (macro constants are per-job
    arrays inside a shared executable).  Returns (best result, all
    per-macro results)."""
    objective = kw.get("objective", "ee")
    method = kw.pop("method", "sa")
    sa_settings = kw.pop("sa_settings", SASettings())
    settings = kw.pop("settings", None)
    space = kw.pop("space", None) or DesignSpace()
    fixed = kw.pop("fixed", None)
    if fixed:
        space = space.fix(**fixed)
    jobs = [
        ExploreJob(macro=m, workload=workload,
                   area_budget_mm2=area_budget_mm2, space=space,
                   search_method=method, **kw)
        for m in macros
    ]
    results = _run_jobs(jobs, method, sa_settings, engine, settings)
    key = (lambda r: -r.metrics["tops_w"]) if objective == "ee" else \
        (lambda r: -r.metrics["gops"]) if objective == "th" else \
        (lambda r: r.metrics["latency_s"] * r.metrics["energy_pj"])
    best = min(results, key=key)
    return best, results


def pareto_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    strategy_set: str = "st",
    space: DesignSpace | None = None,
    bw: int = 256,
    tech: TechConstants | None = None,
    engine: ExplorationEngine | None = None,
) -> list[dict]:
    """Energy-efficiency vs throughput Pareto frontier over the pruned
    hardware space (the EE./Th. columns of Table II are this frontier's two
    endpoints).  Returns frontier points sorted by throughput, each with
    config + metrics.

    Each metric gets its own best mapping (the per-operator argmin is
    objective-dependent), so this is a two-job engine batch -- "th" and
    "ee" sweep the same candidate list inside one compiled executable."""
    from repro.core.pruning import candidates_with_bw, prune_space

    space = space or DesignSpace()
    tech = resolve_tech(tech)
    cands, _ = prune_space(space, macro, area_budget_mm2, bw, tech)
    if len(cands) == 0:
        raise ValueError("no feasible hardware point under budget")
    rows = candidates_with_bw(cands, bw)

    jobs = [
        ExploreJob(macro=macro, workload=workload,
                   area_budget_mm2=area_budget_mm2, objective=obj,
                   strategy_set=strategy_set, bw=bw, tech=tech, space=space)
        for obj in ("th", "ee")
    ]
    # pruned candidates respect budget+bandwidth, so the job objective
    # degenerates to exactly total latency ("th") / total energy ("ee")
    if engine is not None:
        lat, en = engine.candidate_values(jobs, [rows, rows])
    else:
        from repro.service.client import default_service
        svc = default_service()
        futures = [svc.submit_values(j, rows) for j in jobs]
        lat, en = (np.asarray(f.result()) for f in futures)
    return pareto_frontier_from_values(cands, lat, en, workload, macro, bw)


def pareto_frontier_from_values(
    cands: np.ndarray,
    lat: np.ndarray,
    en: np.ndarray,
    workload: Workload,
    macro: MacroSpec,
    bw: int,
) -> list[dict]:
    """Frontier points (maximize GOPS and TOPS/W jointly) from per-candidate
    total latency / total energy sweeps; shared by :func:`pareto_explore`
    and the service's streaming ``stream_pareto``."""
    wl = workload.merged()
    total_ops = float(wl.total_ops)
    gops = total_ops / (lat / (macro.freq_mhz * 1e6)) / 1e9
    tops_w = total_ops / (en * 1e-12) / 1e12

    order = np.argsort(-gops)
    frontier = []
    best_ee = -np.inf
    for i in order:
        if tops_w[i] > best_ee:
            best_ee = tops_w[i]
            frontier.append({
                "config": AcceleratorConfig(*[int(v) for v in cands[i]],
                                            bw=bw),
                "gops": float(gops[i]),
                "tops_w": float(tops_w[i]),
            })
    return frontier


def evaluate_config(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    workload: Workload,
    objective: str = "ee",
    strategy_set: str = "st",
    tech: TechConstants | None = None,
) -> dict:
    """PPA of a *given* accelerator on a workload (used for the Table II
    baselines and for Fig. 8's fixed-hardware breakdowns)."""
    import jax.numpy as jnp

    from repro.core import cost_model

    wl = workload.merged()
    cfg_row = jnp.asarray(
        [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw], dtype=float
    )
    m = cost_model.workload_metrics(
        wl.as_arrays(), cfg_row, macro, tech, objective, strategy_set
    )
    m["per_op_strategy"] = {
        op.name or f"op{i}": str(ALL_STRATEGIES[m["strategy_idx"][i]])
        for i, op in enumerate(wl.ops)
    }
    del m["strategy_idx"]
    return m
