"""Top-level hardware-mapping co-exploration API (paper Fig. 3).

``co_explore`` is the tool a designer calls: given a macro, a workload, an
area budget and an optimization target, it returns the optimal accelerator
sizing (MR, MC, SCR, IS_SIZE, OS_SIZE) together with the optimal per-operator
mapping strategy and PPA metrics.  Mapping exploration (the per-operator
8-strategy argmin) runs as a sub-process of hardware exploration, exactly as
in the paper's workflow.

Two search methods:
  * ``sa``          -- the paper's simulated annealing (vectorized chains);
  * ``exhaustive``  -- ground truth over the pruned space (feasible because
    the whole evaluation is one vmapped jnp expression); used to validate SA
    quality in tests and available to users for small spaces.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.annealing import SAResult, SASettings, exhaustive_search, simulated_annealing
from repro.core.calibration import DEFAULT_TECH, TechConstants
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace, candidates_with_bw, prune_space
from repro.core.strategies import ALL_STRATEGIES, Strategy
from repro.core.template import AcceleratorConfig, accelerator_area_mm2


@dataclasses.dataclass
class ExploreResult:
    config: AcceleratorConfig
    macro: MacroSpec
    workload: str
    objective: str
    strategy_set: str
    per_op_strategy: dict[str, str]
    metrics: dict
    search: dict                      # method, runtime, space stats
    sa: SAResult | None = None

    def summary(self) -> str:
        c = self.config
        return (
            f"[{self.workload} | {self.macro.name} | {self.objective}/"
            f"{self.strategy_set}] (MR,MC,SCR,IS,OS)="
            f"({c.mr},{c.mc},{c.scr},{c.is_kb},{c.os_kb}) "
            f"EE={self.metrics['tops_w']:.2f} TOPS/W "
            f"Th={self.metrics['gops']:.1f} GOPS "
            f"area={self.metrics['area_mm2']:.2f} mm^2"
        )


def co_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    objective: str = "ee",
    strategy_set: str = "st",
    method: str = "sa",
    space: DesignSpace | None = None,
    fixed: dict | None = None,
    bw: int = 256,
    tech: TechConstants = DEFAULT_TECH,
    sa_settings: SASettings = SASettings(),
    merge_ops: bool = True,
) -> ExploreResult:
    t_start = time.perf_counter()
    space = space or DesignSpace()
    if fixed:
        space = space.fix(**fixed)
    wl = workload.merged() if merge_ops else workload
    ops_arr = wl.as_arrays()

    objective_fn = cost_model.make_objective_fn(
        ops_arr, macro, tech, objective, strategy_set,
        area_budget_mm2=area_budget_mm2,
    )

    sa_result = None
    search_stats: dict = {"method": method, "merged_ops": len(wl.ops),
                          "raw_ops": len(workload.ops)}
    if method == "sa":
        sa_result = simulated_annealing(objective_fn, space, bw, sa_settings)
        best_cfg = np.asarray(sa_result.best_cfg)
        # SA walks the raw grid with an area penalty; snap-verify feasibility
        cfg = AcceleratorConfig(*[int(round(v)) for v in best_cfg[:5]], bw=bw)
        if accelerator_area_mm2(cfg, macro, tech) > area_budget_mm2 * 1.001:
            # fall back to best feasible neighbour via exhaustive over the
            # pruned space (rare: penalty almost always keeps SA in budget)
            cands, stats = prune_space(space, macro, area_budget_mm2, bw, tech)
            search_stats.update(stats)
            if len(cands) == 0:
                raise ValueError("no feasible hardware point under budget")
            best_row, _ = exhaustive_search(
                objective_fn, candidates_with_bw(cands, bw)
            )
            cfg = AcceleratorConfig(*[int(v) for v in best_row[:5]], bw=bw)
    elif method == "exhaustive":
        cands, stats = prune_space(space, macro, area_budget_mm2, bw, tech)
        search_stats.update(stats)
        if len(cands) == 0:
            raise ValueError("no feasible hardware point under budget")
        best_row, _ = exhaustive_search(
            objective_fn, candidates_with_bw(cands, bw)
        )
        cfg = AcceleratorConfig(*[int(v) for v in best_row[:5]], bw=bw)
    else:
        raise ValueError(f"unknown method {method!r}")

    cfg_row = jnp.asarray(
        [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw], dtype=float
    )
    metrics = cost_model.workload_metrics(
        ops_arr, cfg_row, macro, tech, objective, strategy_set
    )
    per_op = {
        op.name or f"op{i}": str(ALL_STRATEGIES[metrics["strategy_idx"][i]])
        for i, op in enumerate(wl.ops)
    }
    search_stats["runtime_s"] = time.perf_counter() - t_start
    return ExploreResult(
        config=cfg,
        macro=macro,
        workload=workload.name,
        objective=objective,
        strategy_set=strategy_set,
        per_op_strategy=per_op,
        metrics={k: v for k, v in metrics.items() if k != "strategy_idx"},
        search=search_stats,
        sa=sa_result,
    )


def co_explore_macros(
    macros: list[MacroSpec],
    workload: Workload,
    area_budget_mm2: float,
    **kw,
) -> tuple[ExploreResult, list[ExploreResult]]:
    """Macro-library co-exploration: the paper fixes the macro during
    accelerator exploration; this wrapper additionally selects the best
    macro *family* from a library under the same budget/objective (the
    AutoDCIM-style outer loop the paper cites as complementary).

    Returns (best result, all per-macro results)."""
    results = [co_explore(m, workload, area_budget_mm2, **kw)
               for m in macros]
    objective = kw.get("objective", "ee")
    key = (lambda r: -r.metrics["tops_w"]) if objective == "ee" else \
        (lambda r: -r.metrics["gops"]) if objective == "th" else \
        (lambda r: r.metrics["latency_s"] * r.metrics["energy_pj"])
    best = min(results, key=key)
    return best, results


def pareto_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    strategy_set: str = "st",
    space: DesignSpace | None = None,
    bw: int = 256,
    tech: TechConstants = DEFAULT_TECH,
) -> list[dict]:
    """Energy-efficiency vs throughput Pareto frontier over the pruned
    hardware space (the EE./Th. columns of Table II are this frontier's two
    endpoints).  Returns frontier points sorted by throughput, each with
    config + metrics."""
    import jax

    space = space or DesignSpace()
    wl = workload.merged()
    ops_arr = jnp.asarray(wl.as_arrays())
    cands, _ = prune_space(space, macro, area_budget_mm2, bw, tech)
    if len(cands) == 0:
        raise ValueError("no feasible hardware point under budget")
    rows = jnp.asarray(candidates_with_bw(cands, bw))

    def eval_one(cfg_row):
        # each metric gets its own best mapping (the per-operator argmin is
        # objective-dependent)
        lat_th, _en1, _ = cost_model.workload_cost(
            ops_arr, cfg_row, macro, tech, "th", strategy_set)
        _lat2, en_ee, _ = cost_model.workload_cost(
            ops_arr, cfg_row, macro, tech, "ee", strategy_set)
        return lat_th, en_ee

    lat, en = jax.jit(jax.vmap(eval_one))(rows)
    lat, en = np.asarray(lat), np.asarray(en)
    total_ops = float(wl.total_ops)
    gops = total_ops / (lat / (macro.freq_mhz * 1e6)) / 1e9
    tops_w = total_ops / (en * 1e-12) / 1e12

    # Pareto: maximize both gops and tops_w
    order = np.argsort(-gops)
    frontier = []
    best_ee = -np.inf
    for i in order:
        if tops_w[i] > best_ee:
            best_ee = tops_w[i]
            frontier.append({
                "config": AcceleratorConfig(*[int(v) for v in cands[i]],
                                            bw=bw),
                "gops": float(gops[i]),
                "tops_w": float(tops_w[i]),
            })
    return frontier


def evaluate_config(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    workload: Workload,
    objective: str = "ee",
    strategy_set: str = "st",
    tech: TechConstants = DEFAULT_TECH,
) -> dict:
    """PPA of a *given* accelerator on a workload (used for the Table II
    baselines and for Fig. 8's fixed-hardware breakdowns)."""
    wl = workload.merged()
    cfg_row = jnp.asarray(
        [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw], dtype=float
    )
    m = cost_model.workload_metrics(
        wl.as_arrays(), cfg_row, macro, tech, objective, strategy_set
    )
    m["per_op_strategy"] = {
        op.name or f"op{i}": str(ALL_STRATEGIES[m["strategy_idx"][i]])
        for i, op in enumerate(wl.ops)
    }
    del m["strategy_idx"]
    return m
