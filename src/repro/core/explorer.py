"""Top-level hardware-mapping co-exploration API (paper Fig. 3).

``co_explore`` is the tool a designer calls: given a macro, a workload, an
area budget and an optimization target, it returns the optimal accelerator
sizing (MR, MC, SCR, IS_SIZE, OS_SIZE) together with the optimal per-operator
mapping strategy and PPA metrics.  Mapping exploration (the per-operator
8-strategy argmin) runs as a sub-process of hardware exploration, exactly as
in the paper's workflow.

Two search methods:
  * ``sa``          -- the paper's simulated annealing (vectorized chains);
  * ``exhaustive``  -- ground truth over the pruned space (feasible because
    the whole evaluation is one vmapped jnp expression); used to validate SA
    quality in tests and available to users for small spaces.

Everything here is a thin wrapper over the batched exploration engine
(``core/engine.py``): a single job is just a batch of one, so repeated calls
share the engine's executable cache, and sweep-style consumers should build
``ExploreJob`` lists and call ``ExplorationEngine.run`` directly to amortize
compilation AND dispatch across the whole sweep.
"""
from __future__ import annotations

import numpy as np

from repro.core.annealing import SASettings
from repro.core.calibration import DEFAULT_TECH, TechConstants
from repro.core.engine import (
    ExplorationEngine,
    ExploreJob,
    ExploreResult,
    default_engine,
)
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace
from repro.core.strategies import ALL_STRATEGIES
from repro.core.template import AcceleratorConfig

__all__ = [
    "ExploreResult",
    "co_explore",
    "co_explore_macros",
    "pareto_explore",
    "evaluate_config",
]


def co_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    objective: str = "ee",
    strategy_set: str = "st",
    method: str = "sa",
    space: DesignSpace | None = None,
    fixed: dict | None = None,
    bw: int = 256,
    tech: TechConstants = DEFAULT_TECH,
    sa_settings: SASettings = SASettings(),
    merge_ops: bool = True,
    engine: ExplorationEngine | None = None,
) -> ExploreResult:
    """Single-job co-exploration (batch of one on the shared engine)."""
    space = space or DesignSpace()
    if fixed:
        space = space.fix(**fixed)
    job = ExploreJob(
        macro=macro, workload=workload, area_budget_mm2=area_budget_mm2,
        objective=objective, strategy_set=strategy_set, bw=bw, tech=tech,
        space=space, merge_ops=merge_ops,
    )
    eng = engine or default_engine()
    return eng.run([job], method=method, sa_settings=sa_settings)[0]


def co_explore_macros(
    macros: list[MacroSpec],
    workload: Workload,
    area_budget_mm2: float,
    engine: ExplorationEngine | None = None,
    **kw,
) -> tuple[ExploreResult, list[ExploreResult]]:
    """Macro-library co-exploration: the paper fixes the macro during
    accelerator exploration; this wrapper additionally selects the best
    macro *family* from a library under the same budget/objective (the
    AutoDCIM-style outer loop the paper cites as complementary).

    The per-macro jobs run as ONE engine batch (macro constants are per-job
    arrays inside a shared executable).  Returns (best result, all
    per-macro results)."""
    objective = kw.get("objective", "ee")
    method = kw.pop("method", "sa")
    sa_settings = kw.pop("sa_settings", SASettings())
    space = kw.pop("space", None) or DesignSpace()
    fixed = kw.pop("fixed", None)
    if fixed:
        space = space.fix(**fixed)
    jobs = [
        ExploreJob(macro=m, workload=workload,
                   area_budget_mm2=area_budget_mm2, space=space, **kw)
        for m in macros
    ]
    eng = engine or default_engine()
    results = eng.run(jobs, method=method, sa_settings=sa_settings)
    key = (lambda r: -r.metrics["tops_w"]) if objective == "ee" else \
        (lambda r: -r.metrics["gops"]) if objective == "th" else \
        (lambda r: r.metrics["latency_s"] * r.metrics["energy_pj"])
    best = min(results, key=key)
    return best, results


def pareto_explore(
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    strategy_set: str = "st",
    space: DesignSpace | None = None,
    bw: int = 256,
    tech: TechConstants = DEFAULT_TECH,
    engine: ExplorationEngine | None = None,
) -> list[dict]:
    """Energy-efficiency vs throughput Pareto frontier over the pruned
    hardware space (the EE./Th. columns of Table II are this frontier's two
    endpoints).  Returns frontier points sorted by throughput, each with
    config + metrics.

    Each metric gets its own best mapping (the per-operator argmin is
    objective-dependent), so this is a two-job engine batch -- "th" and
    "ee" sweep the same candidate list inside one compiled executable."""
    from repro.core.pruning import candidates_with_bw, prune_space

    space = space or DesignSpace()
    wl = workload.merged()
    cands, _ = prune_space(space, macro, area_budget_mm2, bw, tech)
    if len(cands) == 0:
        raise ValueError("no feasible hardware point under budget")
    rows = candidates_with_bw(cands, bw)

    jobs = [
        ExploreJob(macro=macro, workload=workload,
                   area_budget_mm2=area_budget_mm2, objective=obj,
                   strategy_set=strategy_set, bw=bw, tech=tech, space=space)
        for obj in ("th", "ee")
    ]
    eng = engine or default_engine()
    # pruned candidates respect budget+bandwidth, so the job objective
    # degenerates to exactly total latency ("th") / total energy ("ee")
    lat, en = eng.candidate_values(jobs, [rows, rows])

    total_ops = float(wl.total_ops)
    gops = total_ops / (lat / (macro.freq_mhz * 1e6)) / 1e9
    tops_w = total_ops / (en * 1e-12) / 1e12

    # Pareto: maximize both gops and tops_w
    order = np.argsort(-gops)
    frontier = []
    best_ee = -np.inf
    for i in order:
        if tops_w[i] > best_ee:
            best_ee = tops_w[i]
            frontier.append({
                "config": AcceleratorConfig(*[int(v) for v in cands[i]],
                                            bw=bw),
                "gops": float(gops[i]),
                "tops_w": float(tops_w[i]),
            })
    return frontier


def evaluate_config(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    workload: Workload,
    objective: str = "ee",
    strategy_set: str = "st",
    tech: TechConstants = DEFAULT_TECH,
) -> dict:
    """PPA of a *given* accelerator on a workload (used for the Table II
    baselines and for Fig. 8's fixed-hardware breakdowns)."""
    import jax.numpy as jnp

    from repro.core import cost_model

    wl = workload.merged()
    cfg_row = jnp.asarray(
        [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw], dtype=float
    )
    m = cost_model.workload_metrics(
        wl.as_arrays(), cfg_row, macro, tech, objective, strategy_set
    )
    m["per_op_strategy"] = {
        op.name or f"op{i}": str(ALL_STRATEGIES[m["strategy_idx"][i]])
        for i, op in enumerate(wl.ops)
    }
    del m["strategy_idx"]
    return m
