"""Closed-form latency/energy cost model for the generalized accelerator
template, covering all 8 mapping strategies (paper Sec. III-B/III-C).

The model is written as pure ``jnp`` arithmetic over scalars so that a single
``vmap`` stack evaluates *candidates x operators x strategies* in one shot --
this is what lets the hardware-mapping co-exploration be jitted, vmapped over
SA chains, batched over whole job lists (``core/engine.py``) and sharded over
a pod (``core/distributed.py``).

Macro and technology constants come in two flavours:

* static -- a :class:`~repro.core.macro.MacroSpec` / ``TechConstants`` pair
  (python scalars baked into the trace), the paper's fixed-macro workflow;
* traced -- :class:`MacroParams` / :class:`TechParams` NamedTuples whose
  leaves are arrays, so one jitted executable can evaluate *different*
  macros/technologies per job (the batched engine vmaps over a stacked job
  axis).  Both flavours run the identical formulas below.

Loop-nest semantics (NR orientation; R swaps M<->N and streamed/stationary
data widths).  ``V`` = streamed matrix (M x K, via Input SRAM), ``S`` =
stationary matrix (K x N, resident in CIM planes), output M x N via Output
SRAM.  The macro grid covers a physical tile of ``Kp x Np`` per plane
(Kp = MR*AL, Np = MC*PC); S is tiled into tK x tN planes; SCR planes are
co-resident.

    IP-AF:  for n_tile(tN): for k_group(G=ceil(tK/SCR)): for m: for plane
    IP-PF:  for n_group(H=ceil(tN/SCR)): for k_tile(tK): for m: for plane
    WP-AF:  for m_batch(B): for n_tile: for k_group: for m: for plane
    WP-PF:  for m_batch(B): for n_group: for k_tile: for m: for plane

Traffic/latency identities implemented below are matched *exactly* (integer
for integer) by the instruction-flow compiler's schedule sums
(``core/compiler.py``) -- property-tested in tests/test_cost_vs_compiler.py.
Latency uses a global three-stage-pipeline overlap bound; the cycle-accurate
simulator's per-set latency is sandwiched between the model's overlapped and
non-overlapped bounds (tests/test_simulator.py).

All arithmetic is float; run under ``repro.compat.enable_x64`` for exact
integer semantics (counts < 2^53), float32 otherwise (plenty for SA ordering).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp

from repro.core.calibration import TechConstants, resolve_tech
from repro.core.macro import MacroSpec
from repro.core.strategies import ALL_STRATEGIES, STRATEGY_SETS

INFEASIBLE = 1e30

#: objective encodings shared by the string API and the traced batched API
OBJ_CODES: dict[str, int] = {"ee": 0, "th": 1, "edp": 2}


class MacroParams(typing.NamedTuple):
    """Traced-friendly view of a :class:`MacroSpec` (+ its energy override).

    Leaves are python floats in the static path and (possibly stacked)
    arrays in the batched path -- the cost formulas accept either.
    """

    al: typing.Any
    pc: typing.Any
    icw: typing.Any
    wuw: typing.Any
    dw_in: typing.Any
    dw_w: typing.Any
    dw_psum: typing.Any
    dw_out: typing.Any
    freq_mhz: typing.Any
    update_during_compute: typing.Any   # 0.0 / 1.0 ping-pong capability
    mac_e_pj: typing.Any                # per-MAC energy (macro override baked)


class TechParams(typing.NamedTuple):
    """Traced-friendly view of :class:`TechConstants` (energy/area/leakage)."""

    e_cim_update_pj_bit: typing.Any
    e_sram_rd_pj_bit: typing.Any
    e_sram_wr_pj_bit: typing.Any
    e_ema_pj_bit: typing.Any
    sys_energy_overhead: typing.Any
    p_leak_mw_mm2: typing.Any
    a_cell_um2_bit: typing.Any
    a_cu_um2: typing.Any
    a_macro_fixed_mm2: typing.Any
    a_sram_mm2_per_mb: typing.Any
    a_sram_fixed_mm2: typing.Any
    a_fixed_mm2: typing.Any


def macro_params(macro: MacroSpec,
                 tech: TechConstants | None = None) -> MacroParams:
    """Scalar (python-float) params of a macro -- the static baked path."""
    tech = resolve_tech(tech)
    return MacroParams(
        al=float(macro.al), pc=float(macro.pc),
        icw=float(macro.icw), wuw=float(macro.wuw),
        dw_in=float(macro.dw_in), dw_w=float(macro.dw_w),
        dw_psum=float(macro.dw_psum), dw_out=float(macro.dw_out),
        freq_mhz=float(macro.freq_mhz),
        update_during_compute=float(macro.update_during_compute),
        mac_e_pj=float(macro.mac_energy_pj(tech)),
    )


def tech_params(tech: TechConstants | None = None) -> TechParams:
    tech = resolve_tech(tech)
    return TechParams(
        e_cim_update_pj_bit=float(tech.e_cim_update_pj_bit),
        e_sram_rd_pj_bit=float(tech.e_sram_rd_pj_bit),
        e_sram_wr_pj_bit=float(tech.e_sram_wr_pj_bit),
        e_ema_pj_bit=float(tech.e_ema_pj_bit),
        sys_energy_overhead=float(tech.sys_energy_overhead),
        p_leak_mw_mm2=float(tech.p_leak_mw_mm2),
        a_cell_um2_bit=float(tech.a_cell_um2_bit),
        a_cu_um2=float(tech.a_cu_um2),
        a_macro_fixed_mm2=float(tech.a_macro_fixed_mm2),
        a_sram_mm2_per_mb=float(tech.a_sram_mm2_per_mb),
        a_sram_fixed_mm2=float(tech.a_sram_fixed_mm2),
        a_fixed_mm2=float(tech.a_fixed_mm2),
    )


def _as_params(macro, tech):
    """Normalize (MacroSpec|MacroParams, TechConstants|TechParams|None)."""
    mp = macro if isinstance(macro, MacroParams) else macro_params(
        macro, tech if isinstance(tech, TechConstants) else None)
    tp = tech if isinstance(tech, TechParams) else tech_params(
        tech if isinstance(tech, TechConstants) else None)
    return mp, tp


def objective_code(objective) -> typing.Any:
    """Map "ee"/"th"/"edp" to its integer code; pass traced codes through."""
    if isinstance(objective, str):
        try:
            return OBJ_CODES[objective]
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r}; "
                f"expected one of {sorted(OBJ_CODES)}") from None
    return objective


def _score(lat, en, code):
    """Per-objective scalar score (lower is better); ``code`` may be traced."""
    return jnp.where(code == OBJ_CODES["th"], lat,
                     jnp.where(code == OBJ_CODES["edp"], lat * en, en))


def _ceil(a, b):
    return jnp.ceil(a / b)


def _fdiv(a, b):
    return jnp.floor(a / b)


class CostBreakdown(typing.NamedTuple):
    """Per-operator-call cost terms (cycles, bits, pJ)."""

    latency_cycles: jax.Array
    compute_cycles: jax.Array
    update_cycles: jax.Array
    ema_cycles: jax.Array
    ema_bits: jax.Array          # total external traffic
    v_ema_bits: jax.Array        # streamed-matrix fetch
    s_ema_bits: jax.Array        # stationary-matrix (CIM update) fetch
    spill_ema_bits: jax.Array    # psum spills
    y_ema_bits: jax.Array        # output writeback
    is_rd_bits: jax.Array
    is_wr_bits: jax.Array
    os_rd_bits: jax.Array
    os_wr_bits: jax.Array
    update_bits: jax.Array       # CIM write traffic (== s_ema_bits)
    macs: jax.Array              # padded MACs actually executed
    energy_pj: jax.Array
    feasible: jax.Array


def matmul_cost(
    # operator (already oriented? no -- raw op dims)
    m, k, n,
    # strategy bits (0/1 floats): reversed, weight_priority, parallel_first
    rev, wp, pf,
    # accelerator config
    mr, mc, scr, is_kb, os_kb, bw, area_mm2,
    # macro (MacroSpec = static python constants, MacroParams = traceable)
    macro,
    tech=None,
) -> CostBreakdown:
    """Cost of one (m x k) @ (k x n) call under one strategy on one config.

    With a ``MacroSpec``/``TechConstants`` pair the macro constants are
    static (python) -- the paper fixes the macro during accelerator
    exploration.  With ``MacroParams``/``TechParams`` they may be traced and
    vmapped like everything else (the batched engine's per-job macros).
    """
    mp, tp = _as_params(macro, tech)
    one = jnp.float32(1.0).astype(jnp.result_type(float))
    m, k, n = (jnp.asarray(x) * one for x in (m, k, n))
    rev, wp, pf = (jnp.asarray(x) * one for x in (rev, wp, pf))
    mr, mc, scr = (jnp.asarray(x) * one for x in (mr, mc, scr))
    is_bits = jnp.asarray(is_kb) * one * 1024.0 * 8.0
    os_bits = jnp.asarray(os_kb) * one * 1024.0 * 8.0
    bw = jnp.asarray(bw) * one

    # ---- spatial scheduling: orientation + data widths -------------------
    M = jnp.where(rev > 0, n, m)
    N = jnp.where(rev > 0, m, n)
    K = k
    dws = jnp.where(rev > 0, mp.dw_w, mp.dw_in)   # streamed operand width
    dwt = jnp.where(rev > 0, mp.dw_in, mp.dw_w)   # stationary operand width
    dw_psum = mp.dw_psum
    dw_out = mp.dw_out

    # per-plane-op / per-plane-update cycles (eqns 3-5); depend on which
    # operand streams through the input drivers
    cyc_c = jnp.maximum(1.0, _ceil(dws * mp.al, mp.icw))
    cyc_u = jnp.maximum(1.0, _ceil(mp.al * dwt, mp.wuw))

    # ---- geometry ---------------------------------------------------------
    Kp = mr * mp.al
    Np = mc * mp.pc
    tK = _ceil(K, Kp)
    tN = _ceil(N, Np)
    Kpad = tK * Kp
    Npad = tN * Np
    planes = tK * tN

    G = _ceil(tK, scr)                      # AF groups per output column
    H = _ceil(tN, scr)                      # PF groups per K tile
    remN = tN - (H - 1.0) * scr             # planes in last PF group
    scr_n = jnp.minimum(scr, tN)

    # ---- Input SRAM residency --------------------------------------------
    # WP keeps full rows (width Kpad) resident across the whole weight sweep.
    rows_res_raw = _fdiv(is_bits, Kpad * dws)
    wp_feasible = rows_res_raw >= 1.0
    rows_res = jnp.clip(rows_res_raw, 1.0, M)
    B = _ceil(M, rows_res)                  # WP input batches
    remB = M - (B - 1.0) * rows_res         # rows in last batch
    # minimal functional IS requirement: one plane-chunk of the streamed row
    is_feasible = is_bits >= Kp * dws
    fits_all_v = M * Kpad * dws <= is_bits  # whole streamed matrix cached

    # ---- streamed-matrix (V) external traffic ----------------------------
    v_refetch_ip = jnp.where(fits_all_v, 1.0, jnp.where(pf > 0, H, tN))
    v_bits = M * Kpad * dws * jnp.where(wp > 0, 1.0, v_refetch_ip)

    # ---- stationary-matrix (S) external traffic + CIM updates ------------
    fits_all_s = planes <= scr
    s_loads = planes * jnp.where(
        (wp > 0) & ~fits_all_s, B, 1.0
    )                                        # plane loads from DRAM
    s_bits = s_loads * Kp * Np * dwt
    update_cycles = s_loads * cyc_u

    # ---- compute ----------------------------------------------------------
    compute_cycles = M * planes * cyc_c      # strategy-invariant
    macs = M * Kpad * Npad                   # padded MACs executed

    # ---- Input SRAM access ------------------------------------------------
    is_wr = v_bits                            # every fetched bit lands in IS
    # reads are compute-driven; PF reuses the row chunk across the group
    is_rd = M * Kpad * dws * jnp.where(pf > 0, H, tN)

    # ---- Output SRAM access + psum spills --------------------------------
    # AF: psum row width Np, accumulation transitions (G-1) per output column
    os_rows_af = _fdiv(os_bits, Np * dw_psum)
    # PF: psum working-set width q*Np for a group of q planes
    def _os_rows_pf(q):
        return _fdiv(os_bits, q * Np * dw_psum)

    def _spill(workrows, osrows):
        return jnp.maximum(0.0, workrows - osrows)

    # --- AF spills ---
    spill_af_ip = 2.0 * (G - 1.0) * _spill(M, os_rows_af) * Np * dw_psum * tN
    spill_af_wp = (
        2.0 * (G - 1.0) * Np * dw_psum * tN
        * ((B - 1.0) * _spill(rows_res, os_rows_af) + _spill(remB, os_rows_af))
    )
    spill_af = jnp.where(wp > 0, spill_af_wp, spill_af_ip)

    # --- PF spills (full groups of width scr_n, remainder group remN) ---
    nfull = H - 1.0
    def _pf_spill_rows(workrows):
        return (
            nfull * _spill(workrows, _os_rows_pf(scr_n)) * scr_n
            + _spill(workrows, _os_rows_pf(remN)) * remN
        )
    spill_pf_ip = 2.0 * (tK - 1.0) * Np * dw_psum * _pf_spill_rows(M)
    spill_pf_wp = 2.0 * (tK - 1.0) * Np * dw_psum * (
        (B - 1.0) * _pf_spill_rows(rows_res) + _pf_spill_rows(remB)
    )
    spill_pf = jnp.where(wp > 0, spill_pf_wp, spill_pf_ip)
    spill_bits = jnp.where(pf > 0, spill_pf, spill_af)

    # --- OS read/write (every psum passes through OS) ---
    groups_per_col = jnp.where(pf > 0, tK, G)   # psum writes per (row, col)
    os_wr = M * tN * groups_per_col * Np * dw_psum
    os_rd = M * tN * (groups_per_col - 1.0) * Np * dw_psum + M * Npad * dw_psum
    os_feasible = os_bits >= Np * dw_psum

    # ---- output writeback --------------------------------------------------
    y_bits = M * Npad * dw_out

    # ---- totals ------------------------------------------------------------
    ema_bits = v_bits + s_bits + spill_bits + y_bits
    ema_cycles = _ceil(ema_bits, bw)

    overlap = mp.update_during_compute * (scr >= 2.0)
    busy = jnp.maximum(compute_cycles, ema_cycles)
    latency = jnp.where(
        overlap,
        jnp.maximum(busy, update_cycles),
        busy + update_cycles,
    )

    feasible = is_feasible & os_feasible & ((wp == 0) | wp_feasible)

    # ---- energy ------------------------------------------------------------
    e_dyn = (
        macs * mp.mac_e_pj
        + s_bits * tp.e_cim_update_pj_bit
        + (is_rd + os_rd) * tp.e_sram_rd_pj_bit
        + (is_wr + os_wr) * tp.e_sram_wr_pj_bit
        + ema_bits * tp.e_ema_pj_bit
    ) * tp.sys_energy_overhead
    lat_s = latency / (mp.freq_mhz * 1e6)
    e_leak = tp.p_leak_mw_mm2 * area_mm2 * lat_s * 1e9  # mW*s -> pJ
    energy = e_dyn + e_leak

    latency = jnp.where(feasible, latency, INFEASIBLE)
    energy = jnp.where(feasible, energy, INFEASIBLE)

    return CostBreakdown(
        latency_cycles=latency,
        compute_cycles=compute_cycles,
        update_cycles=update_cycles,
        ema_cycles=ema_cycles,
        ema_bits=ema_bits,
        v_ema_bits=v_bits,
        s_ema_bits=s_bits,
        spill_ema_bits=spill_bits,
        y_ema_bits=y_bits,
        is_rd_bits=is_rd,
        is_wr_bits=is_wr,
        os_rd_bits=os_rd,
        os_wr_bits=os_wr,
        update_bits=s_bits,
        macs=macs,
        energy_pj=energy,
        feasible=feasible,
    )


# ---------------------------------------------------------------------- #
# vectorized stacks
# ---------------------------------------------------------------------- #
_STRAT_BITS = jnp.array(
    [[float(s.spatial == "R"), float(s.temporal == "WP"),
      float(s.tiling == "PF")] for s in ALL_STRATEGIES]
)  # [8, 3]


def strategy_table(op_row, cfg_row, area_mm2, macro, tech=None):
    """Costs of one op under all 8 strategies.  op_row = (m,k,n,count,static),
    cfg_row = (mr,mc,scr,is_kb,os_kb,bw)."""
    def _one(bits):
        return matmul_cost(
            op_row[0], op_row[1], op_row[2],
            bits[0], bits[1], bits[2],
            cfg_row[0], cfg_row[1], cfg_row[2], cfg_row[3], cfg_row[4],
            cfg_row[5], area_mm2, macro, tech,
        )
    return jax.vmap(_one)(_STRAT_BITS)


def area_mm2_jnp(cfg_row, macro, tech=None):
    """jnp version of template.accelerator_area_mm2 (traced cfg and,
    via MacroParams/TechParams, optionally traced macro/tech)."""
    mp, tp = _as_params(macro, tech)
    mr, mc, scr, is_kb, os_kb = (cfg_row[i] for i in range(5))
    cells = mp.al * mp.pc * scr * mp.dw_w * tp.a_cell_um2_bit
    cus = mp.al * mp.pc * tp.a_cu_um2
    macro_area = (cells + cus) * 1e-6 + tp.a_macro_fixed_mm2
    sram = lambda kb: kb * 8.0 / 1024.0 * tp.a_sram_mm2_per_mb \
        + tp.a_sram_fixed_mm2
    return mr * mc * macro_area + sram(is_kb) + sram(os_kb) + tp.a_fixed_mm2


def bandwidth_ok_jnp(cfg_row, macro):
    mp, _ = _as_params(macro, None)
    bw = cfg_row[5]
    return (mp.icw * cfg_row[0] >= bw) & (
        mp.wuw * cfg_row[0] * cfg_row[1] >= bw
    )


def workload_cost_core(
    ops_arr, cfg_row, strat_bits, allowed, macro,
    tech=None, objective="ee",
):
    """workload_cost with the strategy tables passed in explicitly (lets the
    Pallas strategy_eval kernel feed them through refs instead of capturing
    module-level constants).  ``objective`` may be a string or a (possibly
    traced) integer code from :data:`OBJ_CODES`."""
    mp, tp = _as_params(macro, tech)
    code = objective_code(objective)
    area = area_mm2_jnp(cfg_row, mp, tp)

    def per_op(op_row):
        def _one(bits):
            return matmul_cost(
                op_row[0], op_row[1], op_row[2],
                bits[0], bits[1], bits[2],
                cfg_row[0], cfg_row[1], cfg_row[2], cfg_row[3], cfg_row[4],
                cfg_row[5], area, mp, tp,
            )
        tbl = jax.vmap(_one)(strat_bits)
        lat = jnp.where(allowed > 0, tbl.latency_cycles, INFEASIBLE)
        en = jnp.where(allowed > 0, tbl.energy_pj, INFEASIBLE)
        idx = jnp.argmin(_score(lat, en, code))
        return lat[idx], en[idx], idx

    lat, en, idx = jax.vmap(per_op)(ops_arr)
    counts = ops_arr[:, 3]
    total_lat = jnp.sum(lat * counts)
    total_en = jnp.sum(en * counts)
    return total_lat, total_en, idx


def strategy_mask(strategy_set: str):
    return jnp.array(
        [1.0 if s in STRATEGY_SETS[strategy_set] else 0.0
         for s in ALL_STRATEGIES]
    )


def workload_cost(
    ops_arr,                # [P, 5] (m, k, n, count, static); count==0 -> pad
    cfg_row,                # [6]
    macro,
    tech=None,
    objective="ee",         # "ee" (energy) | "th" (latency) | "edp"
    strategy_set: str = "st",
):
    """Best-strategy-per-operator workload cost on one accelerator config.

    Returns (total_latency_cycles, total_energy_pj, per_op_strategy_idx).
    The per-op argmin implements the fine-grained mapping exploration; the
    restriction mask reproduces the spatial-only baseline of [19].
    """
    return workload_cost_core(
        ops_arr, cfg_row, _STRAT_BITS, strategy_mask(strategy_set),
        macro, tech, objective)


def objective_value(total_lat, total_en, objective):
    """Scalar objective from workload totals; str or integer-code input."""
    return _score(total_lat, total_en, objective_code(objective))


# ---------------------------------------------------------------------- #
# per-job bundles for the batched exploration engine
# ---------------------------------------------------------------------- #
class JobParams(typing.NamedTuple):
    """Everything the objective needs about one job, as traceable leaves.

    Stacking a list of these along axis 0 (``jax.tree.map`` + ``stack``)
    yields the job axis the engine vmaps over; shapes must already agree
    (operator arrays padded to a shared bucket width by the engine).
    """

    ops: typing.Any          # [P, 5] (m, k, n, count, static)
    macro: MacroParams       # scalar leaves
    tech: TechParams         # scalar leaves
    allowed: typing.Any      # [8] strategy mask
    obj_code: typing.Any     # () int32
    area_budget: typing.Any  # () mm^2
    bw: typing.Any           # () external bus bits/cycle


def job_objective(job: JobParams, cfg_row, penalty_scale: float = 1e3):
    """Scalar objective(cfg_row[6]) of one job -- the traced twin of
    :func:`make_objective_fn` (area penalty always on; jobs carry budgets)."""
    lat, en, _ = workload_cost_core(
        job.ops, cfg_row, _STRAT_BITS, job.allowed, job.macro, job.tech,
        job.obj_code)
    val = _score(lat, en, job.obj_code)
    area = area_mm2_jnp(cfg_row, job.macro, job.tech)
    excess = jnp.maximum(0.0, area - job.area_budget) / job.area_budget
    val = val * (1.0 + penalty_scale * excess)
    return jnp.where(bandwidth_ok_jnp(cfg_row, job.macro), val, INFEASIBLE)


def make_objective_fn(
    ops_arr,
    macro,
    tech=None,
    objective="ee",
    strategy_set: str = "st",
    area_budget_mm2: float | None = None,
    penalty_scale: float = 1e3,
):
    """Scalar objective(cfg_row) for the SA / exhaustive explorers.

    Area-budget violation enters as a smooth multiplicative penalty so SA can
    walk the boundary; bandwidth-infeasible configs get the hard INFEASIBLE.
    """
    ops_arr = jnp.asarray(ops_arr)
    mp, tp = _as_params(macro, tech)
    code = objective_code(objective)
    mask = strategy_mask(strategy_set)

    def fn(cfg_row):
        lat, en, _ = workload_cost_core(
            ops_arr, cfg_row, _STRAT_BITS, mask, mp, tp, code
        )
        val = _score(lat, en, code)
        if area_budget_mm2 is not None:
            area = area_mm2_jnp(cfg_row, mp, tp)
            excess = jnp.maximum(0.0, area - area_budget_mm2) / area_budget_mm2
            val = val * (1.0 + penalty_scale * excess)
        val = jnp.where(bandwidth_ok_jnp(cfg_row, mp), val, INFEASIBLE)
        return val

    return fn


def workload_metrics(
    workload_ops_arr,
    cfg_row,
    macro,
    tech=None,
    objective="ee",
    strategy_set: str = "st",
) -> dict:
    """Human-facing PPA metrics for a config (TOPS/W, GOPS, mm^2, ...)."""
    lat, en, idx = workload_cost(
        workload_ops_arr, cfg_row, macro, tech, objective, strategy_set
    )
    ops_arr = jnp.asarray(workload_ops_arr)
    true_ops = 2.0 * jnp.sum(
        ops_arr[:, 0] * ops_arr[:, 1] * ops_arr[:, 2] * ops_arr[:, 3]
    )
    lat_s = lat / (macro.freq_mhz * 1e6)
    energy_j = en * 1e-12
    return {
        "latency_cycles": float(lat),
        "latency_s": float(lat_s),
        "energy_pj": float(en),
        "tops_w": float(true_ops / energy_j / 1e12),
        "gops": float(true_ops / lat_s / 1e9),
        "area_mm2": float(area_mm2_jnp(jnp.asarray(cfg_row), macro, tech)),
        "strategy_idx": [int(i) for i in idx],
    }
