"""Digital systolic-array baseline (paper Fig. 1, scale-sim [1] analogue).

A deliberately simple weight-stationary / input-stationary analytical model
of an R x C MAC array with ifmap/filter/ofmap SRAM buffers and a DRAM bus,
used only to reproduce the paper's motivation figure: under a fixed area
budget, latency is U-shaped in the compute/storage split -- stalls shrink as
the buffer grows until the shrinking array dominates.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.calibration import TechConstants, resolve_tech


@dataclasses.dataclass(frozen=True)
class SystolicConfig:
    rows: int                 # PE rows (K direction)
    cols: int                 # PE cols (N direction)
    buf_kb: int               # the swept buffer (weight or input)
    other_buf_kb: int = 64
    bw_bits: int = 256        # DRAM bus bits / cycle
    dw: int = 8


def systolic_area_mm2(
    cfg: SystolicConfig, tech: TechConstants | None = None
) -> float:
    tech = resolve_tech(tech)
    pe = cfg.rows * cfg.cols * tech.a_cu_um2 * 1e-6
    sram = (cfg.buf_kb + cfg.other_buf_kb) * 8 / 1024.0 * tech.a_sram_mm2_per_mb
    return pe + sram + tech.a_fixed_mm2


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def systolic_latency(
    cfg: SystolicConfig,
    m: int,
    k: int,
    n: int,
    dataflow: str = "ws",     # "ws" weight-stationary | "is" input-stationary
) -> dict:
    """Cycles for (m x k) @ (k x n), scale-sim style tile walk.

    WS: filter tiles (rows x cols) stay in PEs; ifmap rows stream; the weight
    buffer's size sets how many filter tiles are DRAM-resident vs reused.
    IS: symmetric with m <-> n.
    """
    if dataflow == "is":
        m, n = n, m
    tk = _cdiv(k, cfg.rows)
    tn = _cdiv(n, cfg.cols)
    buf_bits = cfg.buf_kb * 1024 * 8

    # compute: each tile processes m rows after a pipeline fill of rows+cols
    compute = tk * tn * (m + cfg.rows + cfg.cols - 1)

    # stationary-operand traffic: every filter tile fetched once
    w_bits = tk * tn * cfg.rows * cfg.cols * cfg.dw
    # streamed-operand refetch factor: if the buffer can't hold the streamed
    # matrix, it is re-fetched for every stationary tile column
    x_bits_once = m * tk * cfg.rows * cfg.dw
    refetch = 1 if x_bits_once <= buf_bits else tn
    x_bits = x_bits_once * refetch
    y_bits = m * tn * cfg.cols * cfg.dw
    dram_cycles = math.ceil((w_bits + x_bits + y_bits) / cfg.bw_bits)

    stall = max(0, dram_cycles - compute)
    return {
        "compute_cycles": compute,
        "dram_cycles": dram_cycles,
        "stall_cycles": stall,
        "total_cycles": compute + stall,
        "refetch": refetch,
    }


def buffer_sweep(
    *,
    area_budget_mm2: float,
    m: int,
    k: int,
    n: int,
    buf_choices_kb=(8, 16, 32, 64, 128, 256, 512, 1024),
    dataflow: str = "ws",
    tech: TechConstants | None = None,
) -> list[dict]:
    """Fig. 1: fixed area budget, sweep buffer size; the PE array takes the
    remaining area (square-ish aspect)."""
    tech = resolve_tech(tech)
    out = []
    for buf in buf_choices_kb:
        sram_mm2 = (buf + 64) * 8 / 1024.0 * tech.a_sram_mm2_per_mb
        pe_mm2 = area_budget_mm2 - sram_mm2 - tech.a_fixed_mm2
        if pe_mm2 <= 0:
            continue
        pes = int(pe_mm2 / (tech.a_cu_um2 * 1e-6))
        side = max(1, int(math.sqrt(pes)))
        cfg = SystolicConfig(rows=side, cols=max(1, pes // side), buf_kb=buf)
        r = systolic_latency(cfg, m, k, n, dataflow)
        r.update(buf_kb=buf, rows=cfg.rows, cols=cfg.cols,
                 area_mm2=systolic_area_mm2(cfg, tech))
        out.append(r)
    return out
