"""Simulated-annealing engine for the hardware-mapping co-exploration
(paper Sec. III-D / IV-A: "hardware configurations are iteratively adjusted
... through the simulated annealing algorithm").

Fully jittable: chains are ``vmap``-ed, steps run under ``lax.scan``, so the
same function drops into ``shard_map`` for the multi-pod distributed DSE
(``core/distributed.py``).  Registered as the ``"sa"`` backend of the
pluggable search subsystem (``repro.search.sa`` adapts :func:`anneal` to
the shared ``SearchBackend`` contract), so it runs through the exact same
engine executable path as the GA / DE / Sobol / portfolio backends.

The walk moves through index space of the (power-of-two constrained) axis
value lists; the area budget enters as a smooth penalty inside the objective
(``cost_model.make_objective_fn``) so chains can skirt the boundary.
Acceptance uses relative deltas (exp(-(new-old)/old / T)) to stay scale-free
across objectives (energy pJ vs latency cycles differ by ~6 orders).
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import DesignSpace


class SAResult(typing.NamedTuple):
    best_cfg: jax.Array        # [6] (mr, mc, scr, is_kb, os_kb, bw)
    best_value: jax.Array      # scalar
    best_per_chain: jax.Array  # [chains]
    trace_best: jax.Array      # [steps] population-best value per step


@dataclasses.dataclass(frozen=True)
class SASettings:
    n_chains: int = 64
    n_steps: int = 400
    t0: float = 0.3
    alpha: float = 0.985
    jump_prob: float = 0.15   # occasional uniform redraw of one axis
    seed: int = 0


def _axes_matrix(space: DesignSpace) -> tuple[np.ndarray, np.ndarray]:
    """Pad per-axis value lists into a [5, Lmax] matrix + length vector."""
    axes = space.axes()
    lmax = max(len(a) for a in axes)
    mat = np.zeros((5, lmax), dtype=np.float64)
    lens = np.zeros(5, dtype=np.int32)
    for i, vals in enumerate(axes):
        mat[i, : len(vals)] = vals
        mat[i, len(vals):] = vals[-1]
        lens[i] = len(vals)
    return mat, lens


def make_chain_keys(settings: SASettings, key: jax.Array | None = None):
    """[n_chains, 2, key] RNG block: (init key, step key) per chain."""
    if key is None:
        key = jax.random.PRNGKey(settings.seed)
    return jax.random.split(key, settings.n_chains * 2).reshape(
        settings.n_chains, 2, -1
    )


def anneal(
    objective_fn,              # cfg_row[6] -> scalar (lower is better)
    mat_j,                     # [5, L] padded axis-value matrix
    lens_j,                    # [5] true axis lengths
    bw_f,                      # () external bus bandwidth (appended to cfg)
    settings: SASettings,
    chain_keys,                # [n_chains, 2, key] from make_chain_keys
):
    """Pure vectorized-chain SA walk -- every operand may be traced, so the
    batched engine can ``vmap`` this over a stacked job axis (per-job axis
    matrices, bandwidths and objectives) inside one jitted executable.

    Returns (best_idx [chains, 5], best_val [chains], hists [chains, steps]).
    """
    bw_f = jnp.asarray(bw_f)

    def cfg_of(idx):
        vals = mat_j[jnp.arange(5), idx]
        return jnp.concatenate([vals, bw_f[None]])

    def chain_init(k):
        idx = jax.random.randint(k, (5,), 0, lens_j)
        val = objective_fn(cfg_of(idx))
        return idx, val

    def chain_step(state, xs):
        idx, val, best_idx, best_val = state
        k, temp = xs
        k1, k2, k3, k4 = jax.random.split(k, 4)
        axis = jax.random.randint(k1, (), 0, 5)
        lo, hi = 0, lens_j[axis]
        jump = jax.random.uniform(k2) < settings.jump_prob
        delta = jnp.where(jax.random.uniform(k3) < 0.5, -1, 1)
        new_pos = jnp.where(
            jump,
            jax.random.randint(k2, (), 0, 1_000_000) % hi,
            jnp.clip(idx[axis] + delta, lo, hi - 1),
        )
        new_idx = idx.at[axis].set(new_pos)
        new_val = objective_fn(cfg_of(new_idx))
        rel = (new_val - val) / jnp.maximum(val, 1e-30)
        accept = (new_val < val) | (
            jax.random.uniform(k4) < jnp.exp(-rel / jnp.maximum(temp, 1e-9))
        )
        idx = jnp.where(accept, new_idx, idx)
        val = jnp.where(accept, new_val, val)
        better = val < best_val
        best_idx = jnp.where(better, idx, best_idx)
        best_val = jnp.where(better, val, best_val)
        return (idx, val, best_idx, best_val), best_val

    def run_chain(k):
        k0, ks = k[0], k[1]
        idx, val = chain_init(k0)
        temps = settings.t0 * settings.alpha ** jnp.arange(settings.n_steps)
        keys = jax.random.split(ks, settings.n_steps)
        (_, _, best_idx, best_val), best_hist = jax.lax.scan(
            chain_step, (idx, val, idx, val), (keys, temps)
        )
        return best_idx, best_val, best_hist

    return jax.vmap(run_chain)(chain_keys)


def simulated_annealing(
    objective_fn,              # cfg_row[6] -> scalar (lower is better)
    space: DesignSpace,
    bw: int,
    settings: SASettings = SASettings(),
    key: jax.Array | None = None,
) -> SAResult:
    mat, lens = _axes_matrix(space)
    mat_j = jnp.asarray(mat)
    lens_j = jnp.asarray(lens)
    bw_f = jnp.asarray(float(bw))
    best_idx, best_val, hists = anneal(
        objective_fn, mat_j, lens_j, bw_f, settings,
        make_chain_keys(settings, key),
    )
    winner = jnp.argmin(best_val)
    vals = mat_j[jnp.arange(5), best_idx[winner]]
    return SAResult(
        best_cfg=jnp.concatenate([vals, bw_f[None]]),
        best_value=best_val[winner],
        best_per_chain=best_val,
        trace_best=jnp.min(hists, axis=0),
    )


def exhaustive_search(
    objective_fn,
    candidates: np.ndarray,    # [C, 6] cfg rows (pruned space + bw column)
    batch: int = 4096,
) -> tuple[np.ndarray, float]:
    """Ground-truth optimum over an (already pruned) candidate list."""
    eval_batch = jax.jit(jax.vmap(objective_fn))
    best_val = np.inf
    best_cfg = None
    for i in range(0, len(candidates), batch):
        chunk = jnp.asarray(candidates[i: i + batch])
        vals = np.asarray(eval_batch(chunk))
        j = int(np.argmin(vals))
        if vals[j] < best_val:
            best_val = float(vals[j])
            best_cfg = np.asarray(candidates[i + j])
    return best_cfg, best_val
