"""Multi-pod distributed hardware-mapping co-exploration.

The paper runs its simulated annealing on a single host.  Because our whole
evaluation pipeline (cost model x operators x strategies) is pure ``jnp``,
the chain population can be sharded across an entire TPU pod (or two) with
``shard_map``.  The population is the *job x chain* grid of the batched
exploration engine (``core/engine.py``): every device anneals a local slice
holding ``chains_per_device`` chains of EVERY job (per-chain job constants
are gathered from replicated per-job arrays), and every ``sync_every`` steps
the per-job incumbent best (value + config) is exchanged with
``lax.pmin``/``psum`` collectives; each device then re-seeds its worst chain
of each job with that job's global best (exploit) while the rest keep
exploring.

Production concerns handled here:
  * fault tolerance -- search state (chain indices, job ids, RNG keys,
    round) checkpoints to an .npz after every round; ``resume=True``
    restarts from the latest checkpoint after a failure;
  * elasticity -- on resume the per-job population is re-padded to whatever
    device count the new mesh has (chains are embarrassingly parallel);
  * stragglers -- rounds are fixed-work (``sync_every`` steps), so a slow
    host delays at most one collective; there is no long-tail barrier.

:func:`race_devices` additionally serves the engine's portfolio racer:
when several devices are visible, portfolio race waves dispatch their
constituent backends round-robin across them (async dispatch, per-rung
best exchange) and fall back transparently to the single-device path.
"""
from __future__ import annotations

import dataclasses
import os
import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import cost_model
from repro.core.annealing import SASettings, _axes_matrix
from repro.core.calibration import TechConstants, resolve_tech
from repro.core.engine import ExploreJob, _job_arrays, _stack_jobs
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace
from repro.core.template import AcceleratorConfig


@dataclasses.dataclass
class DistributedResult:
    config: AcceleratorConfig
    best_value: float
    rounds: int
    n_chains: int
    trace: list[float]


def race_devices() -> list:
    """Visible JAX devices the engine's portfolio racer places
    constituent backends across (``ExplorationEngine._run_portfolio_batch``
    dispatches each race wave's runs asynchronously, one backend per
    device, and folds the wave's results into per-job incumbents -- the
    host-side analogue of this module's per-round ``pmin`` best exchange).
    Multi-CPU-device processes (``XLA_FLAGS=
    --xla_force_host_platform_device_count=N``) race exactly like real
    multi-chip hosts; a 1-device list makes the engine fall back to the
    default-placement path.

    ``CIM_TUNER_RACE_DEVICES="0,2"`` restricts (and orders) the raced
    devices by index -- the process-level complement of
    ``PortfolioSettings.device_affinity``, which pins each constituent to
    a slot *within* this list.  Placement never feeds the RNG, so any
    subset produces bit-identical results."""
    devs = list(jax.devices())
    spec = os.environ.get("CIM_TUNER_RACE_DEVICES", "").strip()
    if spec:
        try:
            slots = [int(x) for x in spec.split(",") if x.strip()]
        except ValueError as exc:
            raise ValueError(
                f"CIM_TUNER_RACE_DEVICES must be comma-separated device "
                f"indices, got {spec!r}") from exc
        devs = [devs[s % len(devs)] for s in slots] or devs
    return devs


def _round_body(
    stacked, mats_j, lens_j, bws_j, settings: SASettings, steps: int,
    axis_names: tuple[str, ...], n_jobs: int,
):
    """Builds the shard_map body: anneal the local job x chain slice `steps`
    steps, then exchange each job's global best and re-seed each device's
    worst chain of that job."""

    def cfg_of(job_id, idx):
        vals = mats_j[job_id][jnp.arange(5), idx]
        return jnp.concatenate([vals, bws_j[job_id][None]])

    def chain_objective(job_id, idx):
        job = jax.tree.map(lambda a: a[job_id], stacked)
        return cost_model.job_objective(job, cfg_of(job_id, idx))

    def chain_step(job_id, state, xs):
        idx, val, best_idx, best_val = state
        k, temp = xs
        k1, k2, k3, k4 = jax.random.split(k, 4)
        axis = jax.random.randint(k1, (), 0, 5)
        hi = lens_j[job_id][axis]
        jump = jax.random.uniform(k2) < settings.jump_prob
        delta = jnp.where(jax.random.uniform(k3) < 0.5, -1, 1)
        new_pos = jnp.where(
            jump,
            jax.random.randint(k2, (), 0, 1_000_000) % hi,
            jnp.clip(idx[axis] + delta, 0, hi - 1),
        )
        new_idx = idx.at[axis].set(new_pos)
        new_val = chain_objective(job_id, new_idx)
        rel = (new_val - val) / jnp.maximum(val, 1e-30)
        accept = (new_val < val) | (
            jax.random.uniform(k4) < jnp.exp(-rel / jnp.maximum(temp, 1e-9))
        )
        idx = jnp.where(accept, new_idx, idx)
        val = jnp.where(accept, new_val, val)
        better = val < best_val
        return (
            idx, val,
            jnp.where(better, idx, best_idx),
            jnp.where(better, val, best_val),
        ), None

    def run_chain(job_id, idx, val, best_idx, best_val, key, t_round):
        temps = t_round * settings.alpha ** jnp.arange(steps)
        keys = jax.random.split(key, steps)
        (idx, val, best_idx, best_val), _ = jax.lax.scan(
            lambda s, xs: chain_step(job_id, s, xs),
            (idx, val, best_idx, best_val), (keys, temps)
        )
        return idx, val, best_idx, best_val

    def body(job_id, idx, val, best_idx, best_val, keys, t_round):
        # local per-chain annealing ([local_chains, ...] block)
        step_keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
        idx, val, best_idx, best_val = jax.vmap(
            run_chain, in_axes=(0, 0, 0, 0, 0, 0, None)
        )(job_id, idx, val, best_idx, best_val, step_keys, t_round[0])

        # ---- per-job global best exchange ----
        job_eye = job_id[:, None] == jnp.arange(n_jobs)[None, :]  # [L, J]
        masked = jnp.where(job_eye, best_val[:, None], jnp.inf)
        local_best = masked.min(axis=0)                           # [J]
        local_arg = masked.argmin(axis=0)                         # [J]
        g_best = jax.lax.pmin(local_best, axis_names)
        winner = (local_best <= g_best).astype(best_idx.dtype)    # [J]
        contrib = best_idx[local_arg] * winner[:, None]           # [J, 5]
        n_win = jax.lax.psum(winner, axis_names)
        g_idx = (
            jax.lax.psum(contrib, axis_names)
            // jnp.maximum(n_win, 1)[:, None]
        )
        # re-seed each job's locally-worst chain with its global best
        worst = jnp.where(job_eye, val[:, None], -jnp.inf).argmax(axis=0)
        idx = idx.at[worst].set(g_idx)
        val = val.at[worst].set(g_best)
        new_keys = jax.vmap(lambda k: jax.random.fold_in(k, 2))(keys)
        return idx, val, best_idx, best_val, new_keys, g_best

    return body


def distributed_co_explore_jobs(
    mesh,
    jobs: typing.Sequence[ExploreJob],
    settings: SASettings = SASettings(),
    chains_per_device: int = 4,          # chains per job per device
    rounds: int = 8,
    sync_every: int = 50,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> list[DistributedResult]:
    """Anneal the full job x chain population of a job batch over a mesh.

    Every device holds ``chains_per_device`` chains of every job, so the
    per-job collectives (best exchange / worst re-seed) always have local
    members; elastic resume re-tiles each job's chains to the new mesh."""
    n_jobs = len(jobs)
    if n_jobs == 0:
        raise ValueError("empty job list")

    # ---- per-job data (shared-shape padding, as in the engine) ----
    ops_pad = max(len(job.merged_workload().ops) for job in jobs)
    axes = [_axes_matrix(job.design_space()) for job in jobs]
    lmax = max(m.shape[1] for m, _ in axes)
    mats = np.stack([
        np.concatenate([m, np.repeat(m[:, -1:], lmax - m.shape[1], axis=1)],
                       axis=1)
        for m, _ in axes])                                    # [J, 5, L]
    lens = np.stack([ln for _, ln in axes])                   # [J, 5]
    stacked_np = _stack_jobs([
        _job_arrays_padded(job, ops_pad) for job in jobs])

    axis_names = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    local = n_jobs * chains_per_device                 # chains per device
    n_chains = n_dev * local                           # total population
    job_id = np.tile(np.repeat(np.arange(n_jobs), chains_per_device), n_dev)

    # ---- init population (possibly from a checkpoint; re-pad if the mesh
    # size changed = elastic resume) ----
    start_round = 0
    rng = np.random.default_rng(settings.seed)
    idx0 = rng.integers(
        0, lens[job_id], size=(n_chains, 5)).astype(np.int32)
    key0 = np.array(jax.vmap(jax.random.PRNGKey)(
        np.arange(settings.seed, settings.seed + n_chains)))
    trace: list[np.ndarray] = []
    ckpt_path = (
        os.path.join(checkpoint_dir, "dse_state.npz") if checkpoint_dir
        else None
    )
    if resume and ckpt_path and os.path.exists(ckpt_path):
        st = np.load(ckpt_path)
        # legacy (pre-batch) checkpoints carry no job axis: all chains job 0
        old_job = (st["job_id"] if "job_id" in st.files
                   else np.zeros(len(st["idx"]), dtype=np.int64))
        for j in range(n_jobs):
            sel = np.flatnonzero(old_job == j)
            if len(sel) == 0:
                continue
            mine = np.flatnonzero(job_id == j)
            reps = -(-len(mine) // len(sel))
            idx0[mine] = np.tile(st["idx"][sel], (reps, 1))[: len(mine)]
            key0[mine] = np.tile(st["keys"][sel], (reps, 1))[: len(mine)]
        start_round = int(st["round"])
        tr = np.asarray(st["trace"])
        trace = [row for row in tr.reshape(-1, n_jobs)]

    stacked = jax.tree.map(jnp.asarray, stacked_np)
    mats_j, lens_j = jnp.asarray(mats), jnp.asarray(lens)
    bws_j = jnp.asarray([float(j.bw) for j in jobs])

    def _cfg_vals(j: int, idx_row: np.ndarray) -> np.ndarray:
        return mats[j][np.arange(5), idx_row]

    eval_cfg = jax.jit(jax.vmap(lambda jid, i: cost_model.job_objective(
        jax.tree.map(lambda a: a[jid], stacked),
        jnp.concatenate([mats_j[jid][jnp.arange(5), i], bws_j[jid][None]]),
    )))
    job_id_j = jnp.asarray(job_id)
    val0 = np.asarray(eval_cfg(job_id_j, jnp.asarray(idx0)))

    body = _round_body(
        stacked, mats_j, lens_j, bws_j, settings, sync_every, axis_names,
        n_jobs,
    )
    spec = P(axis_names)
    rspec = P()
    smapped = jax.jit(
        shard_map(
            body, mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec, spec, rspec),
            out_specs=(spec, spec, spec, spec, spec, rspec),
        )
    )

    idx = jnp.asarray(idx0)
    val = jnp.asarray(val0)
    best_idx, best_val = idx, val
    keys = jnp.asarray(key0)
    for r in range(start_round, rounds):
        t_round = jnp.asarray([settings.t0 * (0.5 ** r)])
        idx, val, best_idx, best_val, keys, g_best = smapped(
            job_id_j, idx, val, best_idx, best_val, keys, t_round
        )
        trace.append(np.asarray(g_best))
        if ckpt_path:
            os.makedirs(checkpoint_dir, exist_ok=True)
            tmp = ckpt_path + ".tmp.npz"
            np.savez(
                tmp, idx=np.asarray(idx), keys=np.asarray(keys),
                job_id=job_id, round=r + 1, trace=np.asarray(trace),
            )
            os.replace(tmp, ckpt_path)

    bv = np.asarray(best_val)
    bi = np.asarray(best_idx)
    results = []
    for j, job in enumerate(jobs):
        mine = np.flatnonzero(job_id == j)
        w = mine[int(np.argmin(bv[mine]))]
        cfg_vals = _cfg_vals(j, bi[w])
        cfg = AcceleratorConfig(
            *[int(round(v)) for v in cfg_vals], bw=job.bw)
        results.append(DistributedResult(
            config=cfg,
            best_value=float(bv[w]),
            rounds=rounds,
            n_chains=len(mine),
            trace=[float(row[j]) for row in trace],
        ))
    return results


def _job_arrays_padded(job: ExploreJob, ops_pad: int):
    """JobParams with the operator array padded to the batch bucket."""
    from repro.core.engine import _PreparedJob, _pow2_at_least

    wl = job.merged_workload()
    mat, ln = _axes_matrix(job.design_space())
    return _job_arrays(_PreparedJob(
        job=job, workload=wl, ops_pad=_pow2_at_least(ops_pad),
        mat=mat, lens=ln))


def distributed_co_explore(
    mesh,
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    objective: str = "ee",
    strategy_set: str = "st",
    space: DesignSpace | None = None,
    bw: int = 256,
    tech: TechConstants | None = None,
    settings: SASettings = SASettings(),
    chains_per_device: int = 4,
    rounds: int = 8,
    sync_every: int = 50,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> DistributedResult:
    """Single-job distributed DSE (a job x chain population of one job)."""
    tech = resolve_tech(tech)
    job = ExploreJob(
        macro=macro, workload=workload, area_budget_mm2=area_budget_mm2,
        objective=objective, strategy_set=strategy_set, bw=bw, tech=tech,
        space=space,
    )
    return distributed_co_explore_jobs(
        mesh, [job], settings=settings,
        chains_per_device=chains_per_device, rounds=rounds,
        sync_every=sync_every, checkpoint_dir=checkpoint_dir,
        resume=resume,
    )[0]
