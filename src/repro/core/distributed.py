"""Multi-pod distributed hardware-mapping co-exploration.

The paper runs its simulated annealing on a single host.  Because our whole
evaluation pipeline (cost model x operators x strategies) is pure ``jnp``,
the chain population can be sharded across an entire TPU pod (or two) with
``shard_map``: every device anneals its local chains, and every
``sync_every`` steps the incumbent best (value + config) is exchanged with
``lax.pmin``/``psum`` collectives; each device then re-seeds its worst chain
with the global best (exploit) while the rest keep exploring.

Production concerns handled here:
  * fault tolerance -- search state (chain indices, values, RNG key, round)
    checkpoints to an .npz after every round; ``resume_round`` restarts from
    the latest checkpoint after a failure;
  * elasticity -- on resume the population is re-padded to whatever device
    count the new mesh has (chains are embarrassingly parallel);
  * stragglers -- rounds are fixed-work (``sync_every`` steps), so a slow
    host delays at most one collective; there is no long-tail barrier.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cost_model
from repro.core.annealing import SASettings, _axes_matrix
from repro.core.calibration import DEFAULT_TECH, TechConstants
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace
from repro.core.template import AcceleratorConfig


@dataclasses.dataclass
class DistributedResult:
    config: AcceleratorConfig
    best_value: float
    rounds: int
    n_chains: int
    trace: list[float]


def _round_body(
    objective_fn, mat_j, lens_j, bw_f, settings: SASettings, steps: int,
    axis_names: tuple[str, ...],
):
    """Builds the shard_map body: anneal local chains `steps` steps, then
    exchange the global best and re-seed each device's worst chain."""

    def cfg_of(idx):
        vals = mat_j[jnp.arange(5), idx]
        return jnp.concatenate([vals, bw_f[None]])

    def chain_step(state, xs):
        idx, val, best_idx, best_val = state
        k, temp = xs
        k1, k2, k3, k4 = jax.random.split(k, 4)
        axis = jax.random.randint(k1, (), 0, 5)
        hi = lens_j[axis]
        jump = jax.random.uniform(k2) < settings.jump_prob
        delta = jnp.where(jax.random.uniform(k3) < 0.5, -1, 1)
        new_pos = jnp.where(
            jump,
            jax.random.randint(k2, (), 0, 1_000_000) % hi,
            jnp.clip(idx[axis] + delta, 0, hi - 1),
        )
        new_idx = idx.at[axis].set(new_pos)
        new_val = objective_fn(cfg_of(new_idx))
        rel = (new_val - val) / jnp.maximum(val, 1e-30)
        accept = (new_val < val) | (
            jax.random.uniform(k4) < jnp.exp(-rel / jnp.maximum(temp, 1e-9))
        )
        idx = jnp.where(accept, new_idx, idx)
        val = jnp.where(accept, new_val, val)
        better = val < best_val
        return (
            idx, val,
            jnp.where(better, idx, best_idx),
            jnp.where(better, val, best_val),
        ), None

    def run_chain(idx, val, best_idx, best_val, key, t_round):
        temps = t_round * settings.alpha ** jnp.arange(steps)
        keys = jax.random.split(key, steps)
        (idx, val, best_idx, best_val), _ = jax.lax.scan(
            chain_step, (idx, val, best_idx, best_val), (keys, temps)
        )
        return idx, val, best_idx, best_val

    def body(idx, val, best_idx, best_val, keys, t_round):
        # local per-chain annealing ([local_chains, ...] block)
        step_keys = jax.vmap(lambda k: jax.random.fold_in(k, 1))(keys)
        idx, val, best_idx, best_val = jax.vmap(
            run_chain, in_axes=(0, 0, 0, 0, 0, None)
        )(idx, val, best_idx, best_val, step_keys, t_round[0])

        # ---- global best exchange ----
        local_best = jnp.min(best_val)
        local_arg = jnp.argmin(best_val)
        g_best = jax.lax.pmin(local_best, axis_names)
        winner = (local_best <= g_best).astype(best_idx.dtype)
        contrib = best_idx[local_arg] * winner
        n_win = jax.lax.psum(winner, axis_names)
        g_idx = (
            jax.lax.psum(contrib, axis_names) // jnp.maximum(n_win, 1)
        )
        # re-seed the locally-worst chain with the global best config
        worst = jnp.argmax(val)
        idx = idx.at[worst].set(g_idx)
        val = val.at[worst].set(g_best)
        new_keys = jax.vmap(lambda k: jax.random.fold_in(k, 2))(keys)
        return idx, val, best_idx, best_val, new_keys, g_best[None]

    return body


def distributed_co_explore(
    mesh: Mesh,
    macro: MacroSpec,
    workload: Workload,
    area_budget_mm2: float,
    objective: str = "ee",
    strategy_set: str = "st",
    space: DesignSpace | None = None,
    bw: int = 256,
    tech: TechConstants = DEFAULT_TECH,
    settings: SASettings = SASettings(),
    chains_per_device: int = 4,
    rounds: int = 8,
    sync_every: int = 50,
    checkpoint_dir: str | None = None,
    resume: bool = False,
) -> DistributedResult:
    space = space or DesignSpace()
    wl = workload.merged()
    objective_fn = cost_model.make_objective_fn(
        wl.as_arrays(), macro, tech, objective, strategy_set,
        area_budget_mm2=area_budget_mm2,
    )
    mat, lens = _axes_matrix(space)
    mat_j, lens_j = jnp.asarray(mat), jnp.asarray(lens)
    bw_f = jnp.asarray(float(bw))
    axis_names = tuple(mesh.axis_names)
    n_dev = int(np.prod(mesh.devices.shape))
    n_chains = n_dev * chains_per_device

    # ---- init population (possibly from a checkpoint; re-pad if the mesh
    # size changed = elastic resume) ----
    start_round = 0
    rng = np.random.default_rng(settings.seed)
    idx0 = rng.integers(0, lens[None, :], size=(n_chains, 5)).astype(np.int32)
    key0 = np.asarray(
        jax.vmap(jax.random.PRNGKey)(np.arange(settings.seed, settings.seed + n_chains))
    )
    trace: list[float] = []
    ckpt_path = (
        os.path.join(checkpoint_dir, "dse_state.npz") if checkpoint_dir else None
    )
    if resume and ckpt_path and os.path.exists(ckpt_path):
        st = np.load(ckpt_path)
        old = st["idx"]
        reps = -(-n_chains // len(old))
        idx0 = np.tile(old, (reps, 1))[:n_chains].astype(np.int32)
        key0 = np.tile(st["keys"], (reps, 1))[:n_chains]
        start_round = int(st["round"])
        trace = [float(x) for x in st["trace"]]

    spec = P(axis_names)
    rspec = P()

    def cfg_of_np(idx_row):
        vals = mat[np.arange(5), idx_row]
        return np.concatenate([vals, [float(bw)]])

    eval_cfg = jax.jit(jax.vmap(lambda i: objective_fn(
        jnp.concatenate([mat_j[jnp.arange(5), i], bw_f[None]])
    )))
    val0 = np.asarray(eval_cfg(jnp.asarray(idx0)))

    body = _round_body(
        objective_fn, mat_j, lens_j, bw_f, settings, sync_every, axis_names
    )
    smapped = jax.jit(
        jax.shard_map(
            body, mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec, rspec),
            out_specs=(spec, spec, spec, spec, spec, rspec),
        )
    )

    idx = jnp.asarray(idx0)
    val = jnp.asarray(val0)
    best_idx, best_val = idx, val
    keys = jnp.asarray(key0)
    for r in range(start_round, rounds):
        t_round = jnp.asarray([settings.t0 * (0.5 ** r)])
        idx, val, best_idx, best_val, keys, g_best = smapped(
            idx, val, best_idx, best_val, keys, t_round
        )
        trace.append(float(g_best[0]))
        if ckpt_path:
            os.makedirs(checkpoint_dir, exist_ok=True)
            tmp = ckpt_path + ".tmp.npz"
            np.savez(
                tmp, idx=np.asarray(idx), keys=np.asarray(keys),
                round=r + 1, trace=np.asarray(trace),
            )
            os.replace(tmp, ckpt_path)

    bv = np.asarray(best_val)
    bi = np.asarray(best_idx)
    w = int(np.argmin(bv))
    cfg_vals = cfg_of_np(bi[w])
    cfg = AcceleratorConfig(*[int(round(v)) for v in cfg_vals[:5]], bw=bw)
    return DistributedResult(
        config=cfg,
        best_value=float(bv[w]),
        rounds=rounds,
        n_chains=n_chains,
        trace=trace,
    )
