"""Operator intermediate representation (IR) and operator-size-aware merging.

CIM-Tuner represents target workloads through an IR that extracts matrix
dimensions (paper Sec. III-A).  A workload is a list of ``MatmulOp``s
(M x K @ K x N, with multiplicity).  Operators of the same size are merged
(Sec. III-D) which shrinks the per-network mapping-strategy space -- the
80 %+ runtime reduction of Fig. 9.

``weights_static`` distinguishes parameter matmuls (weights can live in CIM
across an inference) from activation x activation GEMMs (attention score /
context products) whose "stationary" operand must be re-written per call.
Both are mappable -- the reversed (R) spatial scheduling exists precisely to
let either operand be the CIM-resident one -- the flag only documents the
distinction and is consumed by the energy model's update accounting.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np


@dataclasses.dataclass(frozen=True)
class MatmulOp:
    """One (M, K) x (K, N) matrix multiplication, repeated ``count`` times."""

    m: int
    k: int
    n: int
    count: int = 1
    weights_static: bool = True
    name: str = ""

    def __post_init__(self) -> None:
        if min(self.m, self.k, self.n) <= 0 or self.count <= 0:
            raise ValueError(f"invalid MatmulOp dims: {self}")

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.count

    @property
    def ops(self) -> int:
        return 2 * self.macs

    def key(self) -> tuple[int, int, int, bool]:
        return (self.m, self.k, self.n, self.weights_static)


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named bag of matmul operators (one DNN's GEMM mix)."""

    name: str
    ops: tuple[MatmulOp, ...]

    def __post_init__(self) -> None:
        if not self.ops:
            raise ValueError(f"workload {self.name!r} has no operators")

    @property
    def total_macs(self) -> int:
        return sum(op.macs for op in self.ops)

    @property
    def total_ops(self) -> int:
        return 2 * self.total_macs

    def merged(self) -> "Workload":
        """Operator-size-aware merging: gather same-size operators."""
        acc: OrderedDict[tuple, list] = OrderedDict()
        for op in self.ops:
            k = op.key()
            if k in acc:
                acc[k][0] += op.count
            else:
                acc[k] = [op.count, op]
        merged = tuple(
            dataclasses.replace(op, count=cnt, name=op.name or f"op{i}")
            for i, (cnt, op) in enumerate(acc.values())
        )
        return Workload(name=self.name, ops=merged)

    # ------------------------------------------------------------------ #
    # Vectorized view for the jnp cost model: fixed-width arrays, padded
    # with count == 0 sentinel rows (cost model treats count 0 as "absent").
    # ------------------------------------------------------------------ #
    def as_arrays(self, pad_to: int | None = None):
        n = len(self.ops)
        width = pad_to if pad_to is not None else n
        if width < n:
            raise ValueError(f"pad_to={pad_to} < num ops {n}")
        out = np.zeros((width, 5), dtype=np.float64)
        for i, op in enumerate(self.ops):
            out[i] = (op.m, op.k, op.n, op.count, float(op.weights_static))
        out[n:, :3] = 1.0  # keep dims positive for padded rows
        return out


# ---------------------------------------------------------------------- #
# Transformer-family operator extraction.  These helpers build workloads
# straight from layer hyperparameters; ``repro.configs`` adds per-arch
# wrappers on top so the DSE runs on the assigned architectures.
# ---------------------------------------------------------------------- #
def transformer_layer_ops(
    *,
    seq: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    d_ff: int,
    gated_ffn: bool = True,
    n_experts: int = 0,
    top_k: int = 0,
    window: int | None = None,
    cross_attn_src: int | None = None,
    prefix: str = "",
) -> list[MatmulOp]:
    """GEMM mix of one decoder layer at a given sequence length.

    Attention score/context products are emitted per head-group with
    ``weights_static=False``.  With sliding-window attention the effective
    attended length is capped at ``window``.
    """
    ops: list[MatmulOp] = []
    q_dim = n_heads * head_dim
    kv_dim = n_kv_heads * head_dim

    ops.append(MatmulOp(seq, d_model, q_dim, name=f"{prefix}q_proj"))
    ops.append(MatmulOp(seq, d_model, kv_dim, count=2, name=f"{prefix}kv_proj"))
    ops.append(MatmulOp(seq, q_dim, d_model, name=f"{prefix}o_proj"))

    att_len = min(seq, window) if window else seq
    # score: (seq x head_dim) @ (head_dim x att_len), one per head
    ops.append(MatmulOp(seq, head_dim, att_len, count=n_heads,
                        weights_static=False, name=f"{prefix}attn_score"))
    # context: (seq x att_len) @ (att_len x head_dim)
    ops.append(MatmulOp(seq, att_len, head_dim, count=n_heads,
                        weights_static=False, name=f"{prefix}attn_ctx"))

    if cross_attn_src is not None:
        ops.append(MatmulOp(seq, d_model, q_dim, name=f"{prefix}xq_proj"))
        ops.append(MatmulOp(cross_attn_src, d_model, kv_dim, count=2,
                            name=f"{prefix}xkv_proj"))
        ops.append(MatmulOp(seq, q_dim, d_model, name=f"{prefix}xo_proj"))
        ops.append(MatmulOp(seq, head_dim, cross_attn_src, count=n_heads,
                            weights_static=False, name=f"{prefix}xattn_score"))
        ops.append(MatmulOp(seq, cross_attn_src, head_dim, count=n_heads,
                            weights_static=False, name=f"{prefix}xattn_ctx"))

    if n_experts and top_k:
        # router + top_k active expert FFNs per token (dense equivalent:
        # every token hits top_k experts -> count = top_k per matmul)
        ops.append(MatmulOp(seq, d_model, n_experts, name=f"{prefix}router"))
        up_count = 2 * top_k if gated_ffn else top_k
        ops.append(MatmulOp(seq, d_model, d_ff, count=up_count,
                            name=f"{prefix}moe_up"))
        ops.append(MatmulOp(seq, d_ff, d_model, count=top_k,
                            name=f"{prefix}moe_down"))
    elif d_ff > 0:
        up_count = 2 if gated_ffn else 1
        ops.append(MatmulOp(seq, d_model, d_ff, count=up_count,
                            name=f"{prefix}ffn_up"))
        ops.append(MatmulOp(seq, d_ff, d_model, name=f"{prefix}ffn_down"))
    return ops


def ssm_layer_ops(
    *,
    seq: int,
    d_model: int,
    d_inner: int,
    d_state: int,
    dt_rank: int,
    prefix: str = "",
) -> list[MatmulOp]:
    """Mamba-1 block GEMM mix (the selective scan itself is elementwise and
    out of CIM-Tuner scope -- see DESIGN.md Arch-applicability)."""
    return [
        MatmulOp(seq, d_model, 2 * d_inner, name=f"{prefix}in_proj"),
        MatmulOp(seq, d_inner, dt_rank + 2 * d_state, name=f"{prefix}x_proj"),
        MatmulOp(seq, dt_rank, d_inner, name=f"{prefix}dt_proj"),
        MatmulOp(seq, d_inner, d_model, name=f"{prefix}out_proj"),
    ]


def lm_head_ops(*, seq: int, d_model: int, vocab: int) -> list[MatmulOp]:
    return [MatmulOp(seq, d_model, vocab, name="lm_head")]


def bert_large_workload(seq: int = 512) -> Workload:
    """Bert-large [4]: 24 layers, d=1024, 16 heads, ff=4096 (Fig. 8 /
    Table II workload)."""
    layer = transformer_layer_ops(
        seq=seq, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, gated_ffn=False,
    )
    ops = [dataclasses.replace(op, count=op.count * 24) for op in layer]
    return Workload("bert-large", tuple(ops)).merged()


def bert_large_fig8_ops() -> Workload:
    """The three Bert-large matmul operators used in the Fig. 8 breakdown:
    QKV projection, FFN up, FFN down (seq = 512)."""
    return Workload(
        "bert-large-fig8",
        (
            MatmulOp(512, 1024, 1024, count=3, name="qkv_proj"),
            MatmulOp(512, 1024, 4096, name="ffn_up"),
            MatmulOp(512, 4096, 1024, name="ffn_down"),
        ),
    )
