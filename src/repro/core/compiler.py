"""CIM-Tuner compiler: mapping strategy -> instruction flow (paper Sec. III-A,
IV-A).

Two products, both built by explicitly walking the strategy's loop nest (the
ground truth the closed-form cost model must reproduce):

* ``compile_schedule`` -- a per-*resident-set* record stream (compute /
  update / bus work per set).  Field sums match ``cost_model.matmul_cost``
  exactly, integer for integer (property-tested); the cycle-accurate
  simulator consumes it.

* ``compile_trace`` -- an address-level instruction list (LOAD_V / LOAD_S /
  COMPUTE / STORE_Y) for small operators, replayed by ``replay_trace`` on
  real numpy matrices with IS/CIM/OS capacity invariants asserted.  This is
  the analogue of the paper's silicon-verification "validation script" that
  checks the compiled instruction flow's memory-access trace performs the
  intended matrix multiplication.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.macro import MacroSpec
from repro.core.strategies import Strategy
from repro.core.template import AcceleratorConfig

MAX_SETS = 2_000_000


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Oriented loop-nest geometry shared by schedule and trace builders."""

    M: int
    K: int
    N: int
    dws: int   # streamed-data width (bits)
    dwt: int   # stationary-data width (bits)
    kp: int
    np_: int
    tk: int
    tn: int
    cyc_c: int
    cyc_u: int
    scr: int
    is_bits: int
    os_bits: int
    dw_psum: int
    dw_out: int
    # residency
    rows_res: int        # WP resident rows (full-width)
    fits_all_v: bool
    fits_all_s: bool
    os_rows_af: int

    def os_rows_pf(self, q: int) -> int:
        return self.os_bits // (q * self.np_ * self.dw_psum)


def make_geometry(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    m: int,
    k: int,
    n: int,
    strategy: Strategy,
) -> Geometry:
    rev = strategy.spatial == "R"
    M, N = (n, m) if rev else (m, n)
    K = k
    dws = macro.dw_w if rev else macro.dw_in
    dwt = macro.dw_in if rev else macro.dw_w
    kp = cfg.mr * macro.al
    np_ = cfg.mc * macro.pc
    tk = _cdiv(K, kp)
    tn = _cdiv(N, np_)
    cyc_c = max(1, _cdiv(dws * macro.al, macro.icw))
    cyc_u = max(1, _cdiv(macro.al * dwt, macro.wuw))
    rows_res = min(max(cfg.is_bits // (tk * kp * dws), 1), M)
    return Geometry(
        M=M, K=K, N=N, dws=dws, dwt=dwt, kp=kp, np_=np_, tk=tk, tn=tn,
        cyc_c=cyc_c, cyc_u=cyc_u, scr=cfg.scr,
        is_bits=cfg.is_bits, os_bits=cfg.os_bits,
        dw_psum=macro.dw_psum, dw_out=macro.dw_out,
        fits_all_v=M * tk * kp * dws <= cfg.is_bits,
        fits_all_s=tk * tn <= cfg.scr,
        os_rows_af=cfg.os_bits // (np_ * macro.dw_psum),
        rows_res=rows_res,
    )


def strategy_feasible(
    macro: MacroSpec, cfg: AcceleratorConfig, m: int, k: int, n: int,
    strategy: Strategy,
) -> bool:
    g = make_geometry(macro, cfg, m, k, n, strategy)
    if cfg.is_bits < g.kp * g.dws:
        return False
    if cfg.os_bits < g.np_ * g.dw_psum:
        return False
    if strategy.temporal == "WP" and cfg.is_bits < g.tk * g.kp * g.dws:
        return False  # one full row must fit for weight-priority updates
    return True


SCHEDULE_FIELDS = (
    "planes", "compute_cycles", "update_cycles",
    "v_bits", "s_bits", "spill_bits", "y_bits",
    "is_rd_bits", "is_wr_bits", "os_rd_bits", "os_wr_bits",
)


def compile_schedule(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    m: int,
    k: int,
    n: int,
    strategy: Strategy,
) -> dict[str, np.ndarray]:
    """Per-resident-set work records for (m x k) @ (k x n) under ``strategy``.

    Returns a dict of int64 arrays (one entry per set, loop-nest order).
    """
    if not strategy_feasible(macro, cfg, m, k, n, strategy):
        raise ValueError(f"strategy {strategy} infeasible for op {(m, k, n)} "
                         f"on cfg {cfg.as_tuple()}")
    g = make_geometry(macro, cfg, m, k, n, strategy)
    af = strategy.tiling == "AF"
    wp = strategy.temporal == "WP"

    # batches (WP streams row batches; IP is a single conceptual batch of M)
    if wp:
        nb = _cdiv(g.M, g.rows_res)
        batches = [g.rows_res] * (nb - 1) + [g.M - (nb - 1) * g.rows_res]
    else:
        batches = [g.M]

    if af:
        ng = _cdiv(g.tk, g.scr)
        groups = [(j, gi, min(g.scr, g.tk - gi * g.scr))
                  for j in range(g.tn) for gi in range(ng)]
        n_inner = ng
    else:
        nh = _cdiv(g.tn, g.scr)
        groups = [(h, ki, min(g.scr, g.tn - h * g.scr))
                  for h in range(nh) for ki in range(g.tk)]
        n_inner = g.tk

    n_sets = len(batches) * len(groups)
    if n_sets > MAX_SETS:
        raise ValueError(f"schedule too large ({n_sets} sets); use the "
                         "closed-form cost model for this operator")

    rec = {f: np.zeros(n_sets, dtype=np.int64) for f in SCHEDULE_FIELDS}
    si = 0
    v_fetched_once = False
    for bi, rows in enumerate(batches):
        for (outer, inner, p) in groups:
            r = rec
            r["planes"][si] = p
            r["compute_cycles"][si] = rows * p * g.cyc_c

            # ---- stationary-matrix loads (CIM updates) ----
            # WP re-sweeps all planes per batch unless they all fit in CIM
            load_planes = 0 if (wp and bi > 0 and g.fits_all_s) else p
            r["update_cycles"][si] = load_planes * g.cyc_u
            r["s_bits"][si] = load_planes * g.kp * g.np_ * g.dwt

            # ---- streamed-matrix fetches ----
            v_bits = 0
            if wp:
                if outer == 0 and inner == 0:
                    v_bits = rows * g.tk * g.kp * g.dws
            elif g.fits_all_v:
                if not v_fetched_once:
                    v_bits = g.M * g.tk * g.kp * g.dws
                    v_fetched_once = True
            else:
                span = p * g.kp if af else g.kp
                v_bits = rows * span * g.dws
            r["v_bits"][si] = v_bits
            r["is_wr_bits"][si] = v_bits

            # ---- IS reads (compute-driven; PF reuses the chunk p times) ----
            span_rd = p * g.kp if af else g.kp
            r["is_rd_bits"][si] = rows * span_rd * g.dws

            # ---- psums: OS traffic + spills ----
            width = g.np_ if af else p * g.np_
            os_rows = g.os_rows_af if af else g.os_rows_pf(p)
            spill_rows = max(0, rows - os_rows)
            spill = 0
            if inner > 0:
                spill += spill_rows * width * g.dw_psum      # read back
            if inner < n_inner - 1:
                spill += spill_rows * width * g.dw_psum      # write out
            r["spill_bits"][si] = spill

            os_wr = rows * width * g.dw_psum
            os_rd = rows * width * g.dw_psum if inner > 0 else 0
            if inner == n_inner - 1:                         # final read-out
                os_rd += rows * width * g.dw_psum
                r["y_bits"][si] = rows * width * g.dw_out
            r["os_wr_bits"][si] = os_wr
            r["os_rd_bits"][si] = os_rd
            si += 1
    assert si == n_sets
    return rec


def schedule_totals(rec: dict[str, np.ndarray]) -> dict[str, int]:
    out = {f: int(rec[f].sum()) for f in SCHEDULE_FIELDS}
    out["ema_bits"] = (
        out["v_bits"] + out["s_bits"] + out["spill_bits"] + out["y_bits"]
    )
    out["update_bits"] = out["s_bits"]
    out["n_sets"] = len(rec["planes"])
    return out


# ====================================================================== #
# Address-level trace + functional replay (the "validation script")
# ====================================================================== #
@dataclasses.dataclass(frozen=True)
class Instr:
    op: str              # LOAD_V | LOAD_S | EVICT_S | COMPUTE | STORE_Y
    rows: tuple[int, int] = (0, 0)   # [start, stop) streamed rows
    k_tile: int = -1
    n_tile: int = -1


def compile_trace(
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    m: int,
    k: int,
    n: int,
    strategy: Strategy,
    max_instrs: int = 200_000,
) -> list[Instr]:
    """Address-level instruction flow for a small operator."""
    if not strategy_feasible(macro, cfg, m, k, n, strategy):
        raise ValueError(f"strategy {strategy} infeasible for op {(m, k, n)}")
    g = make_geometry(macro, cfg, m, k, n, strategy)
    af = strategy.tiling == "AF"
    wp = strategy.temporal == "WP"

    instrs: list[Instr] = []

    if wp:
        nb = _cdiv(g.M, g.rows_res)
        batches = [
            (bi * g.rows_res, min((bi + 1) * g.rows_res, g.M))
            for bi in range(nb)
        ]
    else:
        batches = [(0, g.M)]

    if af:
        ng = _cdiv(g.tk, g.scr)
        groups = [
            (j, gi,
             [(gi * g.scr + kk, j) for kk in range(min(g.scr, g.tk - gi * g.scr))])
            for j in range(g.tn) for gi in range(ng)
        ]
        n_inner = ng
    else:
        nh = _cdiv(g.tn, g.scr)
        groups = [
            (h, ki,
             [(ki, h * g.scr + nn) for nn in range(min(g.scr, g.tn - h * g.scr))])
            for h in range(nh) for ki in range(g.tk)
        ]
        n_inner = g.tk

    resident: list[tuple[int, int]] = []   # CIM plane tags (k_tile, n_tile)
    v_loaded_once = False
    for bi, (r0, r1) in enumerate(batches):
        for (outer, inner, planes) in groups:
            # stationary loads (skip if already resident)
            for (kt, nt) in planes:
                if (kt, nt) in resident:
                    continue
                while len(resident) >= cfg.scr:
                    old = resident.pop(0)
                    instrs.append(Instr("EVICT_S", k_tile=old[0], n_tile=old[1]))
                resident.append((kt, nt))
                instrs.append(Instr("LOAD_S", k_tile=kt, n_tile=nt))
            # streamed fetch
            if wp:
                if outer == 0 and inner == 0:
                    # new input batch: previous batch's rows leave the IS
                    instrs.append(Instr("EVICT_V"))
                    instrs.append(Instr("LOAD_V", rows=(r0, r1), k_tile=-1))
            elif g.fits_all_v:
                if not v_loaded_once:
                    instrs.append(Instr("LOAD_V", rows=(0, g.M), k_tile=-1))
                    v_loaded_once = True
            else:
                # streaming set: chunks of the previous set leave the IS FIFO
                instrs.append(Instr("EVICT_V"))
                for (kt, _nt) in planes if af else planes[:1]:
                    instrs.append(Instr("LOAD_V", rows=(r0, r1), k_tile=kt))
            # compute
            for (kt, nt) in planes:
                instrs.append(Instr("COMPUTE", rows=(r0, r1),
                                    k_tile=kt, n_tile=nt))
            # writeback at the last accumulation step
            if inner == n_inner - 1:
                for nt in sorted({nt for (_kt, nt) in planes}):
                    instrs.append(Instr("STORE_Y", rows=(r0, r1), n_tile=nt))
            if len(instrs) > max_instrs:
                raise ValueError("trace too large; shrink the operator")
    return instrs


def replay_trace(
    instrs: list[Instr],
    x: np.ndarray,
    w: np.ndarray,
    macro: MacroSpec,
    cfg: AcceleratorConfig,
    strategy: Strategy,
) -> np.ndarray:
    """Execute the instruction flow on real matrices, asserting IS/CIM/OS
    capacity invariants; returns Y (= x @ w) if the flow is correct."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    g = make_geometry(macro, cfg, m, k, n, strategy)
    rev = strategy.spatial == "R"
    V = (w.T if rev else x).astype(np.float64)       # [M', K]
    S = (x.T if rev else w).astype(np.float64)       # [K, N']

    Vp = np.zeros((g.M, g.tk * g.kp))
    Vp[:, :g.K] = V
    Sp = np.zeros((g.tk * g.kp, g.tn * g.np_))
    Sp[: g.K, : g.N] = S
    Y = np.full((g.M, g.tn * g.np_), np.nan)
    psum: dict[tuple[int, int], np.ndarray] = {}     # (row, n_tile) -> vec

    cim: dict[tuple[int, int], np.ndarray] = {}
    is_buf: dict[tuple[int, int], bool] = {}          # (row, k_tile or -1)

    def is_bits_used() -> int:
        bits = 0
        for (_r, kt) in is_buf:
            bits += (g.tk * g.kp if kt == -1 else g.kp) * g.dws
        return bits

    max_os_rows = 0
    for ins in instrs:
        if ins.op == "LOAD_S":
            assert len(cim) < cfg.scr, "CIM plane capacity exceeded"
            kt, nt = ins.k_tile, ins.n_tile
            cim[(kt, nt)] = Sp[kt * g.kp:(kt + 1) * g.kp,
                               nt * g.np_:(nt + 1) * g.np_]
        elif ins.op == "EVICT_S":
            cim.pop((ins.k_tile, ins.n_tile))
        elif ins.op == "EVICT_V":
            is_buf.clear()
        elif ins.op == "LOAD_V":
            r0, r1 = ins.rows
            for r in range(r0, r1):
                is_buf[(r, ins.k_tile)] = True
            if ins.k_tile == -1:
                # resident (non-streaming) data must actually fit the IS
                assert is_bits_used() <= cfg.is_bits, \
                    "Input SRAM capacity exceeded"
        elif ins.op == "COMPUTE":
            kt, nt = ins.k_tile, ins.n_tile
            assert (kt, nt) in cim, "compute on a non-resident plane"
            r0, r1 = ins.rows
            for r in range(r0, r1):
                assert (r, kt) in is_buf or (r, -1) in is_buf, \
                    f"row {r} k_tile {kt} not in Input SRAM"
                acc = psum.setdefault((r, nt), np.zeros(g.np_))
                acc += Vp[r, kt * g.kp:(kt + 1) * g.kp] @ cim[(kt, nt)]
            max_os_rows = max(max_os_rows, len(psum))
        elif ins.op == "STORE_Y":
            r0, r1 = ins.rows
            nt = ins.n_tile
            for r in range(r0, r1):
                Y[r, nt * g.np_:(nt + 1) * g.np_] = psum.pop((r, nt))
        else:  # pragma: no cover
            raise ValueError(f"unknown instr {ins.op}")

    assert not psum, "partial sums left unaccumulated"
    out = Y[:, : g.N]
    assert not np.isnan(out).any(), "output rows never written"
    return out.T if rev else out
