"""Matrix abstraction of SRAM-CIM macros (paper Sec. III-B, eqns (1)-(5)).

Every SRAM-CIM variant performs the same atomic operation: a vector-matrix
projection between an input vector of accumulation length ``AL`` and a weight
matrix of ``AL x PC`` (parallel channels) stored in the CIM, producing a
partial-sum vector of length ``PC``.  The storage-compute ratio ``SCR``
selects one of SCR resident ``AL x PC`` weight planes per compute.

Two bandwidth parameters standardize latency across designs:

* ``ICW`` -- input-compute bandwidth, bits of input data processed per cycle.
  DCIM: ``ICW = AL * N_input_bitline`` (eq. 1).  ACIM: ``ICW = AL *
  DAC_precision`` (eq. 2).
* ``WUW`` -- weight-update bandwidth, bits of weight data written per cycle.

Latencies (eqns 3-5)::

    compute cycles / plane-op  = ceil(DW_in * AL / ICW)
    update  cycles / plane     = ceil(AL * DW_w / WUW)
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.calibration import TechConstants, resolve_tech


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """Abstracted SRAM-CIM macro: the (AL, PC, SCR, ICW, WUW) tuple.

    ``native_scr`` is the macro's as-published plane count; the *accelerator*
    level SCR (``AcceleratorConfig.scr``) overrides it during exploration
    (Table II explores SCR with the macro family fixed).
    """

    name: str
    al: int                    # accumulation length
    pc: int                    # parallel channels
    native_scr: int            # macro's native storage-compute ratio
    icw: int                   # input-compute bandwidth  [bits / cycle]
    wuw: int                   # weight-update bandwidth  [bits / cycle]
    kind: str = "dcim"         # "dcim" | "acim"
    freq_mhz: float = 500.0
    dw_in: int = 8             # input activation width   [bits]
    dw_w: int = 8              # weight width             [bits]
    dw_psum: int = 24          # partial-sum width        [bits]
    dw_out: int = 8            # quantized output width   [bits]
    # Ping-pong capability: with SCR >= 2 one plane can be updated while
    # another computes.  SCR == 1 designs always expose update latency.
    update_during_compute: bool = True
    # Optional per-macro energy overrides (pJ); ``None`` -> tech default.
    e_mac_pj: float | None = None

    def __post_init__(self) -> None:
        if self.al <= 0 or self.pc <= 0 or self.native_scr <= 0:
            raise ValueError(f"non-positive macro geometry in {self.name}")
        if self.icw <= 0 or self.wuw <= 0:
            raise ValueError(f"non-positive bandwidth in {self.name}")
        if self.kind not in ("dcim", "acim"):
            raise ValueError(f"unknown macro kind {self.kind!r}")

    # ------------------------------------------------------------------ #
    # eqns (3)/(4): one plane-op over an AL-long input vector
    # ------------------------------------------------------------------ #
    def compute_cycles(self) -> int:
        return max(1, math.ceil(self.dw_in * self.al / self.icw))

    # eq. (5): one AL x PC plane update
    def update_cycles(self) -> int:
        return max(1, math.ceil(self.al * self.dw_w / self.wuw))

    # ------------------------------------------------------------------ #
    # derived geometry / PPA
    # ------------------------------------------------------------------ #
    def cells_bits(self, scr: int) -> int:
        """Total storage bits with ``scr`` resident planes."""
        return self.al * self.pc * scr * self.dw_w

    def area_mm2(self, scr: int, tech: TechConstants | None = None) -> float:
        """Macro area: bit-cells (scale with SCR) + compute units (don't)."""
        tech = resolve_tech(tech)
        cells = self.cells_bits(scr) * tech.a_cell_um2_bit
        cus = self.al * self.pc * tech.a_cu_um2
        return (cells + cus) * 1e-6 + tech.a_macro_fixed_mm2

    def mac_energy_pj(self, tech: TechConstants | None = None) -> float:
        tech = resolve_tech(tech)
        return self.e_mac_pj if self.e_mac_pj is not None else tech.e_mac_pj

    def peak_macs_per_cycle(self, mr: int, mc: int) -> float:
        """Peak MAC throughput of an MR x MC grid of this macro."""
        return mr * mc * self.al * self.pc / self.compute_cycles()


# ---------------------------------------------------------------------- #
# Macro library.  Geometry for the silicon-verified vanilla macro is taken
# verbatim from the paper (Sec. IV-E); the others are plausible
# reconstructions of the cited designs (exact parameters are not published
# in the paper text) -- see DESIGN.md Sec. 7.
# ---------------------------------------------------------------------- #
VANILLA_DCIM = MacroSpec(
    # Paper Sec. IV-E: (AL, PC, SCR, ICW, WUW) = (64, 8, 8, 512, 128)
    name="vanilla-dcim", al=64, pc=8, native_scr=8, icw=512, wuw=128,
)

FPCIM = MacroSpec(
    # ref [9]: digital floating-point CIM, long accumulation length
    name="fpcim", al=128, pc=16, native_scr=8, icw=1024, wuw=256,
)

LCC_CIM = MacroSpec(
    # ref [5]: 6T macro with short accumulation length ("LCC-CIM" in Fig. 8
    # generates more partial sums for the same operator)
    name="lcc-cim", al=16, pc=16, native_scr=4, icw=128, wuw=128,
)

TRANCIM_MACRO = MacroSpec(
    # ref [10]: bitline-transpose digital CIM, 4b-serial input
    name="trancim-macro", al=128, pc=16, native_scr=1, icw=512, wuw=256,
)

TPDCIM_MACRO = MacroSpec(
    # ref [16]: transposable digital CIM
    name="tpdcim-macro", al=64, pc=8, native_scr=1, icw=512, wuw=512,
)

ACIM_EXAMPLE = MacroSpec(
    # generic analog CIM: ICW = AL * DAC precision (eq. 2), slow updates
    name="acim-2b-dac", al=256, pc=8, native_scr=4, icw=512, wuw=64,
    kind="acim",
)

MACRO_LIBRARY: dict[str, MacroSpec] = {
    m.name: m
    for m in (VANILLA_DCIM, FPCIM, LCC_CIM, TRANCIM_MACRO, TPDCIM_MACRO,
              ACIM_EXAMPLE)
}


def get_macro(name: str) -> MacroSpec:
    try:
        return MACRO_LIBRARY[name]
    except KeyError:
        raise KeyError(
            f"unknown macro {name!r}; available: {sorted(MACRO_LIBRARY)}"
        ) from None
