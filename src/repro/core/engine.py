"""Batched multi-job hardware-mapping co-exploration engine.

The paper's workflow evaluates one (macro, workload, objective) job at a
time; every sweep-style consumer (Fig. 7's seven networks, Table II's two
baselines x two objectives, macro-library selection, Pareto frontiers)
therefore used to rebuild and re-jit the objective per job -- wall-clock was
dominated by retrace/recompile, not search.  This module batches whole job
lists through shared compiled executables:

1. **Shape bucketing** -- each job's merged operator array is padded to a
   small set of power-of-two widths (padded rows carry ``count == 0`` and are
   cost-transparent), and its design-space axis matrix is padded likewise, so
   heterogeneous jobs share one executable signature.
2. **Job stacking** -- macro/tech constants, strategy masks, objective codes,
   area budgets and bus widths become per-job arrays
   (:class:`repro.core.cost_model.JobParams`) vmapped over a stacked job
   axis: every ``repro.search`` backend (SA chains, GA / DE populations,
   Sobol sweeps) runs *all jobs in one jitted call*, and exhaustive sweeps
   evaluate a ``[jobs, chunk]`` candidate block per call.
3. **Two-level caching** -- an in-process executable cache keyed by (bucket
   shape, backend, settings, x64 mode) means repeated submissions never
   retrace, and JAX's persistent compilation cache is switched on by default
   (:func:`enable_persistent_compilation_cache`) so fresh processes -- CI
   runs, benchmark re-runs -- reuse compiles from disk.

The search method is pluggable (``repro.search``): any registered backend
name is a valid ``method=`` -- ``"sa"``, ``"genetic"``, ``"evolution"``,
``"sobol"`` run as one vmapped executable per shape bucket, the composite
``"portfolio"`` races them per job with a bandit (UCB) or
successive-halving budget allocator
(:meth:`ExplorationEngine._run_portfolio_batch`), re-using the constituent
backends' executables -- and, when several JAX devices are visible,
dispatching the constituents round-robin *across devices* with a per-rung
best exchange (single-device processes take the same code path with no
placement).  ``"exhaustive"`` sweeps the pruned space.
``ExploreJob.search_method`` / ``ExploreJob.search_settings`` carry the
per-job method and backend settings when no explicit ``method=`` /
``settings=`` is given (so one batch may mix methods AND settings), and
:func:`job_key` folds (method, settings) into the canonical identity so
cached results never cross backends or settings.

Identical jobs inside one ``run()`` (same canonical :func:`job_key`)
evaluate once and fan the result out.  ``co_explore`` / ``co_explore_macros``
/ ``pareto_explore`` (``core/explorer.py``) are thin synchronous clients of
the async DSE service (``repro.service``) built on this engine;
``benchmarks/fig7_mapping.py`` prints the measured batched-vs-sequential
speedup (and ``--search`` races the backends).  ``core/distributed.py``
shards the same job x chain population across devices.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import os
import threading
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import cost_model
from repro.core.annealing import SASettings, _axes_matrix
from repro.core.calibration import DEFAULT_TECH, TechConstants
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace, candidates_with_bw, prune_space
from repro.core.strategies import ALL_STRATEGIES
from repro.core.template import AcceleratorConfig, accelerator_area_mm2
from repro.search.base import SearchResult, available_backends, get_backend

__all__ = [
    "ExploreJob",
    "ExploreResult",
    "ExplorationEngine",
    "default_engine",
    "enable_persistent_compilation_cache",
    "job_key",
    "preferred_settings",
    "valid_methods",
]


# --------------------------------------------------------------------- #
# telemetry families (process-wide; see docs/observability.md)
# --------------------------------------------------------------------- #
_REG = obs.registry()
_LOG = obs.get_logger("engine")
_M_JOBS = _REG.counter(
    "cim_engine_jobs_total", "Jobs submitted to ExplorationEngine.run")
_M_BATCHES = _REG.counter(
    "cim_engine_batches_total", "Batched executable dispatches")
_M_DEDUP = _REG.counter(
    "cim_engine_dedup_hits_total",
    "In-batch duplicate jobs folded into one evaluation")
_M_EXEC = _REG.counter(
    "cim_engine_executable_cache_events_total",
    "Executable-cache lookups by outcome", ("outcome",))
_M_RACE = _REG.counter(
    "cim_engine_device_race_dispatches_total",
    "Portfolio waves placed on a non-default device")
_M_RUN_S = _REG.histogram(
    "cim_engine_run_seconds", "Wall-clock of ExplorationEngine.run calls")
_M_COMPILE_S = _REG.histogram(
    "cim_engine_compile_seconds",
    "First-call (trace + XLA compile) latency per cached executable")
_M_PULLS = _REG.counter(
    "cim_search_pulls_total",
    "Portfolio pulls granted per backend by the budget allocator",
    ("backend", "allocator"))
_M_RUNGS = _REG.counter(
    "cim_search_rungs_total",
    "Portfolio race rungs / bandit waves executed", ("allocator",))
# continuous-batching scheduler families (docs/scheduler.md); the queue
# owns the admission counters, the engine owns the budget-flow ones
_M_SCHED_RELEASED = _REG.counter(
    "cim_sched_budget_released_pulls_total",
    "Race pulls released into the shared pool by flatlined jobs")
_M_SCHED_ABSORBED = _REG.counter(
    "cim_sched_budget_absorbed_pulls_total",
    "Shared-pool race pulls absorbed by still-improving jobs")
_M_SCHED_FLATLINED = _REG.counter(
    "cim_sched_flatlined_jobs_total",
    "Jobs whose bandit improvement rate flatlined mid-race")
for _m in (_M_SCHED_RELEASED, _M_SCHED_ABSORBED, _M_SCHED_FLATLINED):
    _m.inc(0)              # eager child: families render even when idle


# --------------------------------------------------------------------- #
# persistent (cross-process) compilation cache
# --------------------------------------------------------------------- #
_persistent_cache_dir: str | None = None


def enable_persistent_compilation_cache(path: str | None = None) -> str | None:
    """Point JAX's persistent compilation cache at a writable directory.

    On by default for every :class:`ExplorationEngine` so benchmark and CI
    processes reuse each other's compiles.  Respects an operator-provided
    ``JAX_COMPILATION_CACHE_DIR``/pre-set config; set
    ``CIM_TUNER_DISABLE_PERSISTENT_CACHE=1`` to opt out.  Returns the active
    cache directory (or ``None`` when disabled).
    """
    global _persistent_cache_dir
    if os.environ.get("CIM_TUNER_DISABLE_PERSISTENT_CACHE"):
        return None
    current = jax.config.jax_compilation_cache_dir
    if current:
        _persistent_cache_dir = current
        return current
    path = (
        path
        or os.environ.get("CIM_TUNER_COMPILE_CACHE")
        or os.path.join(
            os.path.expanduser("~"), ".cache", "cim-tuner", "jax-cache")
    )
    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        # our SA executables compile in O(1s); make sure they qualify
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
        # JAX latches "cache disabled" at its FIRST compile (tiny ops fire
        # during import, before this config lands); reset so the next
        # compile re-initializes against the directory we just set
        from jax.experimental.compilation_cache import (
            compilation_cache as jax_cc,
        )
        jax_cc.reset_cache()
    except Exception:                                  # pragma: no cover
        return None                                    # read-only FS etc.
    _persistent_cache_dir = path
    return path


# --------------------------------------------------------------------- #
# job description + result
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ExploreJob:
    """One (macro, workload, objective, strategy set, area budget) job."""

    macro: MacroSpec
    workload: Workload
    area_budget_mm2: float
    objective: str = "ee"
    strategy_set: str = "st"
    bw: int = 256
    tech: TechConstants = DEFAULT_TECH
    space: DesignSpace | None = None
    merge_ops: bool = True
    #: search backend used when ``run(method=None)`` -- any registered
    #: ``repro.search`` backend name, or "exhaustive"
    search_method: str = "sa"
    #: optional per-job backend settings (the backend's settings
    #: dataclass, e.g. ``GASettings``); ``None`` means the backend's
    #: defaults.  Used when ``run(settings=None)`` and the type matches
    #: the effective method's settings class, so one batch may mix
    #: settings (each (bucket, method, settings) group is one jitted
    #: call).  Folds into :func:`job_key` exactly like an explicit
    #: ``settings=`` would.
    search_settings: typing.Any = None

    def merged_workload(self) -> Workload:
        """The operator list actually evaluated (merged unless opted out)."""
        return self.workload.merged() if self.merge_ops else self.workload

    def design_space(self) -> DesignSpace:
        """This job's axis space (the default space when none was given)."""
        return self.space or DesignSpace()


@dataclasses.dataclass
class ExploreResult:
    """One job's answer: the winning config, metrics, and search record."""

    config: AcceleratorConfig
    macro: MacroSpec
    workload: str
    objective: str
    strategy_set: str
    per_op_strategy: dict[str, str]
    metrics: dict
    search: dict                      # method, runtime, space stats
    #: per-member diagnostics of the stochastic backend run (named ``sa``
    #: for historical reasons; carries any backend's SearchResult)
    sa: SearchResult | None = None

    def summary(self) -> str:
        """One-line human-readable row (what the CLI/benchmarks print)."""
        c = self.config
        return (
            f"[{self.workload} | {self.macro.name} | {self.objective}/"
            f"{self.strategy_set}] (MR,MC,SCR,IS,OS)="
            f"({c.mr},{c.mc},{c.scr},{c.is_kb},{c.os_kb}) "
            f"EE={self.metrics['tops_w']:.2f} TOPS/W "
            f"Th={self.metrics['gops']:.1f} GOPS "
            f"area={self.metrics['area_mm2']:.2f} mm^2"
        )


# --------------------------------------------------------------------- #
# canonical job identity (dedup + the service result store)
# --------------------------------------------------------------------- #
#: bump when the cost model / result schema changes meaning, so persisted
#: results keyed under the old schema stop matching.  Schema 2 folded
#: (search method, backend settings) into the key for EVERY backend, so a
#: warm-store SA result can never be returned for a GA/DE/Sobol/portfolio
#: query (or vice versa).  Schema 3: ``ExploreJob.search_settings`` joined
#: the job dataclass; it is normalized OUT of the job's canonical form and
#: hashed through the key's single ``settings`` slot instead, so the
#: "settings on the job" and "settings as an argument" spellings of one
#: exploration share a key.  Schema 4: a ``calibration`` slot joined the
#: payload -- the active calibration version when the settings request
#: measured fidelity, ``None`` otherwise -- so warm analytic results can
#: never answer calibrated queries (and a re-fit calibration can never be
#: answered by a stale measured result).  Schema 5: ``PortfolioSettings``
#: grew the budget-flow / device-affinity knobs (``flatline_waves``,
#: ``flatline_eps``, ``device_affinity``); they hash through the
#: ``settings`` slot, and the explicit bump retires every pre-scheduler
#: stored result at once instead of only the portfolio ones.
JOB_KEY_SCHEMA = 5


def valid_methods() -> tuple[str, ...]:
    """Every accepted ``method=`` name: the registered ``repro.search``
    backends plus the pruned-space ``"exhaustive"`` sweep."""
    return available_backends() + ("exhaustive",)


def _check_method(method: str) -> None:
    if method != "exhaustive":
        get_backend(method)              # raises ValueError with the list


def preferred_settings(job: "ExploreJob | None", method: str,
                       settings=None):
    """THE settings-precedence rule, in one place: explicit ``settings``
    wins, then a type-matching ``job.search_settings``, else ``None``
    (the caller applies its own default resolution).  Shared by
    :func:`job_key`, :meth:`ExplorationEngine._effective_settings` and
    ``repro.service.queue.resolve_settings`` so the canonical key
    computed at submit time can never diverge from the settings a job
    actually runs with."""
    if method == "exhaustive":
        return None
    if settings is not None:
        return settings
    s = job.search_settings if job is not None else None
    if s is not None and isinstance(s, get_backend(method).settings_cls):
        return s
    return None


def _canonical(obj):
    """JSON-able canonical form of job ingredients (dataclasses, tuples,
    floats-as-hex so equality is bit-exact, not repr-approximate)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        out = {"__type__": type(obj).__name__}
        for f in dataclasses.fields(obj):
            out[f.name] = _canonical(getattr(obj, f.name))
        return out
    if isinstance(obj, (tuple, list)):
        return [_canonical(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return float(obj).hex()
    if isinstance(obj, str) or obj is None:
        return obj
    return repr(obj)                               # pragma: no cover


def job_key(
    job: ExploreJob,
    method: str | None = None,
    settings=None,
) -> str:
    """Content hash identifying one exploration's *answer*.

    Two submissions share a key iff they are guaranteed to produce
    bit-identical results: same job ingredients (macro, workload, budget,
    objective, strategy set, bandwidth, tech constants, design space,
    merge flag), same search method (``None`` defers to
    ``job.search_method``), same backend settings when the method is a
    search backend (``None`` defers to a type-matching
    ``job.search_settings``), and the same x64 mode.  Callers that resolve
    backend *defaults* (the queue, the engine) must pass the resolved
    settings so defaulted and explicit spellings share a key.  Used for
    in-batch dedup (:meth:`ExplorationEngine.run`), in-flight dedup in the
    service queue, and as the content address of the persistent result
    store.
    """
    method = method or job.search_method
    settings = preferred_settings(job, method, settings)
    calibration = None
    if getattr(settings, "fidelity", "analytic") == "measured":
        from repro.core.calibration import active_calibration_version
        calibration = active_calibration_version()
    payload = {
        "schema": JOB_KEY_SCHEMA,
        "calibration": calibration,
        # normalize search_method into the job (so "method override" and
        # "job field" spellings of the same exploration share a key) and
        # search_settings OUT of it (hashed via the "settings" slot below,
        # so the job-field and argument spellings share a key too)
        "job": _canonical(dataclasses.replace(
            job, space=job.design_space(), search_method=method,
            search_settings=None)),
        "method": method,
        "settings": _canonical(settings) if method != "exhaustive" else None,
        "x64": bool(jax.config.jax_enable_x64),
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class _PreparedJob(typing.NamedTuple):
    job: ExploreJob
    workload: Workload               # merged view actually evaluated
    ops_pad: int                     # operator bucket width
    mat: np.ndarray                  # [5, L] axis-value matrix (unpadded L)
    lens: np.ndarray                 # [5]


def _pow2_at_least(n: int, floor: int = 4) -> int:
    return max(floor, 1 << (int(n) - 1).bit_length())


def _job_arrays(p: _PreparedJob) -> cost_model.JobParams:
    """Numpy-leaved JobParams for one prepared job (stacked by the caller)."""
    j = p.job
    return cost_model.JobParams(
        ops=p.workload.as_arrays(pad_to=p.ops_pad),
        macro=cost_model.MacroParams(*[
            np.float64(v)
            for v in cost_model.macro_params(j.macro, j.tech)]),
        tech=cost_model.TechParams(*[
            np.float64(v) for v in cost_model.tech_params(j.tech)]),
        allowed=np.asarray(cost_model.strategy_mask(j.strategy_set),
                           dtype=np.float64),
        obj_code=np.int32(cost_model.objective_code(j.objective)),
        area_budget=np.float64(j.area_budget_mm2),
        bw=np.float64(j.bw),
    )


def _stack_jobs(rows: list[cost_model.JobParams]) -> cost_model.JobParams:
    return jax.tree.map(lambda *xs: np.stack(xs), *rows)


def _spearman(a: np.ndarray, b: np.ndarray) -> float:
    """Spearman rank correlation between two value vectors (1.0 for
    degenerate inputs: fewer than two points, or zero rank variance).
    The two-fidelity report uses it to quantify how well the analytic
    ranking predicted the measured one."""
    a = np.asarray(a, dtype=float)
    b = np.asarray(b, dtype=float)
    if len(a) < 2:
        return 1.0
    ra = np.argsort(np.argsort(a, kind="stable"),
                    kind="stable").astype(float)
    rb = np.argsort(np.argsort(b, kind="stable"),
                    kind="stable").astype(float)
    da, db = ra - ra.mean(), rb - rb.mean()
    denom = float(np.sqrt((da ** 2).sum() * (db ** 2).sum()))
    if denom == 0.0:                                   # pragma: no cover
        return 1.0
    return float((da * db).sum() / denom)


def clone_result(r: ExploreResult) -> ExploreResult:
    """Fan-out copy for deduped submissions (fresh mutable containers so
    callers mutating one result cannot alias another).  ``search`` is
    deep-copied: portfolio results nest mutable dicts inside it."""
    return dataclasses.replace(
        r, per_op_strategy=dict(r.per_op_strategy),
        metrics=dict(r.metrics), search=copy.deepcopy(r.search))


# --------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------- #
class ExplorationEngine:
    """Runs lists of :class:`ExploreJob` through shared jitted executables.

    One engine instance owns one executable cache; the process-wide
    :func:`default_engine` is shared by the ``co_explore`` family so
    interleaved single-job calls amortize compiles too.  Set
    ``executable_cache=False`` to measure the seed repo's retrace-per-job
    behaviour (the benchmark's "sequential" leg).
    """

    #: candidate block width of the exhaustive executable; every chunked
    #: call shares one compiled signature regardless of candidate count
    EXHAUSTIVE_CHUNK = 4096

    def __init__(
        self,
        sa_settings: SASettings = SASettings(),
        executable_cache: bool = True,
        persistent_compile_cache: bool = True,
        penalty_scale: float = 1e3,
        device_race: bool = True,
    ):
        """Build an engine (one executable cache, optional device racing).

        ``sa_settings`` are the defaults the ``"sa"`` method runs with;
        ``executable_cache=False`` disables the in-process executable
        cache (the benchmark's retrace-per-job "sequential" leg);
        ``device_race=False`` pins portfolio races to the default device
        even when more are visible.
        """
        self.sa_settings = sa_settings
        self.penalty_scale = float(penalty_scale)
        self._use_cache = bool(executable_cache)
        self._device_race = bool(device_race)
        self._executables: dict = {}
        # legacy-shaped per-instance counters mirrored into the
        # process-wide registry (the /v1/metrics families above)
        self.stats = obs.StatCounters({
            "jobs": _M_JOBS.labels(),
            "batches": _M_BATCHES.labels(),
            "dedup_hits": _M_DEDUP.labels(),
            "executable_cache_hits": _M_EXEC.labels(outcome="hit"),
            "executable_cache_misses": _M_EXEC.labels(outcome="miss"),
            "device_race_dispatches": _M_RACE.labels(),
        })
        if persistent_compile_cache:
            enable_persistent_compilation_cache()

    def stats_snapshot(self) -> dict:
        """JSON-able counter view for service introspection (``/v1/stats``):
        the run counters plus the live executable-cache size and the active
        persistent compile-cache directory."""
        return {
            **self.stats.snapshot(),
            "executable_cache_size": len(self._executables),
            "persistent_compile_cache": _persistent_cache_dir,
        }

    # ------------------------------------------------------------- #
    # executable cache
    # ------------------------------------------------------------- #
    @staticmethod
    def _time_first_call(fn, label: str):
        """Wrap a fresh ``jax.jit`` executable so its FIRST invocation --
        where the lazy trace + XLA compile actually happen -- is recorded
        as an ``engine.compile`` span and a ``cim_engine_compile_seconds``
        observation; later calls pass straight through."""
        state = {"first": True}
        lock = threading.Lock()

        def wrapper(*a, **kw):
            with lock:
                first, state["first"] = state["first"], False
            if first:
                t0 = time.perf_counter()
                with obs.span("engine.compile", histogram=_M_COMPILE_S,
                              executable=label):
                    out = fn(*a, **kw)
                _LOG.debug("compiled %s in %.2fs", label,
                           time.perf_counter() - t0)
                return out
            return fn(*a, **kw)

        return wrapper

    def _cached(self, key, build):
        label = str(key[:2])
        if not self._use_cache:
            self.stats.bump("executable_cache_misses")
            return self._time_first_call(build(), label)
        hit = key in self._executables
        self.stats.bump("executable_cache_hits" if hit else
                        "executable_cache_misses")
        if not hit:
            self._executables[key] = self._time_first_call(build(), label)
        return self._executables[key]

    def _search_executable(self, backend, ops_pad: int, axes_pad: int,
                           settings):
        """One jitted vmapped executable per (backend, bucket, settings) --
        every ``repro.search`` backend shares this path, so a GA sweep and
        an SA sweep over the same bucket are two cache entries, each
        compiled once.  For backends honouring the ``seed_free_run``
        contract (all randomness enters via the ``keys`` argument) the RNG
        seed is normalized out of the cache key, so reseeded runs
        (hypothesis sweeps, portfolio rungs) share one compile; backends
        that read ``settings.seed`` inside ``run`` keep the seed in the
        key and compile per seed."""
        cache_settings = settings
        if backend.seed_free_run:
            try:
                cache_settings = dataclasses.replace(settings, seed=0)
            except TypeError:                          # seedless settings
                pass
        key = (backend.name, ops_pad, axes_pad, cache_settings,
               bool(jax.config.jax_enable_x64))

        def build():
            def one_job(job, mat, lens, keys):
                def objective(cfg_row):
                    return cost_model.job_objective(
                        job, cfg_row, self.penalty_scale)
                return backend.run(objective, mat, lens, job.bw, settings,
                                   keys)
            return jax.jit(jax.vmap(one_job))

        return self._cached(key, build)

    def _exhaustive_executable(self, ops_pad: int):
        key = ("exhaustive", ops_pad, self.EXHAUSTIVE_CHUNK,
               bool(jax.config.jax_enable_x64))

        def build():
            def one_job(job, cand_block):
                def objective(cfg_row):
                    return cost_model.job_objective(
                        job, cfg_row, self.penalty_scale)
                return jax.vmap(objective)(cand_block)
            return jax.jit(jax.vmap(one_job))

        return self._cached(key, build)

    # ------------------------------------------------------------- #
    # public API
    # ------------------------------------------------------------- #
    def default_settings(self, method: str):
        """Effective settings when the caller supplies none: the engine's
        construction-time ``sa_settings`` for SA (back-compat), the
        backend's defaults otherwise, ``None`` for exhaustive."""
        if method == "exhaustive":
            return None
        if method == "sa":
            return self.sa_settings
        return get_backend(method).default_settings()

    def _resolve_settings(self, method: str, settings):
        if method == "exhaustive":
            return None                # sweep has no knobs; ignore settings
        if settings is None:
            return self.default_settings(method)
        backend = get_backend(method)
        if not isinstance(settings, backend.settings_cls):
            raise TypeError(
                f"method {method!r} expects {backend.settings_cls.__name__}"
                f" settings, got {type(settings).__name__}")
        return settings

    def _effective_settings(self, job: ExploreJob, method: str, settings):
        """The settings one job actually runs with: the shared
        :func:`preferred_settings` precedence (explicit > type-matching
        ``job.search_settings``), then this engine's defaults.  A type
        MISmatch -- job settings left over from a different
        ``search_method`` under a ``method=`` override -- silently falls
        back to defaults."""
        if settings is not None:
            return self._resolve_settings(method, settings)  # type-check
        s = preferred_settings(job, method)
        return s if s is not None else self.default_settings(method)

    def run(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        settings=None,
        sa_settings: SASettings | None = None,
        keys: typing.Sequence[str] | None = None,
        admit: typing.Callable[[], list] | None = None,
    ) -> list[ExploreResult]:
        """Co-explore every job; results come back in submission order.

        ``method`` is any registered ``repro.search`` backend name
        (``"sa"``, ``"genetic"``, ``"evolution"``, ``"sobol"``,
        ``"portfolio"``, ...) or ``"exhaustive"``; ``None`` uses each
        job's own ``search_method``, so one batch may mix methods (each
        (method, shape bucket, settings) group runs as one jitted call).
        ``settings`` must match the backend's settings class, requires a
        homogeneous method across the batch, and overrides every job's
        own ``search_settings``; with ``settings=None`` each job runs
        with its ``search_settings`` (backend defaults when unset), so
        one batch may also mix settings -- e.g. bandit- and
        halving-allocator portfolios side by side.  ``sa_settings`` is
        the legacy alias.  ``keys`` lets callers that already computed
        :func:`job_key` for each job (the service queue) skip re-hashing;
        when given it must align 1:1 with ``jobs``.

        ``admit`` is the continuous-batching admission hook (see
        docs/scheduler.md): a callable polled once per bandit wave that
        returns late-arriving ``(job, key)`` pairs to join the in-flight
        race at the next rung boundary.  It requires a single-bucket
        batch running a bandit-allocator portfolio (the only phase
        structure with rung boundaries that keeps per-job schedules
        independent); admitted jobs start their own pull schedule from
        zero, so each one's result is bit-identical to a solo
        submission.  Their results are appended AFTER the initial jobs'
        results, in admission order.
        """
        t_start = time.perf_counter()
        if settings is None:
            settings = sa_settings
        methods = [method or j.search_method for j in jobs]
        for m in set(methods):
            _check_method(m)
        if settings is not None and len(set(methods)) > 1:
            raise ValueError(
                "explicit settings require a single method across the "
                f"batch, got {sorted(set(methods))}")
        eff = [self._effective_settings(j, m, settings)
               for j, m in zip(jobs, methods)]

        # identical submissions (same canonical key) evaluate ONCE; the
        # result fans out to every duplicate slot below
        if keys is None:
            keys = [job_key(j, m, s)
                    for j, m, s in zip(jobs, methods, eff)]
        elif len(keys) != len(jobs):
            raise ValueError(
                f"keys length {len(keys)} != jobs length {len(jobs)}")
        first_of: dict[str, int] = {}
        unique: list[int] = []
        for i, k in enumerate(keys):
            if k in first_of:
                self.stats.bump("dedup_hits")
            else:
                first_of[k] = i
                unique.append(i)

        prepared = {i: self._prepare(jobs[i]) for i in unique}
        self.stats.bump("jobs", len(jobs))

        results: list[ExploreResult | None] = [None] * len(jobs)
        admitted_results: list[ExploreResult] = []
        bucket_groups = self._buckets(
            [(i, prepared[i]) for i in unique], methods, eff)
        if admit is not None:
            self._check_admittable(bucket_groups)
        with obs.span("engine.run", histogram=_M_RUN_S,
                      jobs=len(jobs), unique=len(unique)):
            for (bucket, group_settings), members in bucket_groups.items():
                m = bucket[0]
                idxs = [i for i, _ in members]
                batch = [p for _, p in members]
                self.stats.bump("batches")
                _LOG.debug("batch method=%s jobs=%d bucket=%s",
                           m, len(idxs), bucket)
                with obs.span("engine.batch", method=m, jobs=len(idxs),
                              bucket=str(bucket)):
                    if m == "exhaustive":
                        outs = self._run_exhaustive_batch(batch)
                    else:
                        backend = get_backend(m)
                        if backend.composite:
                            outs = self._run_portfolio_batch(
                                batch, group_settings,
                                job_keys=[keys[i] for i in idxs],
                                admit=None if admit is None else
                                self._wrap_admit(admit, bucket, m))
                            # rung-admitted jobs ride behind the initial
                            # batch; their results resolve positionally
                            # after every submitted job's
                            admitted_results = list(outs[len(idxs):])
                            outs = outs[:len(idxs)]
                        else:
                            outs = self._run_search_batch(batch, backend,
                                                          group_settings)
                for i, out in zip(idxs, outs):
                    results[i] = out
        fanout: dict[str, int] = {}
        for i, k in enumerate(keys):
            if results[i] is None:
                results[i] = clone_result(results[first_of[k]])
                fanout[k] = fanout.get(k, 0) + 1
        # dedup provenance: a timeline whose result fanned out to
        # duplicate slots says so (annotate no-ops for keys without one)
        recorder = obs.flight_recorder()
        for k, n in fanout.items():
            recorder.annotate(k, dedup_fanout=n)

        results.extend(admitted_results)
        runtime = time.perf_counter() - t_start
        for r in results:
            r.search["runtime_s"] = runtime
            r.search["batch_jobs"] = len(results)
        return typing.cast("list[ExploreResult]", results)

    def candidate_values(
        self,
        jobs: typing.Sequence[ExploreJob],
        candidates: typing.Sequence[np.ndarray],
    ) -> list[np.ndarray]:
        """Objective values of explicit candidate lists, one ``[C_j]`` float
        array per job (batched across jobs; used by the Pareto frontier)."""
        prepared = [self._prepare(j) for j in jobs]
        out: list[np.ndarray | None] = [None] * len(prepared)
        groups: dict = {}
        for i, p in enumerate(prepared):
            groups.setdefault(p.ops_pad, []).append(i)
        for ops_pad, idxs in groups.items():
            stacked = _stack_jobs([_job_arrays(prepared[i]) for i in idxs])
            vals = self._sweep_values(
                ops_pad, stacked, [np.asarray(candidates[i], np.float64)
                                   for i in idxs])
            for i, v in zip(idxs, vals):
                out[i] = v
        return typing.cast("list[np.ndarray]", out)

    # ------------------------------------------------------------- #
    # internals
    # ------------------------------------------------------------- #
    def _prepare(self, job: ExploreJob) -> _PreparedJob:
        wl = job.merged_workload()
        mat, lens = _axes_matrix(job.design_space())
        return _PreparedJob(
            job=job, workload=wl,
            ops_pad=_pow2_at_least(len(wl.ops)),
            mat=mat, lens=lens,
        )

    def bucket_key(self, job: ExploreJob, method: str | None = None) -> tuple:
        """Executable-signature bucket of a job: jobs sharing a bucket run
        in one batched call (the service queue groups submissions by this
        so each micro-batch dispatches as exactly one ``run()``)."""
        method = method or job.search_method
        return self._bucket_key(self._prepare(job), method)

    @staticmethod
    def _bucket_key(p: _PreparedJob, method: str) -> tuple:
        if method == "exhaustive":
            return ("exhaustive", p.ops_pad)
        return (method, p.ops_pad, _pow2_at_least(p.mat.shape[1]))

    def _buckets(
        self, prepared: list[tuple[int, _PreparedJob]],
        methods: typing.Sequence[str],
        eff: typing.Sequence,
    ) -> dict:
        """Group (index, prepared) pairs by (executable signature,
        effective settings), preserving order -- jobs only share a batched
        call when both their compiled signature AND their resolved
        settings agree (settings dataclasses are frozen, hence hashable).
        """
        groups: dict = {}
        for i, p in prepared:
            key = (self._bucket_key(p, methods[i]), eff[i])
            groups.setdefault(key, []).append((i, p))
        return groups

    # ---- continuous-batching admission (docs/scheduler.md) -------- #
    @staticmethod
    def _check_admittable(bucket_groups: dict) -> None:
        """Reject ``admit=`` for batches that have no rung boundaries to
        admit at: admission needs exactly one executable bucket, running
        the composite portfolio under the bandit allocator (halving
        culls across rungs and plain backends are single-shot, so a
        late join would perturb the in-flight jobs)."""
        if len(bucket_groups) != 1:
            raise ValueError(
                "rung admission requires a single executable bucket per "
                f"run() call, got {len(bucket_groups)} groups")
        ((bucket, group_settings),) = bucket_groups.keys()
        m = bucket[0]
        if m == "exhaustive" or not get_backend(m).composite or \
                getattr(group_settings, "allocator", None) != "bandit":
            raise ValueError(
                "rung admission requires a bandit-allocator portfolio "
                f"group, got method={m!r} allocator="
                f"{getattr(group_settings, 'allocator', None)!r}")

    def _wrap_admit(self, admit, bucket: tuple, method: str):
        """Engine-side admission shim: prepares each late ``(job, key)``
        pair the caller's hook returns and verifies it really belongs to
        the in-flight executable bucket (the queue only offers
        compatible entries; a mismatch is a programming error that would
        silently corrupt the batched launch shapes)."""
        def engine_admit() -> list:
            out = []
            for job, key in admit():
                p = self._prepare(job)
                got = self._bucket_key(p, method)
                if got != bucket:
                    raise ValueError(
                        f"admitted job bucket {got} does not match the "
                        f"in-flight group bucket {bucket}")
                self.stats.bump("jobs")
                out.append((key, p))
            return out
        return engine_admit

    # ---- pluggable search-backend path ---------------------------- #
    def _dispatch_backend_async(
        self, batch: list[_PreparedJob], backend, settings,
        device=None, seed_rows: typing.Sequence[int] | None = None,
    ):
        """One batched backend call over a shape bucket, dispatched
        asynchronously (the returned triple holds live JAX arrays; JAX's
        async dispatch lets the portfolio launch several backends --
        possibly on several devices -- before blocking on any of them).

        ``device`` commits every operand to that device before the call,
        so the jitted executable runs there (``None`` = default
        placement); ``seed_rows`` supplies one RNG seed per job (the
        bandit allocator's per-job pull counters diverge, so one settings
        object can carry several jobs' seeds).
        """
        axes_pad = _pow2_at_least(max(p.mat.shape[1] for p in batch))
        stacked = _stack_jobs([_job_arrays(p) for p in batch])
        mats = np.stack([
            np.concatenate(
                [p.mat, np.repeat(p.mat[:, -1:], axes_pad - p.mat.shape[1],
                                  axis=1)], axis=1)
            for p in batch])                                 # [J, 5, L]
        lens = np.stack([p.lens for p in batch])             # [J, 5]
        if seed_rows is None:
            keys = np.stack([
                np.asarray(backend.make_keys(settings)) for _ in batch])
        else:
            keys = np.stack([
                np.asarray(backend.make_keys(
                    settings, key=jax.random.PRNGKey(int(s))))
                for s in seed_rows])

        fn = self._search_executable(
            backend, batch[0].ops_pad, axes_pad, settings)
        operands = (stacked, jnp.asarray(mats), jnp.asarray(lens),
                    jnp.asarray(keys))
        if device is not None:
            operands = jax.device_put(operands, device)
            self.stats.bump("device_race_dispatches")
        return fn(*operands)

    def _dispatch_backend(
        self, batch: list[_PreparedJob], backend, settings,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One batched backend call over a shape bucket.  Returns numpy
        ``(best_idx [J, members, 5], best_val [J, members],
        trace [J, steps])``."""
        best_idx, best_val, trace = self._dispatch_backend_async(
            batch, backend, settings)
        return (np.asarray(best_idx), np.asarray(best_val),
                np.asarray(trace))

    def _wrap_search_winner(
        self, p: _PreparedJob, method: str,
        best_idx: np.ndarray,          # [members, 5] of this job
        best_val: np.ndarray,          # [members]
        trace: np.ndarray,             # [steps]
    ) -> ExploreResult:
        """Shared epilogue of every stochastic backend: pick the winning
        member, snap-verify the area budget, attach diagnostics."""
        job = p.job
        winner = int(np.argmin(best_val))
        vals = p.mat[np.arange(5), best_idx[winner]]
        diag = SearchResult(
            best_cfg=jnp.asarray(
                np.concatenate([vals, [float(job.bw)]])),
            best_value=jnp.asarray(best_val[winner]),
            best_per_chain=jnp.asarray(best_val),
            trace_best=jnp.asarray(trace),
        )
        cfg = AcceleratorConfig(
            *[int(round(v)) for v in vals], bw=job.bw)
        search: dict = {"method": method,
                        "merged_ops": len(p.workload.ops),
                        "raw_ops": len(job.workload.ops)}
        # backends walk the raw grid with an area penalty; snap-verify
        # feasibility and fall back to the pruned-space optimum if the
        # penalty let the winner out of budget (rare)
        if accelerator_area_mm2(cfg, job.macro, job.tech) > \
                job.area_budget_mm2 * 1.001:
            cfg, stats = self._exhaustive_one(p)
            search.update(stats)
        return self._finish(p, cfg, search, diag)

    def _run_search_batch(
        self, batch: list[_PreparedJob], backend, settings,
    ) -> list[ExploreResult]:
        best_idx, best_val, trace = self._dispatch_backend(
            batch, backend, settings)
        return [
            self._wrap_search_winner(
                p, backend.name, best_idx[jx], best_val[jx], trace[jx])
            for jx, p in enumerate(batch)
        ]

    # ---- portfolio (bandit / successive-halving racer) ------------ #
    def _race_devices(self) -> list:
        """Devices portfolio race waves round-robin across.  ``[None]``
        (default placement, no transfer) when only one device is visible
        or ``device_race=False`` -- the single-device fallback is the same
        code path with no placement step."""
        if not self._device_race:
            return [None]
        from repro.core.distributed import race_devices

        devs = race_devices()
        return list(devs) if len(devs) > 1 else [None]

    def _run_portfolio_batch(
        self, batch: list[_PreparedJob], settings,
        job_keys: typing.Sequence[str] | None = None,
        admit: typing.Callable[[], list] | None = None,
    ) -> list[ExploreResult]:
        """Race the constituent backends per job under the settings'
        budget allocator, then spend the remaining budget on each job's
        winner.  The reported best is the min across every phase.
        ``job_keys`` (aligned 1:1 with ``batch``) enables per-rung
        progress events on :func:`repro.obs.progress_bus` -- one event
        per job per race wave plus a ``phase="final"`` event -- so SSE
        clients watch the race converge.

        ``allocator="bandit"``: after one initialization pull per backend
        (identical to halving's rung 0), each adaptive pull goes to the
        per-job UCB argmax over observed improvement rates -- rewards come
        from the best-so-far traces the runs already return, so the
        schedule is bit-deterministic given the seed.
        ``allocator="halving"``: fixed rungs, per-job culling to the best
        ``ceil(k/2)`` each rung.

        The bandit race runs as a continuous-batching wave scheduler
        (docs/scheduler.md): every bandit state (pull counters, rewards,
        UCB choice, derived seeds) is per-job, so the wave loop carries
        each job through its OWN schedule and two extensions fall out
        without perturbing anyone's trajectory:

        * ``admit`` -- prepared late jobs returned by the hook (see
          :meth:`run`) join the next wave at pull 0 and race to
          completion inside this call; with no arrivals the loop is
          bit-identical to the classic closed-batch race.
        * cross-job budget flow -- with ``settings.flatline_waves > 0``,
          a job whose last ``flatline_waves`` adaptive pulls each earned
          reward below ``flatline_eps`` releases its remaining race
          pulls into a shared pool that still-improving jobs drain one
          pull per wave; per-job accounting lands in
          ``search["budget_flow"]`` and as ``phase="budget_flow"``
          SSE/recorder events.

        Every wave's constituent runs are dispatched asynchronously and
        placed across the visible JAX devices (:meth:`_race_devices`;
        round-robin, or pinned per constituent via
        ``settings.device_affinity``); the fold of each wave's results
        into the per-job incumbents is the per-rung best exchange (the
        host-side analogue of ``core/distributed.py``'s ``pmin``
        collective).
        """
        from repro.search.portfolio import (
            bandit_pull_plan,
            bandit_rounds,
            constituent_devices,
            derived_seed,
            final_plan,
            pull_reward,
            race_plan,
            ucb_scores,
        )

        batch = list(batch)
        job_keys = None if job_keys is None else list(job_keys)
        if admit is not None and job_keys is None:
            raise ValueError("rung admission requires job_keys")
        names = settings.backends
        n_jobs, n_back = len(batch), len(names)
        devices = self._race_devices()
        n_devices = sum(d is not None for d in devices) or 1
        dev_of = constituent_devices(settings, devices)
        bus = obs.progress_bus()
        recorder = obs.flight_recorder()
        # the flight recorder opens one decision timeline per job,
        # capturing the same per-rung payloads the SSE bus publishes
        # (so the two reconcile exactly) plus bandit internals
        device_map = {name: str(dev_of[b_idx] or "default")
                      for b_idx, name in enumerate(names)}
        if job_keys is not None:
            for j in range(n_jobs):
                recorder.start(
                    job_keys[j], method="portfolio",
                    allocator=settings.allocator, backends=list(names),
                    devices=n_devices, device_map=device_map,
                    total_evals=settings.total_evals,
                    rungs=settings.rungs, seed=settings.seed)
        best_val = np.full(n_jobs, np.inf)
        best_idx = np.zeros((n_jobs, 5), dtype=np.int64)
        per_backend = np.full((n_jobs, n_back), np.inf)
        # diagnostics track the run that PRODUCED each job's current best,
        # so min(best_per_chain) == min(trace_best) == the reported value
        member_vals: list[np.ndarray | None] = [None] * n_jobs
        traces: list[np.ndarray | None] = [None] * n_jobs
        # per-job candidate pool across every phase (axis-index tuple ->
        # best analytic value seen); the measured fidelity's final phase
        # re-scores the top-K of this pool with calibrated constants
        pool: list[dict[tuple, float]] = [dict() for _ in range(n_jobs)]

        def _launch(b_idx: int, scaled, sel: list[int],
                    seed_rows=None):
            """Dispatch one backend's run over ``sel`` (async, possibly on
            a non-default device); returns a handle for :func:`_collect`.
            """
            if not sel:
                return None
            arrays = self._dispatch_backend_async(
                [batch[j] for j in sel], get_backend(names[b_idx]), scaled,
                device=dev_of[b_idx], seed_rows=seed_rows)
            return (b_idx, sel, arrays)

        def _collect(handle, prev=None,
                     fold_race=True) -> dict[int, tuple[float, float]]:
            """Block on one launched run and fold it into the per-job
            incumbents (the best exchange); returns ``{job: (run best,
            pull reward vs the pre-wave incumbents ``prev``)}``.  Only
            the bandit race passes ``prev`` -- the halving and final
            phases don't consume rewards, so none are computed."""
            b_idx, sel, (idx_a, val_a, tr_a) = handle
            idx_a, val_a, tr_a = (np.asarray(idx_a), np.asarray(val_a),
                                  np.asarray(tr_a))
            out: dict[int, tuple[float, float]] = {}
            for pos, j in enumerate(sel):
                w = int(np.argmin(val_a[pos]))
                v = float(val_a[pos, w])
                out[j] = (v, pull_reward(prev[j], tr_a[pos])
                          if prev is not None else 0.0)
                if fold_race:
                    per_backend[j, b_idx] = min(per_backend[j, b_idx], v)
                if v < best_val[j]:
                    best_val[j] = v
                    best_idx[j] = idx_a[pos, w]
                    member_vals[j] = val_a[pos]
                    traces[j] = tr_a[pos]
                pj = pool[j]
                for m in range(len(val_a[pos])):
                    vm = float(val_a[pos, m])
                    if not np.isfinite(vm):
                        continue
                    t = tuple(int(x) for x in idx_a[pos, m])
                    if vm < pj.get(t, np.inf):
                        pj[t] = vm
            return out

        pulls = np.zeros((n_jobs, n_back), dtype=np.int64)

        def _record_pull(j: int, b_idx: int) -> None:
            """Bookkeeping shared by every phase: the per-(job, backend)
            pull counter plus the process-wide pull family."""
            pulls[j, b_idx] += 1
            _M_PULLS.inc(backend=names[b_idx],
                         allocator=settings.allocator)

        def _fin(v: float) -> float | None:
            return float(v) if np.isfinite(v) else None

        def _publish(phase: str, rung: int,
                     jobs_touched: typing.Iterable[int],
                     rewards: dict | None = None,
                     ucb=None, chosen: dict | None = None) -> None:
            """One progress event per touched job after a race wave (the
            SSE ``progress`` payload; no-op when the caller didn't pass
            ``job_keys``).  The identical payload lands on the flight
            recorder, extended with the wave's bandit internals
            (``rewards`` per job, UCB ``scores`` and the ``chosen``
            arm) so timelines reconcile with the SSE stream exactly.
            ``chosen`` maps job -> backend index for the jobs that made
            an ADAPTIVE pull this wave; initialization pulls carry no
            UCB state, so a mixed wave (admitted jobs initializing next
            to veterans) only attaches ucb/chosen to the veterans."""
            if job_keys is None:
                return
            for j in jobs_touched:
                payload = dict(
                    phase=phase, allocator=settings.allocator,
                    rung=rung, best=_fin(best_val[j]),
                    backend_best={name: _fin(per_backend[j, b])
                                  for b, name in enumerate(names)},
                    pulls={name: int(pulls[j, b])
                           for b, name in enumerate(names)},
                    devices=n_devices)
                bus.publish(job_keys[j], **payload)
                if rewards is not None and j in rewards:
                    payload["rewards"] = rewards[j]
                if chosen is not None and j in chosen:
                    if ucb is not None:
                        payload["ucb"] = {name: _fin(ucb[j, b])
                                          for b, name in enumerate(names)}
                    payload["chosen"] = names[int(chosen[j])]
                recorder.event(job_keys[j], payload)

        # cross-job budget-flow accounting (bandit allocator only; the
        # halving branch leaves the defaults, so ``search["budget_flow"]``
        # reads uniformly for every portfolio result)
        flatlined = [False] * n_jobs
        released = [0] * n_jobs
        absorbed = [0] * n_jobs
        admit_wave = [0] * n_jobs
        spare_pulls = 0

        if settings.allocator == "halving":
            alive = np.ones((n_jobs, n_back), dtype=bool)
            for rung_no, rung in enumerate(race_plan(settings)):
                _M_RUNGS.inc(allocator="halving")
                with obs.span("engine.portfolio.rung", allocator="halving",
                              rung=rung_no, jobs=n_jobs):
                    handles = [
                        _launch(b_idx, rung[name],
                                [j for j in range(n_jobs)
                                 if alive[j, b_idx]])
                        for b_idx, name in enumerate(names)]
                    for h in handles:
                        if h is not None:
                            for j in _collect(h):
                                _record_pull(j, h[0])
                _publish("race", rung_no, range(n_jobs))
                # cull: each job keeps its best ceil(k/2) survivors
                for j in range(n_jobs):
                    live = np.flatnonzero(alive[j])
                    keep = -(-len(live) // 2)
                    order = live[np.argsort(per_backend[j, live],
                                            kind="stable")]
                    alive[j, order[keep:]] = False
        else:                                          # "bandit"
            # continuous-batching wave scheduler: every job carries its
            # OWN pull schedule (counters, rewards, derived seeds), so a
            # closed batch replays the classic init-then-adaptive race
            # bit-for-bit while late-admitted jobs start at pull 0 and
            # follow exactly their solo trajectory (the seed of pull p
            # is derived_seed(seed, backend, p) -- batch-independent)
            sum_reward = np.zeros((n_jobs, n_back))
            base_rounds = bandit_rounds(settings)
            flow_on = settings.flatline_waves > 0
            needs_init = [True] * n_jobs
            race_budget = [base_rounds] * n_jobs
            flat_run = [0] * n_jobs   # consecutive flat adaptive pulls
            wave = 0

            def _admit_pending() -> None:
                """Pull the caller's admission hook and extend every
                per-job state row for the newcomers (they join the next
                wave's initialization pulls)."""
                nonlocal n_jobs, best_val, best_idx, per_backend, \
                    pulls, sum_reward
                for key, p in admit():
                    batch.append(p)
                    job_keys.append(key)
                    best_val = np.append(best_val, np.inf)
                    best_idx = np.concatenate(
                        [best_idx, np.zeros((1, 5), dtype=np.int64)])
                    per_backend = np.concatenate(
                        [per_backend, np.full((1, n_back), np.inf)])
                    pulls = np.concatenate(
                        [pulls, np.zeros((1, n_back), dtype=np.int64)])
                    sum_reward = np.concatenate(
                        [sum_reward, np.zeros((1, n_back))])
                    member_vals.append(None)
                    traces.append(None)
                    pool.append(dict())
                    needs_init.append(True)
                    race_budget.append(base_rounds)
                    flat_run.append(0)
                    flatlined.append(False)
                    released.append(0)
                    absorbed.append(0)
                    admit_wave.append(wave)
                    n_jobs += 1
                    recorder.start(
                        key, method="portfolio",
                        allocator=settings.allocator,
                        backends=list(names), devices=n_devices,
                        device_map=device_map,
                        total_evals=settings.total_evals,
                        rungs=settings.rungs, seed=settings.seed,
                        admitted_wave=wave)

            while True:
                if admit is not None:
                    _admit_pending()
                # plan the wave: newcomers initialize (one pull per
                # backend, == halving's rung 0); veterans with budget
                # make their UCB-argmax adaptive pull (stable: ties
                # resolve to the lower backend index); spent-but-hot
                # jobs drain the shared pool one pull per wave
                init_jobs = [j for j in range(n_jobs) if needs_init[j]]
                chosen: dict[int, int] = {}
                scores = None
                spent = pulls.sum(axis=1)
                ready = [j for j in range(n_jobs)
                         if not needs_init[j] and not flatlined[j]]
                if ready:
                    scores = ucb_scores(
                        sum_reward / np.maximum(pulls, 1), pulls,
                        settings.ucb_c)
                    choice = np.argmax(scores, axis=1)
                    for j in ready:
                        if spent[j] < race_budget[j]:
                            chosen[j] = int(choice[j])
                        elif spare_pulls > 0:
                            spare_pulls -= 1
                            absorbed[j] += 1
                            chosen[j] = int(choice[j])
                            _M_SCHED_ABSORBED.inc()
                            if job_keys is not None:
                                fp = dict(
                                    phase="budget_flow", action="absorb",
                                    allocator=settings.allocator,
                                    rung=wave, absorbed=absorbed[j],
                                    pool=spare_pulls)
                                bus.publish(job_keys[j], **fp)
                                recorder.event(job_keys[j], fp)
                if not init_jobs and not chosen:
                    break
                _M_RUNGS.inc(allocator="bandit")
                prev = best_val.copy()
                touched: set[int] = set()
                wave_rewards: dict[int, dict[str, float]] = {}
                with obs.span("engine.portfolio.rung",
                              allocator="bandit", rung=wave,
                              jobs=n_jobs):
                    handles = []
                    for b_idx in range(n_back):
                        sel = sorted(set(init_jobs) |
                                     {j for j, b in chosen.items()
                                      if b == b_idx})
                        if not sel:
                            continue
                        handles.append(_launch(
                            b_idx, bandit_pull_plan(settings, b_idx, 0),
                            sel,
                            seed_rows=[derived_seed(settings.seed, b_idx,
                                                    int(pulls[j, b_idx]))
                                       for j in sel]))
                    for h in handles:
                        for j, (_v, r) in _collect(h, prev).items():
                            sum_reward[j, h[0]] += r
                            _record_pull(j, h[0])
                            touched.add(j)
                            wave_rewards.setdefault(j, {})[
                                names[h[0]]] = float(r)
                            if flow_on and j in chosen:
                                flat_run[j] = 0 \
                                    if r >= settings.flatline_eps \
                                    else flat_run[j] + 1
                for j in init_jobs:
                    needs_init[j] = False
                _publish("race", wave, sorted(touched),
                         rewards=wave_rewards, ucb=scores, chosen=chosen)
                if flow_on:
                    # flatline release: a job whose improvement rate
                    # dried up hands its unspent race pulls to the pool
                    spent = pulls.sum(axis=1)
                    for j in range(n_jobs):
                        if flatlined[j] or needs_init[j] or \
                                flat_run[j] < settings.flatline_waves:
                            continue
                        rem = int(race_budget[j] - spent[j])
                        flatlined[j] = True
                        _M_SCHED_FLATLINED.inc()
                        if rem > 0:
                            released[j] = rem
                            race_budget[j] = int(spent[j])
                            spare_pulls += rem
                            _M_SCHED_RELEASED.inc(rem)
                        if job_keys is not None:
                            fp = dict(
                                phase="budget_flow", action="release",
                                allocator=settings.allocator, rung=wave,
                                released=rem, pool=spare_pulls,
                                spent=int(spent[j]))
                            bus.publish(job_keys[j], **fp)
                            recorder.event(job_keys[j], fp)
                wave += 1

        # exploitation: the per-job winner gets the remaining budget
        # (kept out of per_backend so `race` stays race-phase-only)
        winners = per_backend.argmin(axis=1)
        final = final_plan(settings)
        final_best = np.full(n_jobs, np.inf)
        with obs.span("engine.portfolio.final", allocator=settings.allocator,
                      jobs=n_jobs):
            handles = [
                _launch(b_idx, final[name],
                        [j for j in range(n_jobs) if winners[j] == b_idx])
                for b_idx, name in enumerate(names)]
            for h in handles:
                if h is None:
                    continue
                for j, (v, _r) in _collect(h, fold_race=False).items():
                    final_best[j] = v

        # measured fidelity: re-score each job's top-K analytic
        # candidates under kernel-measurement-calibrated tech constants
        # and report both rankings plus their rank correlation
        two_fidelity: list[dict | None] = [None] * n_jobs
        measured_prep: list[_PreparedJob | None] = [None] * n_jobs
        measured_idx: list[np.ndarray | None] = [None] * n_jobs
        measured_val = np.full(n_jobs, np.inf)
        if getattr(settings, "fidelity", "analytic") == "measured":
            from repro.core.calibration import (
                calibration_version,
                resolve_corrections,
            )

            with obs.span("engine.portfolio.measured",
                          allocator=settings.allocator, jobs=n_jobs):
                cf, source, meas_records = resolve_corrections()
                version = calibration_version(cf)
                topk = int(getattr(settings, "topk", 8))
                p_cal = [
                    p._replace(job=dataclasses.replace(
                        p.job, tech=p.job.tech.with_corrections(cf)))
                    for p in batch]
                stacked_a = _stack_jobs([_job_arrays(p) for p in batch])
                stacked_m = _stack_jobs([_job_arrays(p) for p in p_cal])
                top_rows, cand_rows = [], []
                for j, p in enumerate(batch):
                    # deterministic top-K: analytic value, then axis
                    # indices break ties
                    ranked = sorted(pool[j].items(),
                                    key=lambda kv: (kv[1], kv[0]))[:topk]
                    top_rows.append([t for t, _v in ranked])
                    cand_rows.append(np.stack([
                        np.concatenate(
                            [p.mat[np.arange(5), np.asarray(t)],
                             [float(p.job.bw)]])
                        for t, _v in ranked]))
                vals_a = self._sweep_values(
                    batch[0].ops_pad, stacked_a, cand_rows)
                vals_m = self._sweep_values(
                    batch[0].ops_pad, stacked_m, cand_rows)
                for j, p in enumerate(batch):
                    va, vm = vals_a[j], vals_m[j]
                    order_a = np.argsort(va, kind="stable")
                    order_m = np.argsort(vm, kind="stable")
                    w = int(order_m[0])
                    measured_prep[j] = p_cal[j]
                    measured_idx[j] = np.asarray(top_rows[j][w],
                                                 dtype=np.int64)
                    measured_val[j] = float(vm[w])
                    two_fidelity[j] = {
                        "source": source,
                        "calibration_version": version,
                        "corrections": cf.as_dict(),
                        "topk": len(va),
                        "measurement_count": len(meas_records),
                        "analytic_ranking": [int(x) for x in order_a],
                        "measured_ranking": [int(x) for x in order_m],
                        "analytic_values": [float(x) for x in va],
                        "measured_values": [float(x) for x in vm],
                        "rank_correlation": _spearman(va, vm),
                        "analytic_winner": [
                            int(x)
                            for x in cand_rows[j][int(order_a[0])][:5]],
                        "measured_winner": [
                            int(x) for x in cand_rows[j][w][:5]],
                    }
                    if job_keys is not None:
                        # parked for the queue to persist as the result's
                        # .measurements.json store sidecar
                        obs.profile.record_measurements(
                            job_keys[j], meas_records)

        if job_keys is not None:
            for j in range(n_jobs):
                payload = dict(
                    phase="final", allocator=settings.allocator,
                    winner=names[int(winners[j])], best=_fin(best_val[j]),
                    final=_fin(final_best[j]),
                    pulls={name: int(pulls[j, b])
                           for b, name in enumerate(names)},
                    devices=n_devices)
                bus.publish(job_keys[j], **payload)
                recorder.event(job_keys[j], payload)
                if two_fidelity[j] is not None:
                    mp = dict(
                        phase="measured", allocator=settings.allocator,
                        best=_fin(measured_val[j]),
                        rank_correlation=two_fidelity[j][
                            "rank_correlation"],
                        topk=two_fidelity[j]["topk"],
                        calibration=two_fidelity[j][
                            "calibration_version"],
                        devices=n_devices)
                    bus.publish(job_keys[j], **mp)
                    recorder.event(job_keys[j], mp)
                recorder.finish(
                    job_keys[j], winner=payload["winner"],
                    best=payload["best"], final=payload["final"],
                    pulls=payload["pulls"])

        results = []
        for j, p in enumerate(batch):
            if measured_prep[j] is not None:
                # the measured winner, finished under calibrated
                # constants, IS the answer of a two-fidelity race
                out = self._wrap_search_winner(
                    measured_prep[j], "portfolio",
                    measured_idx[j][None, :],
                    np.asarray([measured_val[j]]), traces[j])
            else:
                out = self._wrap_search_winner(
                    p, "portfolio", best_idx[j][None, :],
                    np.asarray([best_val[j]]), traces[j])
            out.search["portfolio"] = {
                "winner": names[int(winners[j])],
                "allocator": settings.allocator,
                "race": {name: float(per_backend[j, b])
                         for b, name in enumerate(names)},
                "pulls": {name: int(pulls[j, b])
                          for b, name in enumerate(names)},
                "final": float(final_best[j]),
                "rungs": settings.rungs,
                "total_evals": settings.total_evals,
                "devices": sum(d is not None for d in devices) or 1,
                "fidelity": getattr(settings, "fidelity", "analytic"),
            }
            out.search["budget_flow"] = {
                "enabled": settings.allocator == "bandit"
                and settings.flatline_waves > 0,
                "flatlined": bool(flatlined[j]),
                "released": int(released[j]),
                "absorbed": int(absorbed[j]),
                "race_pulls": int(pulls[j].sum()),
                "pool_leftover": int(spare_pulls),
                "admitted_wave": int(admit_wave[j]),
            }
            if two_fidelity[j] is not None:
                out.search["two_fidelity"] = two_fidelity[j]
            out.sa = out.sa._replace(
                best_per_chain=jnp.asarray(member_vals[j]))
            results.append(out)
        return results

    # ---- exhaustive path ------------------------------------------ #
    def _pruned_candidates(self, p: _PreparedJob) -> tuple[np.ndarray, dict]:
        job = p.job
        cands, stats = prune_space(
            p.job.design_space(), job.macro, job.area_budget_mm2, job.bw,
            job.tech)
        if len(cands) == 0:
            raise ValueError("no feasible hardware point under budget")
        return candidates_with_bw(cands, job.bw), stats

    def _sweep_values(
        self, ops_pad: int, stacked: cost_model.JobParams,
        cand_rows: list[np.ndarray],
    ) -> list[np.ndarray]:
        """Evaluate per-job candidate lists in shared [J, CHUNK] blocks."""
        chunk = self.EXHAUSTIVE_CHUNK
        fn = self._exhaustive_executable(ops_pad)
        n_max = max(len(c) for c in cand_rows)
        vals = [np.empty(len(c)) for c in cand_rows]
        for lo in range(0, n_max, chunk):
            # jobs exhaust their lists at different points; pad every lane
            # to the full chunk with its own first row (values discarded)
            lanes = []
            for c in cand_rows:
                part = c[lo: lo + chunk]
                if len(part) < chunk:
                    fill = np.repeat(c[:1], chunk - len(part), axis=0)
                    part = np.concatenate([part, fill], axis=0) \
                        if len(part) else np.repeat(c[:1], chunk, axis=0)
                lanes.append(part)
            block = np.stack(lanes, axis=0)                  # [J, chunk, 6]
            out = np.asarray(fn(stacked, jnp.asarray(block)))
            for jx, c in enumerate(cand_rows):
                take = min(max(len(c) - lo, 0), chunk)
                if take:
                    vals[jx][lo: lo + take] = out[jx, :take]
        return vals

    def _run_exhaustive_batch(
        self, batch: list[_PreparedJob],
    ) -> list[ExploreResult]:
        stacked = _stack_jobs([_job_arrays(p) for p in batch])
        cands, prune_stats = zip(*[self._pruned_candidates(p) for p in batch])
        vals = self._sweep_values(batch[0].ops_pad, stacked, list(cands))
        results = []
        for p, c, v, st in zip(batch, cands, vals, prune_stats):
            best = int(np.argmin(v))
            cfg = AcceleratorConfig(
                *[int(x) for x in c[best][:5]], bw=p.job.bw)
            search = {"method": "exhaustive",
                      "merged_ops": len(p.workload.ops),
                      "raw_ops": len(p.job.workload.ops), **st}
            results.append(self._finish(p, cfg, search, None))
        return results

    def _exhaustive_one(self, p: _PreparedJob) -> tuple[AcceleratorConfig,
                                                        dict]:
        """Pruned-space optimum of a single job (SA snap-fallback)."""
        rows, stats = self._pruned_candidates(p)
        stacked = _stack_jobs([_job_arrays(p)])
        v = self._sweep_values(p.ops_pad, stacked, [rows])[0]
        best = int(np.argmin(v))
        return AcceleratorConfig(
            *[int(x) for x in rows[best][:5]], bw=p.job.bw), stats

    # ---- shared epilogue ------------------------------------------ #
    def _finish(self, p: _PreparedJob, cfg: AcceleratorConfig, search: dict,
                sa_res: SearchResult | None) -> ExploreResult:
        job = p.job
        cfg_row = jnp.asarray(
            [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw],
            dtype=float)
        metrics = cost_model.workload_metrics(
            p.workload.as_arrays(), cfg_row, job.macro, job.tech,
            job.objective, job.strategy_set)
        per_op = {
            op.name or f"op{i}":
                str(ALL_STRATEGIES[metrics["strategy_idx"][i]])
            for i, op in enumerate(p.workload.ops)
        }
        return ExploreResult(
            config=cfg,
            macro=job.macro,
            workload=job.workload.name,
            objective=job.objective,
            strategy_set=job.strategy_set,
            per_op_strategy=per_op,
            metrics={k: v for k, v in metrics.items()
                     if k != "strategy_idx"},
            search=search,
            sa=sa_res,
        )


# --------------------------------------------------------------------- #
# process-wide default engine (shared executable cache)
# --------------------------------------------------------------------- #
_default_engine: ExplorationEngine | None = None


def default_engine() -> ExplorationEngine:
    """The process-wide engine (one shared executable cache); created
    lazily on first use and shared by the ``co_explore`` family and the
    service queue so interleaved callers amortize compiles."""
    global _default_engine
    if _default_engine is None:
        _default_engine = ExplorationEngine()
    return _default_engine
