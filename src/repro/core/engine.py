"""Batched multi-job hardware-mapping co-exploration engine.

The paper's workflow evaluates one (macro, workload, objective) job at a
time; every sweep-style consumer (Fig. 7's seven networks, Table II's two
baselines x two objectives, macro-library selection, Pareto frontiers)
therefore used to rebuild and re-jit the objective per job -- wall-clock was
dominated by retrace/recompile, not search.  This module batches whole job
lists through shared compiled executables:

1. **Shape bucketing** -- each job's merged operator array is padded to a
   small set of power-of-two widths (padded rows carry ``count == 0`` and are
   cost-transparent), and its design-space axis matrix is padded likewise, so
   heterogeneous jobs share one executable signature.
2. **Job stacking** -- macro/tech constants, strategy masks, objective codes,
   area budgets and bus widths become per-job arrays
   (:class:`repro.core.cost_model.JobParams`) vmapped over a stacked job
   axis: simulated annealing runs *all jobs' chains in one jitted call*, and
   exhaustive sweeps evaluate a ``[jobs, chunk]`` candidate block per call.
3. **Two-level caching** -- an in-process executable cache keyed by (bucket
   shape, SA settings, x64 mode) means repeated submissions never retrace,
   and JAX's persistent compilation cache is switched on by default
   (:func:`enable_persistent_compilation_cache`) so fresh processes -- CI
   runs, benchmark re-runs -- reuse compiles from disk.

Identical jobs inside one ``run()`` (same canonical :func:`job_key`)
evaluate once and fan the result out.  ``co_explore`` / ``co_explore_macros``
/ ``pareto_explore`` (``core/explorer.py``) are thin synchronous clients of
the async DSE service (``repro.service``) built on this engine;
``benchmarks/fig7_mapping.py`` prints the measured batched-vs-sequential
speedup.  ``core/distributed.py`` shards the same job x chain population
across devices.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model
from repro.core.annealing import (
    SAResult,
    SASettings,
    _axes_matrix,
    anneal,
    make_chain_keys,
)
from repro.core.calibration import DEFAULT_TECH, TechConstants
from repro.core.ir import Workload
from repro.core.macro import MacroSpec
from repro.core.pruning import DesignSpace, candidates_with_bw, prune_space
from repro.core.strategies import ALL_STRATEGIES
from repro.core.template import AcceleratorConfig, accelerator_area_mm2

__all__ = [
    "ExploreJob",
    "ExploreResult",
    "ExplorationEngine",
    "default_engine",
    "enable_persistent_compilation_cache",
    "job_key",
]


# --------------------------------------------------------------------- #
# persistent (cross-process) compilation cache
# --------------------------------------------------------------------- #
_persistent_cache_dir: str | None = None


def enable_persistent_compilation_cache(path: str | None = None) -> str | None:
    """Point JAX's persistent compilation cache at a writable directory.

    On by default for every :class:`ExplorationEngine` so benchmark and CI
    processes reuse each other's compiles.  Respects an operator-provided
    ``JAX_COMPILATION_CACHE_DIR``/pre-set config; set
    ``CIM_TUNER_DISABLE_PERSISTENT_CACHE=1`` to opt out.  Returns the active
    cache directory (or ``None`` when disabled).
    """
    global _persistent_cache_dir
    if os.environ.get("CIM_TUNER_DISABLE_PERSISTENT_CACHE"):
        return None
    current = jax.config.jax_compilation_cache_dir
    if current:
        _persistent_cache_dir = current
        return current
    path = (
        path
        or os.environ.get("CIM_TUNER_COMPILE_CACHE")
        or os.path.join(
            os.path.expanduser("~"), ".cache", "cim-tuner", "jax-cache")
    )
    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        # our SA executables compile in O(1s); make sure they qualify
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
        # JAX latches "cache disabled" at its FIRST compile (tiny ops fire
        # during import, before this config lands); reset so the next
        # compile re-initializes against the directory we just set
        from jax.experimental.compilation_cache import (
            compilation_cache as jax_cc,
        )
        jax_cc.reset_cache()
    except Exception:                                  # pragma: no cover
        return None                                    # read-only FS etc.
    _persistent_cache_dir = path
    return path


# --------------------------------------------------------------------- #
# job description + result
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ExploreJob:
    """One (macro, workload, objective, strategy set, area budget) job."""

    macro: MacroSpec
    workload: Workload
    area_budget_mm2: float
    objective: str = "ee"
    strategy_set: str = "st"
    bw: int = 256
    tech: TechConstants = DEFAULT_TECH
    space: DesignSpace | None = None
    merge_ops: bool = True

    def merged_workload(self) -> Workload:
        return self.workload.merged() if self.merge_ops else self.workload

    def design_space(self) -> DesignSpace:
        return self.space or DesignSpace()


@dataclasses.dataclass
class ExploreResult:
    config: AcceleratorConfig
    macro: MacroSpec
    workload: str
    objective: str
    strategy_set: str
    per_op_strategy: dict[str, str]
    metrics: dict
    search: dict                      # method, runtime, space stats
    sa: SAResult | None = None

    def summary(self) -> str:
        c = self.config
        return (
            f"[{self.workload} | {self.macro.name} | {self.objective}/"
            f"{self.strategy_set}] (MR,MC,SCR,IS,OS)="
            f"({c.mr},{c.mc},{c.scr},{c.is_kb},{c.os_kb}) "
            f"EE={self.metrics['tops_w']:.2f} TOPS/W "
            f"Th={self.metrics['gops']:.1f} GOPS "
            f"area={self.metrics['area_mm2']:.2f} mm^2"
        )


# --------------------------------------------------------------------- #
# canonical job identity (dedup + the service result store)
# --------------------------------------------------------------------- #
#: bump when the cost model / result schema changes meaning, so persisted
#: results keyed under the old schema stop matching
JOB_KEY_SCHEMA = 1


def _canonical(obj):
    """JSON-able canonical form of job ingredients (dataclasses, tuples,
    floats-as-hex so equality is bit-exact, not repr-approximate)."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        out = {"__type__": type(obj).__name__}
        for f in dataclasses.fields(obj):
            out[f.name] = _canonical(getattr(obj, f.name))
        return out
    if isinstance(obj, (tuple, list)):
        return [_canonical(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        return float(obj).hex()
    if isinstance(obj, str) or obj is None:
        return obj
    return repr(obj)                               # pragma: no cover


def job_key(
    job: ExploreJob,
    method: str = "sa",
    sa_settings: SASettings | None = None,
) -> str:
    """Content hash identifying one exploration's *answer*.

    Two submissions share a key iff they are guaranteed to produce
    bit-identical results: same job ingredients (macro, workload, budget,
    objective, strategy set, bandwidth, tech constants, design space,
    merge flag), same search method, same SA settings when the method is
    stochastic, and the same x64 mode.  Used for in-batch dedup
    (:meth:`ExplorationEngine.run`), in-flight dedup in the service queue,
    and as the content address of the persistent result store.
    """
    payload = {
        "schema": JOB_KEY_SCHEMA,
        "job": _canonical(dataclasses.replace(job, space=job.design_space())),
        "method": method,
        "sa": _canonical(sa_settings) if method == "sa" else None,
        "x64": bool(jax.config.jax_enable_x64),
    }
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


class _PreparedJob(typing.NamedTuple):
    job: ExploreJob
    workload: Workload               # merged view actually evaluated
    ops_pad: int                     # operator bucket width
    mat: np.ndarray                  # [5, L] axis-value matrix (unpadded L)
    lens: np.ndarray                 # [5]


def _pow2_at_least(n: int, floor: int = 4) -> int:
    return max(floor, 1 << (int(n) - 1).bit_length())


def _job_arrays(p: _PreparedJob) -> cost_model.JobParams:
    """Numpy-leaved JobParams for one prepared job (stacked by the caller)."""
    j = p.job
    return cost_model.JobParams(
        ops=p.workload.as_arrays(pad_to=p.ops_pad),
        macro=cost_model.MacroParams(*[
            np.float64(v)
            for v in cost_model.macro_params(j.macro, j.tech)]),
        tech=cost_model.TechParams(*[
            np.float64(v) for v in cost_model.tech_params(j.tech)]),
        allowed=np.asarray(cost_model.strategy_mask(j.strategy_set),
                           dtype=np.float64),
        obj_code=np.int32(cost_model.objective_code(j.objective)),
        area_budget=np.float64(j.area_budget_mm2),
        bw=np.float64(j.bw),
    )


def _stack_jobs(rows: list[cost_model.JobParams]) -> cost_model.JobParams:
    return jax.tree.map(lambda *xs: np.stack(xs), *rows)


def clone_result(r: ExploreResult) -> ExploreResult:
    """Fan-out copy for deduped submissions (fresh mutable containers so
    callers mutating one result cannot alias another)."""
    return dataclasses.replace(
        r, per_op_strategy=dict(r.per_op_strategy),
        metrics=dict(r.metrics), search=dict(r.search))


# --------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------- #
class ExplorationEngine:
    """Runs lists of :class:`ExploreJob` through shared jitted executables.

    One engine instance owns one executable cache; the process-wide
    :func:`default_engine` is shared by the ``co_explore`` family so
    interleaved single-job calls amortize compiles too.  Set
    ``executable_cache=False`` to measure the seed repo's retrace-per-job
    behaviour (the benchmark's "sequential" leg).
    """

    #: candidate block width of the exhaustive executable; every chunked
    #: call shares one compiled signature regardless of candidate count
    EXHAUSTIVE_CHUNK = 4096

    def __init__(
        self,
        sa_settings: SASettings = SASettings(),
        executable_cache: bool = True,
        persistent_compile_cache: bool = True,
        penalty_scale: float = 1e3,
    ):
        self.sa_settings = sa_settings
        self.penalty_scale = float(penalty_scale)
        self._use_cache = bool(executable_cache)
        self._executables: dict = {}
        self.stats = {
            "jobs": 0, "batches": 0, "dedup_hits": 0,
            "executable_cache_hits": 0, "executable_cache_misses": 0,
        }
        if persistent_compile_cache:
            enable_persistent_compilation_cache()

    # ------------------------------------------------------------- #
    # executable cache
    # ------------------------------------------------------------- #
    def _cached(self, key, build):
        if not self._use_cache:
            self.stats["executable_cache_misses"] += 1
            return build()
        hit = key in self._executables
        self.stats["executable_cache_hits" if hit else
                   "executable_cache_misses"] += 1
        if not hit:
            self._executables[key] = build()
        return self._executables[key]

    def _sa_executable(self, ops_pad: int, axes_pad: int,
                       settings: SASettings):
        key = ("sa", ops_pad, axes_pad, settings,
               bool(jax.config.jax_enable_x64))

        def build():
            def one_job(job, mat, lens, keys):
                def objective(cfg_row):
                    return cost_model.job_objective(
                        job, cfg_row, self.penalty_scale)
                return anneal(objective, mat, lens, job.bw, settings, keys)
            return jax.jit(jax.vmap(one_job))

        return self._cached(key, build)

    def _exhaustive_executable(self, ops_pad: int):
        key = ("exhaustive", ops_pad, self.EXHAUSTIVE_CHUNK,
               bool(jax.config.jax_enable_x64))

        def build():
            def one_job(job, cand_block):
                def objective(cfg_row):
                    return cost_model.job_objective(
                        job, cfg_row, self.penalty_scale)
                return jax.vmap(objective)(cand_block)
            return jax.jit(jax.vmap(one_job))

        return self._cached(key, build)

    # ------------------------------------------------------------- #
    # public API
    # ------------------------------------------------------------- #
    def run(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str = "sa",
        sa_settings: SASettings | None = None,
        keys: typing.Sequence[str] | None = None,
    ) -> list[ExploreResult]:
        """Co-explore every job; results come back in submission order.

        ``method="sa"`` anneals all jobs' chains in one jitted call per
        shape bucket; ``method="exhaustive"`` sweeps each job's pruned
        candidate list in shared ``[jobs, chunk]`` blocks.  ``keys`` lets
        callers that already computed :func:`job_key` for each job (the
        service queue) skip re-hashing; when given it must align 1:1 with
        ``jobs``.
        """
        if method not in ("sa", "exhaustive"):
            raise ValueError(f"unknown method {method!r}")
        t_start = time.perf_counter()
        settings = sa_settings or self.sa_settings

        # identical submissions (same canonical key) evaluate ONCE; the
        # result fans out to every duplicate slot below
        if keys is None:
            keys = [job_key(j, method, settings if method == "sa" else None)
                    for j in jobs]
        elif len(keys) != len(jobs):
            raise ValueError(
                f"keys length {len(keys)} != jobs length {len(jobs)}")
        first_of: dict[str, int] = {}
        unique: list[int] = []
        for i, k in enumerate(keys):
            if k in first_of:
                self.stats["dedup_hits"] += 1
            else:
                first_of[k] = i
                unique.append(i)

        prepared = {i: self._prepare(jobs[i]) for i in unique}
        self.stats["jobs"] += len(jobs)

        results: list[ExploreResult | None] = [None] * len(jobs)
        for bucket, members in self._buckets(
                [(i, prepared[i]) for i in unique], method).items():
            del bucket
            idxs = [i for i, _ in members]
            batch = [p for _, p in members]
            self.stats["batches"] += 1
            if method == "sa":
                outs = self._run_sa_batch(batch, settings)
            else:
                outs = self._run_exhaustive_batch(batch)
            for i, out in zip(idxs, outs):
                results[i] = out
        for i, k in enumerate(keys):
            if results[i] is None:
                results[i] = clone_result(results[first_of[k]])

        runtime = time.perf_counter() - t_start
        for r in results:
            r.search["runtime_s"] = runtime
            r.search["batch_jobs"] = len(jobs)
        return typing.cast("list[ExploreResult]", results)

    def candidate_values(
        self,
        jobs: typing.Sequence[ExploreJob],
        candidates: typing.Sequence[np.ndarray],
    ) -> list[np.ndarray]:
        """Objective values of explicit candidate lists, one ``[C_j]`` float
        array per job (batched across jobs; used by the Pareto frontier)."""
        prepared = [self._prepare(j) for j in jobs]
        out: list[np.ndarray | None] = [None] * len(prepared)
        groups: dict = {}
        for i, p in enumerate(prepared):
            groups.setdefault(p.ops_pad, []).append(i)
        for ops_pad, idxs in groups.items():
            stacked = _stack_jobs([_job_arrays(prepared[i]) for i in idxs])
            vals = self._sweep_values(
                ops_pad, stacked, [np.asarray(candidates[i], np.float64)
                                   for i in idxs])
            for i, v in zip(idxs, vals):
                out[i] = v
        return typing.cast("list[np.ndarray]", out)

    # ------------------------------------------------------------- #
    # internals
    # ------------------------------------------------------------- #
    def _prepare(self, job: ExploreJob) -> _PreparedJob:
        wl = job.merged_workload()
        mat, lens = _axes_matrix(job.design_space())
        return _PreparedJob(
            job=job, workload=wl,
            ops_pad=_pow2_at_least(len(wl.ops)),
            mat=mat, lens=lens,
        )

    def bucket_key(self, job: ExploreJob, method: str = "sa") -> tuple:
        """Executable-signature bucket of a job: jobs sharing a bucket run
        in one batched call (the service queue groups submissions by this
        so each micro-batch dispatches as exactly one ``run()``)."""
        return self._bucket_key(self._prepare(job), method)

    @staticmethod
    def _bucket_key(p: _PreparedJob, method: str) -> tuple:
        if method == "sa":
            return (p.ops_pad, _pow2_at_least(p.mat.shape[1]))
        return (p.ops_pad,)

    def _buckets(
        self, prepared: list[tuple[int, _PreparedJob]], method: str,
    ) -> dict:
        """Group (index, prepared) pairs by executable signature,
        preserving order."""
        groups: dict = {}
        for i, p in prepared:
            groups.setdefault(self._bucket_key(p, method), []).append((i, p))
        return groups

    # ---- SA path -------------------------------------------------- #
    def _run_sa_batch(
        self, batch: list[_PreparedJob], settings: SASettings,
    ) -> list[ExploreResult]:
        axes_pad = _pow2_at_least(max(p.mat.shape[1] for p in batch))
        stacked = _stack_jobs([_job_arrays(p) for p in batch])
        mats = np.stack([
            np.concatenate(
                [p.mat, np.repeat(p.mat[:, -1:], axes_pad - p.mat.shape[1],
                                  axis=1)], axis=1)
            for p in batch])                                 # [J, 5, L]
        lens = np.stack([p.lens for p in batch])             # [J, 5]
        keys = np.stack([
            np.asarray(make_chain_keys(settings)) for _ in batch])

        fn = self._sa_executable(batch[0].ops_pad, axes_pad, settings)
        best_idx, best_val, hists = fn(
            stacked, jnp.asarray(mats), jnp.asarray(lens), jnp.asarray(keys))
        best_idx = np.asarray(best_idx)                      # [J, chains, 5]
        best_val = np.asarray(best_val)                      # [J, chains]
        hists = np.asarray(hists)                            # [J, chains, S]

        results = []
        for jx, p in enumerate(batch):
            job = p.job
            winner = int(np.argmin(best_val[jx]))
            vals = p.mat[np.arange(5), best_idx[jx, winner]]
            sa_res = SAResult(
                best_cfg=jnp.asarray(
                    np.concatenate([vals, [float(job.bw)]])),
                best_value=jnp.asarray(best_val[jx, winner]),
                best_per_chain=jnp.asarray(best_val[jx]),
                trace_best=jnp.asarray(hists[jx].min(axis=0)),
            )
            cfg = AcceleratorConfig(
                *[int(round(v)) for v in vals], bw=job.bw)
            search: dict = {"method": "sa",
                            "merged_ops": len(p.workload.ops),
                            "raw_ops": len(job.workload.ops)}
            # SA walks the raw grid with an area penalty; snap-verify
            # feasibility and fall back to the pruned-space optimum if the
            # penalty let the winner out of budget (rare)
            if accelerator_area_mm2(cfg, job.macro, job.tech) > \
                    job.area_budget_mm2 * 1.001:
                cfg, stats = self._exhaustive_one(p)
                search.update(stats)
            results.append(self._finish(p, cfg, search, sa_res))
        return results

    # ---- exhaustive path ------------------------------------------ #
    def _pruned_candidates(self, p: _PreparedJob) -> tuple[np.ndarray, dict]:
        job = p.job
        cands, stats = prune_space(
            p.job.design_space(), job.macro, job.area_budget_mm2, job.bw,
            job.tech)
        if len(cands) == 0:
            raise ValueError("no feasible hardware point under budget")
        return candidates_with_bw(cands, job.bw), stats

    def _sweep_values(
        self, ops_pad: int, stacked: cost_model.JobParams,
        cand_rows: list[np.ndarray],
    ) -> list[np.ndarray]:
        """Evaluate per-job candidate lists in shared [J, CHUNK] blocks."""
        chunk = self.EXHAUSTIVE_CHUNK
        fn = self._exhaustive_executable(ops_pad)
        n_max = max(len(c) for c in cand_rows)
        vals = [np.empty(len(c)) for c in cand_rows]
        for lo in range(0, n_max, chunk):
            # jobs exhaust their lists at different points; pad every lane
            # to the full chunk with its own first row (values discarded)
            lanes = []
            for c in cand_rows:
                part = c[lo: lo + chunk]
                if len(part) < chunk:
                    fill = np.repeat(c[:1], chunk - len(part), axis=0)
                    part = np.concatenate([part, fill], axis=0) \
                        if len(part) else np.repeat(c[:1], chunk, axis=0)
                lanes.append(part)
            block = np.stack(lanes, axis=0)                  # [J, chunk, 6]
            out = np.asarray(fn(stacked, jnp.asarray(block)))
            for jx, c in enumerate(cand_rows):
                take = min(max(len(c) - lo, 0), chunk)
                if take:
                    vals[jx][lo: lo + take] = out[jx, :take]
        return vals

    def _run_exhaustive_batch(
        self, batch: list[_PreparedJob],
    ) -> list[ExploreResult]:
        stacked = _stack_jobs([_job_arrays(p) for p in batch])
        cands, prune_stats = zip(*[self._pruned_candidates(p) for p in batch])
        vals = self._sweep_values(batch[0].ops_pad, stacked, list(cands))
        results = []
        for p, c, v, st in zip(batch, cands, vals, prune_stats):
            best = int(np.argmin(v))
            cfg = AcceleratorConfig(
                *[int(x) for x in c[best][:5]], bw=p.job.bw)
            search = {"method": "exhaustive",
                      "merged_ops": len(p.workload.ops),
                      "raw_ops": len(p.job.workload.ops), **st}
            results.append(self._finish(p, cfg, search, None))
        return results

    def _exhaustive_one(self, p: _PreparedJob) -> tuple[AcceleratorConfig,
                                                        dict]:
        """Pruned-space optimum of a single job (SA snap-fallback)."""
        rows, stats = self._pruned_candidates(p)
        stacked = _stack_jobs([_job_arrays(p)])
        v = self._sweep_values(p.ops_pad, stacked, [rows])[0]
        best = int(np.argmin(v))
        return AcceleratorConfig(
            *[int(x) for x in rows[best][:5]], bw=p.job.bw), stats

    # ---- shared epilogue ------------------------------------------ #
    def _finish(self, p: _PreparedJob, cfg: AcceleratorConfig, search: dict,
                sa_res: SAResult | None) -> ExploreResult:
        job = p.job
        cfg_row = jnp.asarray(
            [cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb, cfg.bw],
            dtype=float)
        metrics = cost_model.workload_metrics(
            p.workload.as_arrays(), cfg_row, job.macro, job.tech,
            job.objective, job.strategy_set)
        per_op = {
            op.name or f"op{i}":
                str(ALL_STRATEGIES[metrics["strategy_idx"][i]])
            for i, op in enumerate(p.workload.ops)
        }
        return ExploreResult(
            config=cfg,
            macro=job.macro,
            workload=job.workload.name,
            objective=job.objective,
            strategy_set=job.strategy_set,
            per_op_strategy=per_op,
            metrics={k: v for k, v in metrics.items()
                     if k != "strategy_idx"},
            search=search,
            sa=sa_res,
        )


# --------------------------------------------------------------------- #
# process-wide default engine (shared executable cache)
# --------------------------------------------------------------------- #
_default_engine: ExplorationEngine | None = None


def default_engine() -> ExplorationEngine:
    global _default_engine
    if _default_engine is None:
        _default_engine = ExplorationEngine()
    return _default_engine
