"""CIM-Tuner core: hardware-mapping co-exploration for SRAM-CIM accelerators.

Public API:
    MacroSpec / MACRO_LIBRARY       -- matrix abstraction of CIM macros
    AcceleratorConfig               -- generalized accelerator template point
    MatmulOp / Workload             -- operator IR (+ size-aware merging)
    Strategy / ALL_STRATEGIES       -- two-level mapping strategy space
    matmul_cost / workload_cost     -- closed-form vectorized cost model
    compile_schedule / compile_trace / replay_trace -- instruction flows
    simulate_schedule               -- cycle simulator
    co_explore / evaluate_config    -- the co-exploration tool
    ExplorationEngine / ExploreJob  -- batched multi-job engine (shared
                                       compiled executables + caching);
                                       search backends are pluggable via
                                       repro.search (sa / genetic /
                                       evolution / sobol / portfolio)
    distributed_co_explore          -- multi-pod DSE (shard_map)
"""
from repro.core.calibration import (
    CALIBRATION_ENV,
    DEFAULT_TECH,
    CorrectionFactors,
    CostModel,
    TechConstants,
    calibration_version,
    default_cost_model,
    fit_corrections,
    fit_report,
    load_calibration,
    reset_default_cost_model,
    resolve_tech,
    save_calibration,
)
from repro.core.compiler import (
    compile_schedule,
    compile_trace,
    replay_trace,
    schedule_totals,
    strategy_feasible,
)
from repro.core.cost_model import (
    CostBreakdown,
    matmul_cost,
    strategy_table,
    workload_cost,
    workload_metrics,
)
from repro.core.distributed import DistributedResult, distributed_co_explore
from repro.core.engine import (ExplorationEngine, ExploreJob,
                               default_engine,
                               enable_persistent_compilation_cache,
                               job_key, valid_methods)
from repro.core.explorer import (ExploreResult, co_explore,
                                 co_explore_macros, evaluate_config,
                                 pareto_explore)
from repro.core.ir import MatmulOp, Workload, bert_large_workload
from repro.core.macro import MACRO_LIBRARY, MacroSpec, get_macro
from repro.core.pruning import DesignSpace, prune_space
from repro.core.annealing import SASettings, exhaustive_search, simulated_annealing
from repro.core.simulator import analytic_latency_bounds, simulate_schedule
from repro.core.strategies import ALL_STRATEGIES, SPATIAL_ONLY, Strategy
from repro.core.template import AcceleratorConfig, accelerator_area_mm2

__all__ = [
    "DEFAULT_TECH", "TechConstants",
    "CostModel", "CorrectionFactors", "CALIBRATION_ENV",
    "default_cost_model", "reset_default_cost_model", "resolve_tech",
    "calibration_version", "fit_corrections", "fit_report",
    "save_calibration", "load_calibration",
    "MacroSpec", "MACRO_LIBRARY", "get_macro",
    "AcceleratorConfig", "accelerator_area_mm2",
    "MatmulOp", "Workload", "bert_large_workload",
    "Strategy", "ALL_STRATEGIES", "SPATIAL_ONLY",
    "CostBreakdown", "matmul_cost", "strategy_table", "workload_cost",
    "workload_metrics",
    "compile_schedule", "compile_trace", "replay_trace", "schedule_totals",
    "strategy_feasible",
    "simulate_schedule", "analytic_latency_bounds",
    "DesignSpace", "prune_space",
    "SASettings", "simulated_annealing", "exhaustive_search",
    "co_explore", "co_explore_macros", "pareto_explore",
    "evaluate_config", "ExploreResult",
    "ExplorationEngine", "ExploreJob", "default_engine",
    "enable_persistent_compilation_cache", "job_key", "valid_methods",
    "distributed_co_explore", "DistributedResult",
]
