"""Multi-process HTTP front door of the async DSE service.

One ``repro-service serve`` process owns the batched exploration engine,
the micro-batching job queue and the persistent result store; any number of
client processes -- CI shards, benchmark sweeps, notebooks on other hosts --
submit over plain HTTP and share its warm executables and results.  Stdlib
only (``http.server.ThreadingHTTPServer``): no new dependencies.

Endpoints
---------

``POST /v1/jobs``
    Body: one JSON job spec or a list (the exact schema the CLI reads --
    see :func:`repro.service.client.job_from_spec`, including ``"search"``
    as a backend name or the structured per-job form ``{"method": ...,
    "settings": {...}, "allocator": "bandit"|"halving"}``, plus the
    legacy top-level ``"settings"``; a spec with ``"candidates": [[...],
    ...]`` runs the Pareto candidate-sweep path).  Specs are validated up front:
    any bad record fails the whole request with 400 before anything is
    admitted.  Returns one state record per spec (canonical ``key``,
    ``status``, and the inline result for store/dedup answers);
    ``?wait=SECONDS`` long-polls until done.
``GET /v1/jobs/<key>``
    Status/result of one submission (``?wait=SECONDS`` long-polls).
    Falls back to the persistent store for keys from previous runs.
``GET /v1/stream?keys=k1,k2,...``
    Server-sent events: one ``result`` event per key the moment its
    micro-batch bucket finishes -- completion order, mirroring
    :func:`repro.service.streams.as_completed` -- then one ``end`` event.
    Comment pings keep idle connections alive.
``GET /v1/pareto?macro=...&workloads=a,b&area_budget_mm2=...``
    Streams per-workload EE/Th Pareto frontiers as SSE events
    (server-side :func:`repro.service.streams.stream_pareto`).
``GET /v1/store/<key>``
    Raw serialized record from the server's result store -- the remote
    tier of :class:`repro.service.store.RemoteStoreTier` reads this; the
    server is the only writer of the shared store.
``GET /healthz`` / ``GET /v1/stats``
    Liveness; queue depth, dedup/store hit counters, engine executable
    -cache size, HTTP counters.

Graceful shutdown (``DSEServer.shutdown`` / SIGTERM in the CLI) stops
accepting connections, then drains in-flight micro-batch buckets through
``JobQueue.close`` so accepted work still lands in the store.
"""
from __future__ import annotations

import dataclasses
import json
import os
import queue as _queue
import threading
import time
import typing
import urllib.parse
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro import obs
from repro.core.engine import ExplorationEngine, ExploreResult
from repro.service.client import ServiceClient, job_from_spec
from repro.service.store import serialize_result
from repro.service.streams import ExploreFuture, stream_pareto

__all__ = ["ServerConfig", "DSEServer", "serve"]

_SPEC_ERRORS = (KeyError, TypeError, ValueError)

# telemetry families (process-wide; see docs/observability.md)
_REG = obs.registry()
_M_HTTP = _REG.counter(
    "cim_http_requests_total",
    "Requests served per (normalized) endpoint and method",
    ("endpoint", "method"))
_M_HTTP_S = _REG.histogram(
    "cim_http_request_seconds", "Request handling latency per endpoint",
    ("endpoint",))
_M_EVENTS = _REG.counter(
    "cim_http_events_total", "Front-door events by type", ("event",))

#: normalized route labels -- key-bearing paths collapse onto one child so
#: label cardinality stays bounded no matter how many job keys exist
_ROUTES = ("/healthz", "/v1/stats", "/v1/metrics", "/v1/trace",
           "/v1/jobs", "/v1/stream", "/v1/pareto", "/v1/calibration")


def _route(path: str) -> str:
    """Bounded endpoint label of a request path."""
    if path in _ROUTES:
        return path
    if path.startswith("/v1/jobs/"):
        if path.endswith("/timeline"):
            return "/v1/jobs/{key}/timeline"
        if path.endswith("/measurements"):
            return "/v1/jobs/{key}/measurements"
        return "/v1/jobs/{key}"
    if path.startswith("/v1/store/"):
        return "/v1/store/{key}"
    return "other"


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Front-door knobs (all orthogonal to the queue's own config)."""

    host: str = "127.0.0.1"
    #: 0 binds an ephemeral port (read it back from ``DSEServer.port``)
    port: int = 0
    #: reject request bodies larger than this (one giant candidate sweep
    #: is ~a few MB; 64 MB is far beyond any legitimate submission)
    max_body_bytes: int = 64 * 1024 * 1024
    #: completed futures kept addressable for /v1/jobs + /v1/stream;
    #: evicted explore results remain reachable through the store
    registry_cap: int = 4096
    #: SSE keep-alive comment interval
    stream_ping_s: float = 15.0
    #: cap on ?wait= long-polling
    max_wait_s: float = 600.0
    #: keep the ``repro.server`` logger at its env-configured level
    #: (``CIM_TUNER_LOG``); ``quiet=False`` forces it to DEBUG, which
    #: turns on per-request access lines (the old stderr logging)
    quiet: bool = True


class DSEServer:
    """The always-on multi-process front door over one ServiceClient."""

    def __init__(
        self,
        client: ServiceClient | None = None,
        engine: ExplorationEngine | None = None,
        store: typing.Any = "auto",
        config: ServerConfig = ServerConfig(),
    ):
        self.client = client or ServiceClient(engine=engine, store=store)
        if self.client.remote:
            raise ValueError("DSEServer needs an in-process ServiceClient")
        self.config = config
        # legacy-shaped per-instance counters mirrored into the
        # process-wide cim_http_events_total family; StatCounters locks
        # each bump, replacing the old dedicated _stats_lock
        self.http_stats = obs.StatCounters({
            key: _M_EVENTS.labels(event=key)
            for key in ("requests", "bad_requests", "errors",
                        "jobs_posted", "values_posted", "store_get_hits",
                        "store_get_misses", "streams")})
        self.log = obs.get_logger("server")
        if not config.quiet:
            # --verbose: per-request access lines regardless of env
            import logging
            self.log.setLevel(logging.DEBUG)
        self._registry: OrderedDict[str, ExploreFuture] = OrderedDict()
        self._reg_lock = threading.Lock()
        self._started_s = time.time()
        self._httpd = ThreadingHTTPServer(
            (config.host, config.port), _Handler)
        self._httpd.dse = self                         # type: ignore[attr-defined]
        self._thread: threading.Thread | None = None
        self._shut = False

    # ------------------------------------------------------------- #
    # lifecycle
    # ------------------------------------------------------------- #
    @property
    def host(self) -> str:
        return self._httpd.server_address[0]

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "DSEServer":
        """Serve in a daemon thread; returns self (context-manager style:
        ``with DSEServer(...).start() as srv: ...``).

        With ``CIM_TUNER_PROFILE`` set, a background warm-up runs the
        kernel micro-profile pass once so ``/v1/metrics`` serves real
        ``cim_kernel_*`` series (with exemplars into this process's
        ``/v1/trace``) from the first scrape."""
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            name="cim-tuner-dse-http", daemon=True)
        self._thread.start()
        if obs.profile.profiling_enabled():
            threading.Thread(target=self._profile_warmup,
                             name="cim-tuner-profile-warmup",
                             daemon=True).start()
        return self

    def _profile_warmup(self) -> None:
        try:
            rows = obs.profile.run_microbench()
            self.log.info("kernel profile warm-up: %d series", len(rows))
        except Exception as exc:           # noqa: BLE001 -- never fatal
            self.log.warning("kernel profile warm-up failed: %r", exc)

    def shutdown(self, drain: bool = True,
                 timeout: float | None = 30.0) -> None:
        """Stop accepting requests, then (by default) drain every accepted
        micro-batch bucket through the queue so in-flight submissions still
        resolve and persist before the process exits."""
        if self._shut:
            return
        self._shut = True
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout)
        if drain:
            self.client.close()

    def __enter__(self) -> "DSEServer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def bump(self, counter: str) -> None:
        """Locked counter increment -- handler threads are concurrent and
        ``/v1/stats`` readings gate CI assertions, so lost updates from
        racing read-modify-writes are not acceptable."""
        self.http_stats.bump(counter)

    # ------------------------------------------------------------- #
    # registry
    # ------------------------------------------------------------- #
    def register(self, fut: ExploreFuture) -> None:
        store = self.client.store
        with self._reg_lock:
            self._registry[fut.key] = fut
            self._registry.move_to_end(fut.key)
            while len(self._registry) > self.config.registry_cap:
                # eviction preference: completed entries whose result is
                # recoverable through the store, then any completed entry
                # (values sweeps / --no-store results become 404s), and
                # NEVER a pending future -- /v1/stream must not lose
                # running work, so the cap may temporarily overrun
                victim = next(
                    (k for k, f in self._registry.items()
                     if f.done() and store is not None and k in store),
                    None)
                if victim is None:
                    victim = next((k for k, f in self._registry.items()
                                   if f.done()), None)
                if victim is None:
                    break
                del self._registry[victim]

    def lookup(self, key: str) -> ExploreFuture | None:
        """Future for a key: live registry first, then the persistent
        store (as an already-completed future)."""
        with self._reg_lock:
            fut = self._registry.get(key)
        if fut is not None:
            return fut
        store = self.client.store
        if store is None:
            return None
        result = store.get(key)
        if result is None:
            return None
        return ExploreFuture.completed(None, "store", key, result,
                                       source="store")

    # ------------------------------------------------------------- #
    # state serialization
    # ------------------------------------------------------------- #
    @staticmethod
    def job_state(fut: ExploreFuture) -> dict:
        """JSON-able status/result record of one future."""
        rec: dict = {"key": fut.key, "method": fut.method}
        if not fut.done():
            rec["status"] = "pending"
            return rec
        exc = fut.exception(timeout=0)
        if exc is not None:
            rec.update(status="failed", error=str(exc),
                       error_type=type(exc).__name__,
                       job_key=getattr(exc, "job_key", None))
            return rec
        rec["status"] = "done"
        rec["source"] = fut.source
        result = fut._result
        if isinstance(result, ExploreResult):
            rec["result"] = serialize_result(result)
        else:
            rec["values"] = np.asarray(result).tolist()
        return rec

    def stats(self) -> dict:
        snap = self.client.stats_snapshot()
        with self._reg_lock:
            registry = len(self._registry)
        http = self.http_stats.snapshot()
        snap["server"] = {
            **http,
            "registry": registry,
            "uptime_s": round(time.time() - self._started_s, 3),
            "url": self.url,
        }
        return snap


def serve(
    host: str = "127.0.0.1",
    port: int = 0,
    store: typing.Any = "auto",
    engine: ExplorationEngine | None = None,
    config: ServerConfig | None = None,
) -> DSEServer:
    """Build and start a front door in one call; returns the running
    server (``.url`` carries the bound ephemeral port)."""
    cfg = config or ServerConfig(host=host, port=port)
    return DSEServer(engine=engine, store=store, config=cfg).start()


# ------------------------------------------------------------------ #
# the request handler
# ------------------------------------------------------------------ #
class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server_version = "cim-tuner-dse/1.0"

    # -- plumbing --------------------------------------------------- #
    @property
    def dse(self) -> DSEServer:
        return self.server.dse                         # type: ignore[attr-defined]

    def log_message(self, fmt: str, *args) -> None:    # noqa: A003
        # request lines go through the repro.server logger at DEBUG --
        # silent by default, enabled via CIM_TUNER_LOG=server or --verbose
        self.dse.log.debug("%s %s", self.address_string(), fmt % args)

    def _send_json(self, code: int, obj: dict) -> None:
        body = json.dumps(obj).encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, code: int, text: str, content_type: str) -> None:
        body = text.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _bad(self, message: str, code: int = 400) -> None:
        self.dse.bump("bad_requests")
        self._send_json(code, {"error": message})

    def _query(self) -> tuple[str, dict[str, str]]:
        parts = urllib.parse.urlsplit(self.path)
        q = {k: v[-1] for k, v in
             urllib.parse.parse_qs(parts.query).items()}
        return parts.path, q

    def _wait_s(self, q: dict[str, str]) -> float:
        try:
            wait = float(q.get("wait", "0"))
        except ValueError:
            wait = 0.0
        return max(0.0, min(wait, self.dse.config.max_wait_s))

    # -- SSE -------------------------------------------------------- #
    def _sse_begin(self) -> None:
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True

    def _sse_event(self, obj: dict, event: str | None = None) -> None:
        buf = b""
        if event:
            buf += f"event: {event}\n".encode()
        buf += b"data: " + json.dumps(obj).encode("utf-8") + b"\n\n"
        self.wfile.write(buf)
        self.wfile.flush()

    def _sse_ping(self) -> None:
        self.wfile.write(b": ping\n\n")
        self.wfile.flush()

    # -- routing ---------------------------------------------------- #
    def do_GET(self) -> None:                          # noqa: N802
        self.dse.bump("requests")
        path, q = self._query()
        route = _route(path)
        _M_HTTP.inc(endpoint=route, method="GET")
        try:
            with obs.span("server.request", histogram=_M_HTTP_S.labels(
                    endpoint=route), endpoint=route, method="GET"):
                if path == "/healthz":
                    self._send_json(200, {
                        "ok": True, "service": "cim-tuner-dse",
                        "pid": os.getpid(),
                        "uptime_s": round(
                            time.time() - self.dse._started_s, 3)})
                elif path == "/v1/stats":
                    self._send_json(200, self.dse.stats())
                elif path == "/v1/metrics":
                    self._send_text(
                        200, obs.registry().render(),
                        "text/plain; version=0.0.4; charset=utf-8")
                elif path == "/v1/trace":
                    self._send_json(
                        200, obs.chrome_trace(obs.tracer().events()))
                elif path == "/v1/calibration":
                    self._get_calibration()
                elif path.startswith("/v1/jobs/") and \
                        path.endswith("/timeline"):
                    key = path[len("/v1/jobs/"):-len("/timeline")]
                    self._get_timeline(key.rstrip("/"))
                elif path.startswith("/v1/jobs/") and \
                        path.endswith("/measurements"):
                    key = path[len("/v1/jobs/"):-len("/measurements")]
                    self._get_measurements(key.rstrip("/"))
                elif path.startswith("/v1/jobs/"):
                    self._get_job(path.rsplit("/", 1)[1], q)
                elif path == "/v1/stream":
                    self._get_stream(q)
                elif path == "/v1/pareto":
                    self._get_pareto(q)
                elif path.startswith("/v1/store/"):
                    self._get_store(path.rsplit("/", 1)[1])
                else:
                    self._bad(f"unknown path {path!r}", code=404)
        except (BrokenPipeError, ConnectionResetError):
            pass                                       # client went away
        except Exception as exc:                       # noqa: BLE001
            self.dse.bump("errors")
            self.dse.log.warning("GET %s failed: %r", path, exc)
            try:
                self._send_json(500, {"error": repr(exc)})
            except OSError:                            # pragma: no cover
                pass

    def do_POST(self) -> None:                         # noqa: N802
        self.dse.bump("requests")
        path, q = self._query()
        route = _route(path)
        _M_HTTP.inc(endpoint=route, method="POST")
        try:
            with obs.span("server.request", histogram=_M_HTTP_S.labels(
                    endpoint=route), endpoint=route, method="POST"):
                if path == "/v1/jobs":
                    self._post_jobs(q)
                else:
                    self._bad(f"unknown path {path!r}", code=404)
        except (BrokenPipeError, ConnectionResetError):
            pass
        except Exception as exc:                       # noqa: BLE001
            self.dse.bump("errors")
            self.dse.log.warning("POST %s failed: %r", path, exc)
            try:
                self._send_json(500, {"error": repr(exc)})
            except OSError:                            # pragma: no cover
                pass

    # -- endpoints -------------------------------------------------- #
    def _read_body(self) -> typing.Any:
        length = int(self.headers.get("Content-Length") or 0)
        if length <= 0:
            raise ValueError("missing request body")
        if length > self.dse.config.max_body_bytes:
            raise ValueError(
                f"body of {length} bytes exceeds the "
                f"{self.dse.config.max_body_bytes}-byte cap")
        return json.loads(self.rfile.read(length).decode("utf-8"))

    def _post_jobs(self, q: dict[str, str]) -> None:
        try:
            payload = self._read_body()
        except (ValueError, UnicodeDecodeError) as exc:
            self._bad(f"bad request body: {exc}")
            return
        specs = payload if isinstance(payload, list) else [payload]
        if not specs or not all(isinstance(s, dict) for s in specs):
            self._bad("body must be a job-spec object or a non-empty "
                      "list of them")
            return
        # validate every spec before admitting ANY of them -- a typo'd
        # backend name must not leave half a batch running.  Per-job
        # backend settings (structured "search" form or the top-level
        # "settings" dict) are parsed onto ExploreJob.search_settings by
        # job_from_spec, so the queue resolves and keys them per job.
        parsed = []
        for i, spec in enumerate(specs):
            try:
                job, method = job_from_spec(spec)
                cands = spec.get("candidates")
                if cands is not None:
                    cands = np.asarray(cands, dtype=np.float64)
                    if cands.ndim != 2 or cands.shape[1] != 6:
                        raise ValueError(
                            f"candidates must be [C, 6] rows, got shape "
                            f"{cands.shape}")
                parsed.append((job, method, cands,
                               int(spec.get("priority", 0))))
            except _SPEC_ERRORS as exc:
                self._bad(f"bad job spec #{i}: {exc}")
                return
        svc = self.dse.client
        futs: list[ExploreFuture] = []
        for job, method, cands, priority in parsed:
            if cands is not None:
                fut = svc.submit_values(job, cands, priority=priority)
                self.dse.bump("values_posted")
            else:
                fut = svc.submit(job, method, priority=priority)
                self.dse.bump("jobs_posted")
            self.dse.register(fut)
            futs.append(fut)
        wait = self._wait_s(q)
        if wait:
            deadline = time.monotonic() + wait
            for fut in futs:
                fut.wait(max(0.0, deadline - time.monotonic()))
        states = [self.dse.job_state(f) for f in futs]
        self._send_json(200, {
            "jobs": states,
            "pending": sum(s["status"] == "pending" for s in states)})

    def _get_job(self, key: str, q: dict[str, str]) -> None:
        fut = self.dse.lookup(key)
        if fut is None:
            self._bad(f"unknown job key {key!r}", code=404)
            return
        wait = self._wait_s(q)
        if wait:
            fut.wait(wait)
        self._send_json(200, self.dse.job_state(fut))

    def _get_timeline(self, key: str) -> None:
        """Flight-recorder timeline of one job: the in-process recorder
        first (live or recently finished races), then the store's
        persisted sidecar (results from previous runs / other hosts)."""
        timeline = obs.flight_recorder().timeline(key)
        source = "live"
        if timeline is None:
            store = self.dse.client.store
            get_timeline = getattr(store, "get_timeline", None)
            timeline = get_timeline(key) if callable(get_timeline) \
                else None
            source = "store"
        if timeline is None:
            self._bad(f"no timeline for job {key!r}", code=404)
            return
        self._send_json(200, {"key": key, "source": source,
                              "timeline": timeline})

    def _get_calibration(self) -> None:
        """The process's active kernel calibration: source (pinned
        artifact / live fit / none), version, correction factors and fit
        diagnostics (see docs/calibration.md)."""
        from repro.core.calibration import calibration_record
        self._send_json(200, calibration_record())

    def _get_measurements(self, key: str) -> None:
        """The measurement records behind one measured-fidelity result,
        from the store's ``.measurements.json`` sidecar."""
        store = self.dse.client.store
        get_meas = getattr(store, "get_measurements", None)
        records = get_meas(key) if callable(get_meas) else None
        if records is None:
            self._bad(f"no measurements for job {key!r}", code=404)
            return
        self._send_json(200, {"key": key, "measurements": records})

    def _get_store(self, key: str) -> None:
        store = self.dse.client.store
        payload = store.get_raw(key) if store is not None else None
        if payload is None:
            # a read-through miss is normal fleet behaviour, not a bad
            # request -- don't pollute that counter
            self.dse.bump("store_get_misses")
            self._send_json(404, {"error": f"no stored result for {key!r}"})
            return
        self.dse.bump("store_get_hits")
        self._send_json(200, {"key": key, "result": payload})

    def _get_stream(self, q: dict[str, str]) -> None:
        keys = [k for k in q.get("keys", "").split(",") if k]
        if not keys:
            self._bad("stream needs ?keys=k1,k2,...")
            return
        try:
            timeout = float(q.get("timeout", "0")) or None
        except ValueError:
            timeout = None
        futs: list[ExploreFuture] = []
        unknown: list[str] = []
        for key in dict.fromkeys(keys):                # dedup, keep order
            fut = self.dse.lookup(key)
            if fut is None:
                unknown.append(key)
            else:
                futs.append(fut)
        if unknown:
            self._bad(f"unknown job keys {unknown}", code=404)
            return
        self.dse.bump("streams")
        self._sse_begin()
        # one queue interleaves final results and per-rung progress
        # events (portfolio races publish on the progress bus); the
        # atomic subscribe returns history for rungs that fired before
        # this stream attached, so POST-then-stream clients still see
        # the whole race, each event exactly once
        done_q: _queue.SimpleQueue = _queue.SimpleQueue()
        bus = obs.progress_bus()

        def _on_progress(_key: str, ev: dict) -> None:
            done_q.put(("progress", ev))

        history = bus.subscribe([f.key for f in futs], _on_progress)
        for fut in futs:
            fut.add_done_callback(lambda f: done_q.put(("result", f)))
        try:
            for ev in history:
                self._sse_event(ev, event="progress")
            deadline = None if timeout is None \
                else time.monotonic() + timeout
            remaining = len(futs)
            while remaining:
                budget = self.dse.config.stream_ping_s
                if deadline is not None:
                    budget = min(budget, deadline - time.monotonic())
                    if budget <= 0:
                        self._sse_event({"remaining": remaining,
                                         "reason": "timeout"}, event="end")
                        return
                try:
                    kind, item = done_q.get(timeout=budget)
                except _queue.Empty:
                    self._sse_ping()
                    continue
                if kind == "progress":
                    self._sse_event(item, event="progress")
                    continue
                self._sse_event(self.dse.job_state(item), event="result")
                remaining -= 1
            self._sse_event({"remaining": 0}, event="end")
        finally:
            bus.unsubscribe(_on_progress)

    def _get_pareto(self, q: dict[str, str]) -> None:
        from repro.core.macro import get_macro
        from repro.service.client import _workload_from_spec
        try:
            macro = get_macro(q["macro"])
            budget = float(q["area_budget_mm2"])
            names = [w for w in q.get("workloads", "").split(",") if w]
            if not names:
                raise KeyError("workloads")
            seq = int(q.get("seq", "512"))
            workloads = [_workload_from_spec({"name": n, "seq": seq})
                         for n in names]
            bw = int(q.get("bw", "256"))
            strategy_set = q.get("strategy_set", "st")
        except _SPEC_ERRORS as exc:
            self._bad(f"bad pareto query: {exc}")
            return
        try:
            timeout = float(q.get("timeout", "0")) or None
        except ValueError:
            timeout = None
        self._sse_begin()
        count = 0
        try:
            for name, frontier in stream_pareto(
                    macro, workloads, budget, service=self.dse.client,
                    strategy_set=strategy_set, bw=bw, timeout=timeout):
                self._sse_event({
                    "workload": name,
                    "frontier": [{
                        "config": dataclasses.asdict(pt["config"]),
                        "gops": pt["gops"], "tops_w": pt["tops_w"],
                    } for pt in frontier],
                }, event="frontier")
                count += 1
        except Exception as exc:                       # noqa: BLE001
            self._sse_event({"error": repr(exc)}, event="error")
        self._sse_event({"remaining": len(workloads) - count}, event="end")
