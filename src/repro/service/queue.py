"""Thread-backed exploration job queue: continuous batching, dedup.

Submissions accumulate for a small window (or until a batch-size threshold),
dedup by canonical job key, and dispatch as ONE ``ExplorationEngine.run()``
per executable bucket -- so concurrent callers share compiled executables
exactly like a hand-built batch, while each caller's
:class:`~repro.service.streams.ExploreFuture` resolves the moment *its*
bucket finishes, not when the whole micro-batch drains.

Three admission tiers, checked in order at submit time:

1. **persistent store** (``store.py``) -- repeated queries across processes
   resolve immediately with zero engine work;
2. **in-flight dedup** -- an identical pending/running job fans its result
   out to every duplicate future;
3. **queue** -- new work enters the micro-batch window.

On top of the window, the queue runs a **continuous-batching scheduler**
(docs/scheduler.md): while a bandit-allocator portfolio group races, the
engine polls :meth:`JobQueue._admission_hook`'s callback at every rung
boundary, and pending submissions that match the in-flight ``(kind,
method, settings, bucket)`` signature join the running race instead of
waiting out the window behind it.  Admitted entries keep full queue
semantics -- they stay in the in-flight dedup map, their results persist
to the store, and their futures resolve exactly once -- and with no late
arrivals the dispatch is bit-identical to the plain window path
(``QueueConfig(continuous=False)``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import threading
import time
import typing

import numpy as np

from repro import obs
from repro.core.annealing import SASettings
from repro.core.engine import (
    ExplorationEngine,
    ExploreJob,
    ExploreResult,
    clone_result,
    default_engine,
    job_key,
    preferred_settings,
)
from repro.search.base import get_backend
from repro.service.store import ResultStore, default_store
from repro.service.streams import ExploreFuture

__all__ = ["QueueConfig", "JobQueue", "values_key", "resolve_settings"]

# telemetry families (process-wide; see docs/observability.md)
_REG = obs.registry()
_LOG = obs.get_logger("queue")
_M_SUBMITTED = _REG.counter(
    "cim_queue_submitted_total", "Jobs admitted to the service queue")
_M_STORE_HITS = _REG.counter(
    "cim_queue_store_hits_total",
    "Submissions resolved from the persistent result store")
_M_INFLIGHT_DEDUP = _REG.counter(
    "cim_queue_inflight_dedup_total",
    "Submissions folded onto an identical pending/running job")
_M_DISPATCHES = _REG.counter(
    "cim_queue_dispatches_total", "Engine calls issued (one per bucket)")
_M_COMPLETED = _REG.counter(
    "cim_queue_completed_total", "Queue entries resolved successfully")
_M_FAILED = _REG.counter(
    "cim_queue_failed_total", "Queue entries rejected with an error")
_M_WINDOW = _REG.counter(
    "cim_queue_window_flushes_total",
    "Micro-batch windows closed and dispatched")
_M_DEPTH = _REG.gauge(
    "cim_queue_depth", "Instantaneous queue depth", ("state",))
_M_WAIT_S = _REG.histogram(
    "cim_queue_wait_seconds",
    "Submit-to-dispatch latency per queue entry")
# continuous-batching scheduler families (docs/scheduler.md); the engine
# owns the budget-flow counters, the queue owns the admission ones
_M_SCHED_ADMISSIONS = _REG.counter(
    "cim_sched_admissions_total",
    "Late submissions admitted into an in-flight group at a rung boundary")
_M_SCHED_CHECKS = _REG.counter(
    "cim_sched_admission_checks_total",
    "Rung-boundary admission polls made by in-flight groups")
_M_SCHED_GROUPS = _REG.gauge(
    "cim_sched_inflight_groups",
    "Executable-bucket groups currently inside an engine call")
_M_SCHED_GROUP_JOBS = _REG.gauge(
    "cim_sched_inflight_group_jobs",
    "Jobs in the currently dispatched group, rung admissions included")
_M_SCHED_GROUPS.set(0)
_M_SCHED_GROUP_JOBS.set(0)


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    #: micro-batch accumulation window after the first pending submission
    batch_window_s: float = 0.02
    #: hard cap on jobs per dispatch (and per admission poll): a bigger
    #: backlog dispatches as successive bounded batches -- or, under the
    #: continuous scheduler, joins the in-flight race in ``max_batch``
    #: slices at successive rung boundaries
    max_batch_jobs: int = 64
    #: continuous batching: let pending submissions that match an
    #: in-flight bandit-portfolio group join its race at the next rung
    #: boundary instead of waiting for the group to finish.  ``False``
    #: restores the pure fixed-window scheduler (every dispatch is a
    #: closed world until it returns)
    continuous: bool = True


class _Entry:
    __slots__ = ("priority", "seq", "kind", "key", "job", "method",
                 "settings", "payload", "futures", "bucket", "t_submit")

    def __init__(self, priority, seq, kind, key, job, method, settings,
                 payload, future):
        self.priority = priority
        self.seq = seq
        self.kind = kind                  # "explore" | "values"
        self.key = key
        self.job = job
        self.method = method
        self.settings = settings
        self.payload = payload            # candidate rows for "values"
        self.futures = [future]
        self.bucket = None                # lazily cached executable bucket
        self.t_submit = time.perf_counter()  # queue-wait histogram anchor

    def order(self) -> tuple:
        return (-self.priority, self.seq)


def values_key(job: ExploreJob, rows: np.ndarray) -> str:
    """Canonical key of a candidate-sweep submission (job identity plus the
    exact candidate rows); shared by the local queue and the remote client
    so both sides address the same in-flight future."""
    base = job_key(job, "exhaustive", None)
    h = hashlib.sha256()
    h.update(base.encode())
    h.update(np.ascontiguousarray(rows, dtype=np.float64).tobytes())
    return "values-" + h.hexdigest()


_values_key = values_key                       # pre-PR-4 private spelling


def resolve_settings(method: str, settings=None, engine=None, job=None):
    """The effective backend settings a submission runs with -- mirrored
    by the remote client so client-side ``job_key`` computation matches
    what the server's queue will use.  Precedence is the shared
    :func:`repro.core.engine.preferred_settings` rule (explicit
    ``settings`` > a type-matching ``job.search_settings``), then the
    backend's defaults.  Raises on unknown backend names."""
    if method == "exhaustive":
        return None
    backend = get_backend(method)        # raises on unknown backends
    settings = preferred_settings(job, method, settings)
    if settings is not None:
        return settings
    if method == "sa":
        return engine.sa_settings if engine is not None else SASettings()
    return backend.default_settings()


#: accepted ``fidelity=`` spellings; "two" is the CLI/benchmark shorthand
#: for a two-fidelity race and normalizes to "measured"
_FIDELITY_ALIASES = {"two": "measured"}
_FIDELITY_VALUES = ("analytic", "measured")


def _normalize_submit_args(job: ExploreJob, method=None, settings=None,
                           sa_settings=None, fidelity=None, engine=None):
    """THE shared submit contract: every submit surface (``JobQueue``,
    ``ServiceClient``, ``RemoteQueue``) normalizes its keywords through
    this one helper, so ``(method, settings, priority, fidelity)`` mean
    exactly the same thing everywhere and the canonical ``job_key`` can
    never diverge between local and remote spellings.

    Returns ``(method, effective_settings, key)``.  ``sa_settings`` is
    the legacy SA spelling of ``settings``; ``fidelity`` (``"analytic"``,
    ``"measured"``, or the shorthand ``"two"``) overrides the settings'
    own ``fidelity`` field and requires a fidelity-capable backend
    (currently the portfolio racer)."""
    method = method or job.search_method
    if settings is None:
        settings = sa_settings
    settings = resolve_settings(method, settings, engine=engine, job=job)
    if fidelity is not None:
        fid = _FIDELITY_ALIASES.get(fidelity, fidelity)
        if fid not in _FIDELITY_VALUES:
            raise ValueError(
                f"unknown fidelity {fidelity!r}; valid: "
                f"{_FIDELITY_VALUES + tuple(_FIDELITY_ALIASES)}")
        if not hasattr(settings, "fidelity"):
            # every backend is implicitly analytic; only a non-analytic
            # request needs a fidelity-capable backend
            if fid != "analytic":
                raise ValueError(
                    f"method {method!r} does not support fidelity="
                    f"{fidelity!r}; two-fidelity runs need the portfolio "
                    f"backend")
        elif getattr(settings, "fidelity") != fid:
            settings = dataclasses.replace(settings, fidelity=fid)
    return method, settings, job_key(job, method, settings)


def _tag_job_exc(exc: BaseException, key: str) -> BaseException:
    """Per-future copy of a dispatch failure, carrying the originating
    ``job_key`` both in the message and as a ``.job_key`` attribute (one
    engine error fails a whole bucket; every caller must still be able to
    tell WHICH of its submissions died)."""
    note = f"[job {key[:16]}] "
    if str(exc).startswith(note):
        return exc
    try:
        tagged = type(exc)(f"{note}{exc}")
    except Exception:                    # noqa: BLE001 -- exotic signatures
        tagged = RuntimeError(f"{note}{exc!r}")
    tagged.job_key = key
    tagged.__cause__ = exc
    return tagged


class JobQueue:
    """The always-on exploration service core (one worker thread).

    ``engine=None`` uses the process-wide :func:`default_engine`;
    ``store=None`` disables the persistent result cache; the default
    (``"auto"``) resolves via :func:`repro.service.store.default_store`
    (honouring ``CIM_TUNER_RESULT_STORE`` / the disable env var).
    """

    def __init__(
        self,
        engine: ExplorationEngine | None = None,
        store: ResultStore | None | str = "auto",
        config: QueueConfig = QueueConfig(),
    ):
        self._engine = engine
        self.store = default_store() if store == "auto" else store
        self.config = config
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: list[_Entry] = []
        self._inflight: dict[str, _Entry] = {}
        self._thread: threading.Thread | None = None
        self._closed = False
        self._seq = 0
        # legacy-shaped per-instance counters mirrored into the
        # process-wide registry; StatCounters carries its own lock, so
        # bump() is safe from submitter threads AND the worker thread
        self.stats = obs.StatCounters({
            "submitted": _M_SUBMITTED.labels(),
            "store_hits": _M_STORE_HITS.labels(),
            "inflight_dedup": _M_INFLIGHT_DEDUP.labels(),
            "dispatches": _M_DISPATCHES.labels(),
            "completed": _M_COMPLETED.labels(),
            "failed": _M_FAILED.labels(),
        })
        # scheduler counters live in their own /v1/stats section so the
        # legacy "queue" shape stays exactly as pre-scheduler clients
        # (and the CI fleet smoke) expect it
        self.sched_stats = obs.StatCounters({
            "admitted": _M_SCHED_ADMISSIONS.labels(),
            "admission_checks": _M_SCHED_CHECKS.labels(),
        })
        self._running_group: list[_Entry] | None = None
        self._engine_admits: bool | None = None   # lazy capability probe

    # ------------------------------------------------------------- #
    # engine access (lazy so tests can build queues without JAX work)
    # ------------------------------------------------------------- #
    @property
    def engine(self) -> ExplorationEngine:
        if self._engine is None:
            self._engine = default_engine()
        return self._engine

    # ------------------------------------------------------------- #
    # submission API
    # ------------------------------------------------------------- #
    def submit(
        self,
        job: ExploreJob,
        method: str | None = None,
        sa_settings: SASettings | None = None,
        priority: int = 0,
        meta=None,
        settings=None,
        fidelity: str | None = None,
    ) -> ExploreFuture:
        """Admit one exploration job; returns immediately with a future.

        ``method`` is any registered ``repro.search`` backend name or
        ``"exhaustive"`` (``None`` uses ``job.search_method``);
        ``settings`` carries the backend's settings object
        (``sa_settings`` is the legacy SA spelling; ``None`` falls back
        to the job's own ``search_settings``, then backend defaults);
        ``fidelity`` ("analytic" | "measured" | shorthand "two")
        overrides the settings' fidelity for fidelity-capable backends
        (the portfolio racer)."""
        # resolve the effective settings WITHOUT instantiating the default
        # engine (store-only submissions skip engine construction and its
        # persistent-cache setup); a default-constructed engine uses
        # SASettings() too, so the canonical key matches either way
        method, settings, key = _normalize_submit_args(
            job, method, settings, sa_settings, fidelity,
            engine=self._engine)
        future = ExploreFuture(job, method, key, meta=meta)
        # submissions arrive from concurrent threads (the HTTP front
        # door); StatCounters locks each bump so increments never race
        self.stats.bump("submitted")

        if self.store is not None:
            cached = self.store.get(key)
            if cached is not None:
                self.stats.bump("store_hits")
                future._finish(cached, source="store")
                return future

        self._enqueue("explore", key, job, method, settings, None,
                      priority, future)
        return future

    def submit_many(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        sa_settings: SASettings | None = None,
        priority: int = 0,
        metas: typing.Sequence | None = None,
        settings=None,
        fidelity: str | None = None,
    ) -> list[ExploreFuture]:
        metas = metas if metas is not None else [None] * len(jobs)
        if len(metas) != len(jobs):
            raise ValueError(
                f"metas length {len(metas)} != jobs length {len(jobs)}")
        return [self.submit(j, method, sa_settings, priority, meta=m,
                            settings=settings, fidelity=fidelity)
                for j, m in zip(jobs, metas)]

    def submit_values(
        self,
        job: ExploreJob,
        candidates: np.ndarray,
        priority: int = 0,
        meta=None,
    ) -> ExploreFuture:
        """Admit an explicit candidate sweep (the Pareto path); the future
        resolves to the ``[C]`` objective-value array."""
        rows = np.asarray(candidates, dtype=np.float64)
        key = values_key(job, rows)
        future = ExploreFuture(job, "values", key, meta=meta)
        self.stats.bump("submitted")
        self._enqueue("values", key, job, "values", None, rows,
                      priority, future)
        return future

    def run_sync(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        sa_settings: SASettings | None = None,
        timeout: float | None = None,
        settings=None,
        fidelity: str | None = None,
    ) -> list[ExploreResult]:
        """Blocking batch call with service semantics (store, dedup) --
        what the ``co_explore`` family uses under the hood."""
        futures = self.submit_many(jobs, method, sa_settings,
                                   settings=settings, fidelity=fidelity)
        return [f.result(timeout) for f in futures]

    # ------------------------------------------------------------- #
    # introspection (the HTTP front door's /v1/stats)
    # ------------------------------------------------------------- #
    def depth(self) -> dict:
        """Instantaneous queue depth: submissions still waiting for a
        micro-batch plus keys currently being evaluated (also exported as
        the ``cim_queue_depth`` gauge)."""
        with self._lock:
            d = {"pending": len(self._pending),
                 "inflight": len(self._inflight)}
        _M_DEPTH.set(d["pending"], state="pending")
        _M_DEPTH.set(d["inflight"], state="inflight")
        return d

    def stats_snapshot(self) -> dict:
        """One JSON-able view of queue + scheduler + store + engine
        counters (engine stats appear only once an engine was actually
        instantiated).  The ``scheduler`` section carries the
        continuous-batching state: cumulative rung admissions and polls,
        plus the in-flight group depth (groups inside an engine call and
        the job count of the running group, admissions included)."""
        out: dict = {"queue": {**self.stats.snapshot(), **self.depth()}}
        with self._lock:
            running = self._running_group
            group_jobs = len(running) if running is not None else 0
        out["scheduler"] = {
            **self.sched_stats.snapshot(),
            "continuous": bool(self.config.continuous),
            "inflight_groups": 1 if running is not None else 0,
            "inflight_group_jobs": group_jobs,
        }
        out["store"] = dict(self.store.stats) \
            if self.store is not None else None
        eng = self._engine
        snap = getattr(eng, "stats_snapshot", None)
        out["engine"] = snap() if callable(snap) else None
        return out

    # ------------------------------------------------------------- #
    # lifecycle
    # ------------------------------------------------------------- #
    def close(self, timeout: float | None = None) -> None:
        """Reject new submissions, drain everything admitted, then stop
        the worker thread.

        Close is a DRAIN, not an abort: entries already queued when the
        flag flips are still dispatched (the worker loops until pending
        is empty, skipping the accumulation window once closed), and a
        race in flight keeps absorbing compatible pending entries at its
        rung boundaries -- so shutdown under active load resolves every
        accepted future instead of stranding whatever the window timer
        had not yet collected.  ``timeout=None`` (the default) waits for
        the full drain; pass a number to give up waiting after that many
        seconds (the daemon worker keeps draining in the background)."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            thread = self._thread
        if thread is not None:
            thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- #
    # internals
    # ------------------------------------------------------------- #
    def _enqueue(self, kind, key, job, method, settings, payload,
                 priority, future) -> None:
        with self._cv:
            if self._closed:
                raise RuntimeError("service queue is closed")
            entry = self._inflight.get(key)
            if entry is not None:
                entry.futures.append(future)
                self.stats.bump("inflight_dedup")
                return
            self._seq += 1
            entry = _Entry(priority, self._seq, kind, key, job, method,
                           settings, payload, future)
            self._pending.append(entry)
            self._inflight[key] = entry
            _M_DEPTH.set(len(self._pending), state="pending")
            _M_DEPTH.set(len(self._inflight), state="inflight")
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._worker, name="cim-tuner-dse-queue",
                    daemon=True)
                self._thread.start()
            self._cv.notify_all()

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
                # micro-batch window: let near-simultaneous submissions
                # (NAS-style callers, sweep loops) coalesce into one batch
                deadline = time.monotonic() + self.config.batch_window_s
                while len(self._pending) < self.config.max_batch_jobs:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or self._closed:
                        break
                    self._cv.wait(remaining)
                # max_batch_jobs is a hard cap per dispatch: the overflow
                # stays pending, where the continuous scheduler admits it
                # into the dispatched race at rung boundaries and the
                # window scheduler picks it up as the next bounded batch
                cap = max(1, self.config.max_batch_jobs)
                ordered = sorted(self._pending, key=_Entry.order)
                batch, self._pending = ordered[:cap], ordered[cap:]
                _M_DEPTH.set(len(self._pending), state="pending")
            _M_WINDOW.inc()
            try:
                with obs.span("queue.batch", jobs=len(batch)):
                    self._dispatch(batch)
            except Exception as exc:    # noqa: BLE001 -- worker must survive
                # reject whatever the dispatch didn't resolve (resolved
                # futures ignore the second _finish) and keep serving
                self._resolve_group(batch, None, exc)

    def _groups(self, batch: list[_Entry]) -> list[list[_Entry]]:
        """Group a micro-batch by executable signature; one engine call
        per group, dispatched in (priority, arrival) order.  Entries whose
        jobs can't even be bucketed (malformed space/workload) are
        rejected individually so one bad spec can't poison the batch."""
        groups: dict[tuple, list[_Entry]] = {}
        for e in batch:
            try:
                if e.bucket is None:
                    method = "exhaustive" if e.kind == "values" else e.method
                    e.bucket = (e.kind, e.method, e.settings,
                                self.engine.bucket_key(e.job, method))
            except Exception as exc:     # noqa: BLE001 -- reject this entry
                self._resolve_group([e], None, exc)
                continue
            groups.setdefault(e.bucket, []).append(e)
        return list(groups.values())

    def _admission_hook(self, group: list[_Entry]):
        """The continuous-batching admission callback for one in-flight
        group, or ``None`` when the group has no rung boundaries to admit
        at (admission needs a bandit-allocator portfolio race; halving
        culls across rungs and every other method is single-shot).

        The engine polls the callback between bandit waves ON the worker
        thread.  Under the queue lock it sweeps ``_pending`` for entries
        matching the group's exact ``(kind, method, settings, bucket)``
        signature and moves them into the group -- they never leave the
        in-flight dedup map, so duplicate submissions keep folding onto
        them, and ``_resolve_group`` later persists + resolves them
        exactly like window-dispatched entries (the engine appends their
        results in admission order).  Entries that fail bucketing stay
        pending for the window path to reject individually."""
        if not self.config.continuous:
            return None
        head = group[0]
        if head.kind != "explore" or head.method != "portfolio" or \
                getattr(head.settings, "allocator", None) != "bandit":
            return None
        if self._engine_admits is None:
            # stub/legacy engines without an ``admit=`` parameter keep
            # the plain window path instead of failing the dispatch
            try:
                params = inspect.signature(
                    self.engine.run).parameters.values()
                self._engine_admits = any(
                    p.name == "admit" or p.kind == p.VAR_KEYWORD
                    for p in params)
            except (TypeError, ValueError):
                self._engine_admits = False
        if not self._engine_admits:
            return None
        sig = head.bucket

        def admit() -> list[tuple[ExploreJob, str]]:
            self.sched_stats.bump("admission_checks")
            taken: list[_Entry] = []
            cap = max(1, self.config.max_batch_jobs)
            with self._cv:
                if self._pending:
                    rest = []
                    for e in self._pending:
                        (taken if len(taken) < cap
                         and self._admissible(e, sig)
                         else rest).append(e)
                    if taken:
                        self._pending = rest
                        _M_DEPTH.set(len(self._pending), state="pending")
            if not taken:
                return []
            now = time.perf_counter()
            for e in taken:
                group.append(e)
                _M_WAIT_S.observe(now - e.t_submit)
            self.sched_stats.bump("admitted", len(taken))
            _M_SCHED_GROUP_JOBS.set(len(group))
            _LOG.debug("admitted %d job(s) into in-flight group %s",
                       len(taken), sig)
            return [(e.job, e.key) for e in taken]

        return admit

    def _admissible(self, e: _Entry, sig: tuple) -> bool:
        """Does pending entry ``e`` match an in-flight group signature?
        Settings compare by dataclass equality; the executable bucket is
        computed lazily (and cached on the entry) exactly as the window
        path's ``_groups`` would."""
        if e.kind != "explore" or e.method != sig[1] or \
                e.settings != sig[2]:
            return False
        try:
            if e.bucket is None:
                e.bucket = (e.kind, e.method, e.settings,
                            self.engine.bucket_key(e.job, e.method))
        except Exception:        # noqa: BLE001 -- window path rejects it
            return False
        return e.bucket == sig

    def _dispatch(self, batch: list[_Entry]) -> None:
        for group in self._groups(batch):
            self.stats.bump("dispatches")
            now = time.perf_counter()
            for e in group:
                _M_WAIT_S.observe(now - e.t_submit)
            _LOG.debug("dispatch %d job(s) kind=%s method=%s wait=%.3fs",
                       len(group), group[0].kind, group[0].method,
                       now - min(e.t_submit for e in group))
            with self._lock:
                self._running_group = group
            _M_SCHED_GROUPS.set(1)
            _M_SCHED_GROUP_JOBS.set(len(group))
            try:
                if group[0].kind == "values":
                    outs = self.engine.candidate_values(
                        [e.job for e in group], [e.payload for e in group])
                else:
                    # pass the canonical keys computed at submit time so
                    # the engine's dedup pass skips re-hashing; the
                    # admission hook (None for non-admittable groups)
                    # lets compatible late arrivals join mid-race, and
                    # the engine returns their results appended behind
                    # the dispatched entries' -- group grows in lockstep
                    admit = self._admission_hook(group)
                    kwargs = {} if admit is None else {"admit": admit}
                    outs = self.engine.run(
                        [e.job for e in group], method=group[0].method,
                        settings=group[0].settings,
                        keys=[e.key for e in group], **kwargs)
            except Exception as exc:              # noqa: BLE001 -- reject group
                self._resolve_group(group, None, exc)
                continue
            finally:
                with self._lock:
                    self._running_group = None
                _M_SCHED_GROUPS.set(0)
                _M_SCHED_GROUP_JOBS.set(0)
            self._resolve_group(group, outs, None)

    def _resolve_group(self, group, outs, exc) -> None:
        for i, e in enumerate(group):
            out = outs[i] if exc is None else None
            if exc is None and e.kind == "explore" and \
                    self.store is not None:
                # persist BEFORE leaving the in-flight map: an identical
                # submission always sees either the running entry or the
                # stored result, never a gap
                self.store.put(e.key, out)
                # the decision timeline (portfolio runs) lands next to
                # the result, so warm-store hits after a restart still
                # serve GET /v1/jobs/<key>/timeline
                put_timeline = getattr(self.store, "put_timeline", None)
                if callable(put_timeline):
                    timeline = obs.flight_recorder().timeline(e.key)
                    if timeline is not None:
                        put_timeline(e.key, timeline)
                # measured-fidelity runs park their kernel measurement
                # records under the job key; they become the result's
                # .measurements.json sidecar (same lifecycle)
                put_meas = getattr(self.store, "put_measurements", None)
                if callable(put_meas):
                    records = obs.profile.take_measurements(e.key)
                    if records is not None:
                        put_meas(e.key, records)
            with self._lock:
                self._inflight.pop(e.key, None)
                futures = list(e.futures)
                _M_DEPTH.set(len(self._inflight), state="inflight")
            if exc is not None:
                self.stats.bump("failed")
                # surface the failure into every affected future, tagged
                # with ITS canonical key -- a bucket-wide engine error must
                # stay attributable per submission, not merely logged
                err = _tag_job_exc(exc, e.key)
                for f in futures:
                    f._finish(exc=err, source="engine")
                continue
            self.stats.bump("completed")
            for j, f in enumerate(futures):
                r = out
                if j > 0 and isinstance(out, ExploreResult):
                    r = clone_result(out)
                f._finish(r, source="engine" if j == 0 else "inflight")
