"""Persistent on-disk result store, content-addressed by canonical job key.

Repeated queries across processes -- CI runs, benchmark re-runs, notebook
users -- hit this cache instead of re-annealing.  Layout: one JSONL record
per result at ``<root>/<key[:2]>/<key>.jsonl``, written to a temp file and
moved into place with ``os.replace`` so concurrent writers (parallel CI
shards, several notebooks) can never expose a torn record.

The key already folds in everything that determines the answer bit-for-bit
(job ingredients, search method, backend settings, x64 mode, and a schema
version -- see :func:`repro.core.engine.job_key`), so ``get`` is a pure
content lookup.  Corrupt or schema-mismatched records read as misses.

Hygiene: records older than ``CIM_TUNER_RESULT_STORE_TTL`` seconds expire
on read, and every ``put`` enforces ``CIM_TUNER_RESULT_STORE_MAX_MB`` by
evicting the least-recently-*used* records first (``get`` touches a hit's
mtime, so hot entries survive).  Both limits default to off.  Expired or
evicted entries simply read as misses -- the caller falls back to the
engine and the record is re-written.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from repro import obs
from repro.core.engine import ExploreResult
from repro.core.macro import MacroSpec
from repro.core.template import AcceleratorConfig

__all__ = ["ResultStore", "RemoteStoreTier", "default_store",
           "serialize_result", "deserialize_result", "STORE_SCHEMA"]

#: one family covers both tiers: ``tier="local"`` is the on-disk store,
#: ``tier="remote"`` the read-through client tier (docs/observability.md)
_M_OPS = obs.registry().counter(
    "cim_store_ops_total", "Result-store operations by tier and outcome",
    ("tier", "op"))

#: bump together with ``engine.JOB_KEY_SCHEMA`` when the serialized result
#: layout changes shape
STORE_SCHEMA = 1


def _to_py(v):
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: _to_py(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_py(x) for x in v]
    return v


def serialize_result(r: ExploreResult) -> dict:
    """JSON-able record of an ExploreResult.  The SA trace arrays are
    deliberately dropped (they are diagnostics, not the answer); rehydrated
    results carry ``sa=None``."""
    return {
        "config": dataclasses.asdict(r.config),
        "macro": dataclasses.asdict(r.macro),
        "workload": r.workload,
        "objective": r.objective,
        "strategy_set": r.strategy_set,
        "per_op_strategy": dict(r.per_op_strategy),
        "metrics": _to_py(r.metrics),
        "search": _to_py(r.search),
    }


def deserialize_result(rec: dict) -> ExploreResult:
    """Rehydrate a :func:`serialize_result` record (``sa`` diagnostics
    were dropped at serialization time, so they come back ``None``)."""
    return ExploreResult(
        config=AcceleratorConfig(**rec["config"]),
        macro=MacroSpec(**rec["macro"]),
        workload=rec["workload"],
        objective=rec["objective"],
        strategy_set=rec["strategy_set"],
        per_op_strategy=dict(rec["per_op_strategy"]),
        metrics=dict(rec["metrics"]),
        search=dict(rec["search"]),
        sa=None,
    )


def _limit_from_env(var: str) -> float | None:
    raw = os.environ.get(var)
    if not raw:
        return None
    try:
        val = float(raw)
    except ValueError:
        return None
    return val if val > 0 else None


class ResultStore:
    """Content-addressed persistent cache of ExploreResults.

    ``ttl_s`` / ``max_mb`` default to the ``CIM_TUNER_RESULT_STORE_TTL``
    (seconds) and ``CIM_TUNER_RESULT_STORE_MAX_MB`` environment variables;
    pass explicit numbers to override, or ``None``-producing env state to
    run uncapped.
    """

    _ENV = object()                    # sentinel: read limits from env

    def __init__(self, root: str | None = None, ttl_s=_ENV, max_mb=_ENV):
        """Open (lazily -- no I/O here) the store rooted at ``root``
        (default: ``CIM_TUNER_RESULT_STORE``, else
        ``~/.cache/cim-tuner/result-store``); see the class docstring for
        the ``ttl_s`` / ``max_mb`` hygiene knobs."""
        self.root = root or os.environ.get("CIM_TUNER_RESULT_STORE") or \
            os.path.join(os.path.expanduser("~"), ".cache", "cim-tuner",
                         "result-store")
        self.ttl_s = _limit_from_env("CIM_TUNER_RESULT_STORE_TTL") \
            if ttl_s is self._ENV else ttl_s
        max_mb = _limit_from_env("CIM_TUNER_RESULT_STORE_MAX_MB") \
            if max_mb is self._ENV else max_mb
        self.max_bytes = None if max_mb is None else max_mb * 1e6
        #: running (over-)estimate of the store's byte total; a full
        #: directory walk only happens when this crosses the cap, so puts
        #: stay O(1) until eviction is actually needed
        self._approx_bytes: float | None = None
        # handler threads of the HTTP front door and the queue worker hit
        # one store concurrently; StatCounters locks each bump and
        # mirrors it into the process-wide cim_store_ops_total family
        self.stats = obs.StatCounters({
            "hits": _M_OPS.labels(tier="local", op="hit"),
            "misses": _M_OPS.labels(tier="local", op="miss"),
            "puts": _M_OPS.labels(tier="local", op="put"),
            "expired": _M_OPS.labels(tier="local", op="expired"),
            "evicted": _M_OPS.labels(tier="local", op="evicted"),
        })

    def _bump(self, counter: str, n: int = 1) -> None:
        self.stats.bump(counter, n)

    # ------------------------------------------------------------- #
    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], f"{key}.jsonl")

    def _timeline_path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], f"{key}.timeline.json")

    def _measurements_path(self, key: str) -> str:
        return os.path.join(self.root, key[:2],
                            f"{key}.measurements.json")

    def _sidecar_paths(self, key: str) -> tuple[str, ...]:
        """Every sidecar that shares its parent record's lifecycle --
        evicted/expired with it, recency-refreshed on its hits."""
        return (self._timeline_path(key), self._measurements_path(key))

    def _write_sidecar(self, path: str, payload, op: str) -> None:
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path), suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)                      # atomic publish
        except (OSError, TypeError, ValueError):       # pragma: no cover
            return
        _M_OPS.inc(tier="local", op=op)

    def put_timeline(self, key: str, timeline: dict) -> None:
        """Persist one flight-recorder timeline next to its result
        (atomic publish; write failures degrade to a no-op, exactly like
        :meth:`put`) -- warm-store hits after a server restart still
        serve ``GET /v1/jobs/<key>/timeline`` from this sidecar."""
        self._write_sidecar(self._timeline_path(key), timeline,
                            "timeline_put")

    def put_measurements(self, key: str, records: list) -> None:
        """Persist the kernel measurement records backing one measured-
        fidelity result next to it (same lifecycle as the timeline
        sidecar: atomic publish, evicted/expired with the parent) -- so
        a two-fidelity race replays bit-for-bit from the store and
        ``GET /v1/jobs/<key>/measurements`` survives server restarts."""
        self._write_sidecar(self._measurements_path(key), list(records),
                            "measurements_put")

    def get_measurements(self, key: str) -> list | None:
        """The persisted measurement records for a canonical job key
        (``None`` on any kind of miss -- absent, corrupt, non-list)."""
        try:
            with open(self._measurements_path(key)) as f:
                records = json.load(f)
            if not isinstance(records, list):
                raise ValueError("malformed measurements")
        except (OSError, ValueError):
            _M_OPS.inc(tier="local", op="measurements_miss")
            return None
        _M_OPS.inc(tier="local", op="measurements_hit")
        return records

    def get_timeline(self, key: str) -> dict | None:
        """The persisted timeline for a canonical job key (``None`` on
        any kind of miss -- absent, corrupt, non-dict)."""
        try:
            with open(self._timeline_path(key)) as f:
                timeline = json.load(f)
            if not isinstance(timeline, dict):
                raise ValueError("malformed timeline")
        except (OSError, ValueError):
            _M_OPS.inc(tier="local", op="timeline_miss")
            return None
        _M_OPS.inc(tier="local", op="timeline_hit")
        return timeline

    def get_raw(self, key: str, count: bool = True) -> dict | None:
        """The serialized-result payload of a live record (TTL and schema
        enforced exactly like :meth:`get`); what the HTTP front door's
        ``GET /v1/store/<key>`` ships to remote readers.  ``count=False``
        suppresses the hit/miss accounting (for callers like :meth:`get`
        that do their own, once deserialization is known to succeed --
        mirrored counters are monotonic, so outcomes must be counted
        exactly once, after they are final)."""
        path = self._path(key)
        try:
            with open(path) as f:
                rec = json.loads(f.readline())
            if rec.get("schema") != STORE_SCHEMA:
                raise ValueError("schema mismatch")
            if self.ttl_s is not None and \
                    time.time() - rec.get("created_s", 0.0) > self.ttl_s:
                self._bump("expired")
                for p in (path, *self._sidecar_paths(key)):
                    try:
                        os.remove(p)
                    except OSError:
                        pass
                raise ValueError("expired")
            payload = rec["result"]
            if not isinstance(payload, dict):
                raise ValueError("malformed record")
        except (OSError, ValueError, KeyError, TypeError):
            if count:
                self._bump("misses")
            return None
        if count:
            self._bump("hits")
        try:
            os.utime(path)             # LRU-ish: hits refresh the mtime
        except OSError:                                # pragma: no cover
            pass
        for p in self._sidecar_paths(key):
            try:                       # sidecars share the hit's recency
                os.utime(p)
            except OSError:
                pass
        return payload

    def get(self, key: str) -> ExploreResult | None:
        """The stored result for a canonical job key, or ``None`` on any
        kind of miss (absent, expired, corrupt, schema-mismatched); hits
        are tagged ``search["cache"] = "store"`` and refresh recency."""
        with obs.span("store.get", tier="local"):
            payload = self.get_raw(key, count=False)
            if payload is None:
                self._bump("misses")
                return None
            try:
                out = deserialize_result(payload)
            except (ValueError, KeyError, TypeError):
                self._bump("misses")
                return None
            self._bump("hits")
        out.search["cache"] = "store"
        return out

    def put(self, key: str, result: ExploreResult) -> None:
        """Persist one result under its canonical key (atomic publish via
        ``os.replace``; write failures degrade to a no-op so read-only
        filesystems never break exploration), then enforce the size cap.
        """
        rec = {"schema": STORE_SCHEMA, "key": key,
               "created_s": time.time(),
               "result": serialize_result(result)}
        path = self._path(key)
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path), suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(rec) + "\n")
            os.replace(tmp, path)                      # atomic publish
        except OSError:                                # pragma: no cover
            return                                     # read-only FS etc.
        self._bump("puts")
        if self.max_bytes is not None:
            if self._approx_bytes is not None:
                # overwrites double-count the record; the estimate only
                # ever errs high, forcing at worst an early rescan
                try:
                    self._approx_bytes += os.path.getsize(path)
                except OSError:                        # pragma: no cover
                    self._approx_bytes = None
            if self._approx_bytes is None or \
                    self._approx_bytes > self.max_bytes:
                self._enforce_cap(keep=key)

    def _enforce_cap(self, keep: str | None = None) -> None:
        """Evict least-recently-used records until under ``max_bytes``
        (the just-written ``keep`` key is never evicted).  Re-establishes
        the exact byte total as a side effect."""
        entries = []                    # (mtime, size, key, path)
        total = 0
        for k in self.keys():
            p = self._path(k)
            try:
                st = os.stat(p)
            except OSError:                            # pragma: no cover
                continue
            total += st.st_size
            entries.append((st.st_mtime, st.st_size, k, p))
        for mtime, size, k, p in sorted(entries):
            if total <= self.max_bytes:
                break
            if k == keep:
                continue
            try:
                os.remove(p)
            except OSError:                            # pragma: no cover
                continue
            for sp in self._sidecar_paths(k):
                try:                   # every sidecar goes with it
                    os.remove(sp)
                except OSError:
                    pass
            self._bump("evicted")
            total -= size
        self._approx_bytes = total

    def __contains__(self, key: str) -> bool:
        """get-parity membership: a record ``get`` would reject (expired,
        schema-mismatched, unparseable) is absent."""
        try:
            with open(self._path(key)) as f:
                rec = json.loads(f.readline())
        except (OSError, ValueError):
            return False
        if rec.get("schema") != STORE_SCHEMA:
            return False
        return self.ttl_s is None or \
            time.time() - rec.get("created_s", 0.0) <= self.ttl_s

    def keys(self) -> list[str]:
        """Every record key currently on disk, sorted within shards."""
        out = []
        if not os.path.isdir(self.root):
            return out
        for shard in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, shard)
            if os.path.isdir(d):
                out.extend(sorted(
                    f[:-len(".jsonl")] for f in os.listdir(d)
                    if f.endswith(".jsonl")))
        return out

    def clear(self) -> int:
        """Remove every record; returns how many were deleted."""
        n = 0
        for key in self.keys():
            try:
                os.remove(self._path(key))
                n += 1
            except OSError:                            # pragma: no cover
                pass
            for sp in self._sidecar_paths(key):
                try:
                    os.remove(sp)
                except OSError:
                    pass
        self._approx_bytes = None
        return n


class RemoteStoreTier:
    """Read-through tiering over a ``repro-service serve`` instance.

    ``get`` falls through **local store -> remote GET /v1/store/<key>**;
    remote hits are written back into the local tier so the next identical
    query on this host never leaves the machine.  ``put`` writes the local
    tier only -- the *server* is the sole writer of the shared store (every
    engine result it computes lands there via its own queue), so client
    fleets cannot race each other's writes across hosts.  Remote errors
    (server down, timeouts) degrade to misses: the caller simply submits.
    """

    def __init__(self, base_url: str,
                 local: "ResultStore | None" = None,
                 timeout_s: float = 10.0):
        """Tier over the server at ``base_url`` with an optional
        ``local`` write-back store; ``timeout_s`` bounds each remote GET.
        """
        self.base_url = base_url.rstrip("/")
        self.local = local
        self.timeout_s = float(timeout_s)
        self.stats = obs.StatCounters({
            "local_hits": _M_OPS.labels(tier="remote", op="local_hit"),
            "remote_hits": _M_OPS.labels(tier="remote", op="remote_hit"),
            "misses": _M_OPS.labels(tier="remote", op="miss"),
            "puts": _M_OPS.labels(tier="remote", op="put"),
            "remote_errors": _M_OPS.labels(tier="remote",
                                           op="remote_error"),
        })

    def _bump(self, counter: str) -> None:
        self.stats.bump(counter)

    def get(self, key: str) -> ExploreResult | None:
        """Read-through lookup: local tier, then ``GET /v1/store/<key>``
        (remote hits are written back locally; remote errors read as
        misses so a down server degrades to plain submission)."""
        with obs.span("store.get", tier="remote"):
            if self.local is not None:
                out = self.local.get(key)
                if out is not None:
                    self._bump("local_hits")
                    return out
            payload = self._remote_get(key)
            if payload is None:
                self._bump("misses")
                return None
            try:
                out = deserialize_result(payload)
            except (ValueError, KeyError, TypeError):
                self._bump("misses")
                return None
            self._bump("remote_hits")
        out.search["cache"] = "remote-store"
        if self.local is not None:
            self.local.put(key, out)       # read-through: warm the local tier
        return out

    def put(self, key: str, result: ExploreResult) -> None:
        """Write the LOCAL tier only -- the server is the shared store's
        sole writer (its own queue persists every engine result)."""
        if self.local is not None:
            self.local.put(key, result)
        self._bump("puts")

    def put_measurements(self, key: str, records: list) -> None:
        """Measurement sidecars follow :meth:`put`'s local-only rule."""
        if self.local is not None:
            self.local.put_measurements(key, records)

    def get_measurements(self, key: str) -> list | None:
        """Local tier only (no remote fall-through for sidecars)."""
        if self.local is not None:
            return self.local.get_measurements(key)
        return None

    def _remote_get(self, key: str) -> dict | None:
        import urllib.error
        import urllib.request
        url = f"{self.base_url}/v1/store/{key}"
        try:
            with urllib.request.urlopen(url, timeout=self.timeout_s) as resp:
                rec = json.loads(resp.read().decode("utf-8"))
        except urllib.error.HTTPError as exc:
            if exc.code != 404:                        # pragma: no cover
                self._bump("remote_errors")
            return None
        except (OSError, ValueError):
            self._bump("remote_errors")
            return None
        payload = rec.get("result") if isinstance(rec, dict) else None
        return payload if isinstance(payload, dict) else None


def default_store() -> ResultStore | None:
    """The store the process-wide service uses; ``None`` (cache off) when
    ``CIM_TUNER_DISABLE_RESULT_STORE`` is set."""
    if os.environ.get("CIM_TUNER_DISABLE_RESULT_STORE"):
        return None
    return ResultStore()
