"""Persistent on-disk result store, content-addressed by canonical job key.

Repeated queries across processes -- CI runs, benchmark re-runs, notebook
users -- hit this cache instead of re-annealing.  Layout: one JSONL record
per result at ``<root>/<key[:2]>/<key>.jsonl``, written to a temp file and
moved into place with ``os.replace`` so concurrent writers (parallel CI
shards, several notebooks) can never expose a torn record.

The key already folds in everything that determines the answer bit-for-bit
(job ingredients, method, SA settings, x64 mode, and a schema version --
see :func:`repro.core.engine.job_key`), so ``get`` is a pure content
lookup.  Corrupt or schema-mismatched records read as misses.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time

import numpy as np

from repro.core.engine import ExploreResult
from repro.core.macro import MacroSpec
from repro.core.template import AcceleratorConfig

__all__ = ["ResultStore", "default_store", "serialize_result",
           "deserialize_result", "STORE_SCHEMA"]

#: bump together with ``engine.JOB_KEY_SCHEMA`` when the serialized result
#: layout changes shape
STORE_SCHEMA = 1


def _to_py(v):
    if isinstance(v, (np.bool_,)):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, dict):
        return {k: _to_py(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_py(x) for x in v]
    return v


def serialize_result(r: ExploreResult) -> dict:
    """JSON-able record of an ExploreResult.  The SA trace arrays are
    deliberately dropped (they are diagnostics, not the answer); rehydrated
    results carry ``sa=None``."""
    return {
        "config": dataclasses.asdict(r.config),
        "macro": dataclasses.asdict(r.macro),
        "workload": r.workload,
        "objective": r.objective,
        "strategy_set": r.strategy_set,
        "per_op_strategy": dict(r.per_op_strategy),
        "metrics": _to_py(r.metrics),
        "search": _to_py(r.search),
    }


def deserialize_result(rec: dict) -> ExploreResult:
    return ExploreResult(
        config=AcceleratorConfig(**rec["config"]),
        macro=MacroSpec(**rec["macro"]),
        workload=rec["workload"],
        objective=rec["objective"],
        strategy_set=rec["strategy_set"],
        per_op_strategy=dict(rec["per_op_strategy"]),
        metrics=dict(rec["metrics"]),
        search=dict(rec["search"]),
        sa=None,
    )


class ResultStore:
    """Content-addressed persistent cache of ExploreResults."""

    def __init__(self, root: str | None = None):
        self.root = root or os.environ.get("CIM_TUNER_RESULT_STORE") or \
            os.path.join(os.path.expanduser("~"), ".cache", "cim-tuner",
                         "result-store")
        self.stats = {"hits": 0, "misses": 0, "puts": 0}

    # ------------------------------------------------------------- #
    def _path(self, key: str) -> str:
        return os.path.join(self.root, key[:2], f"{key}.jsonl")

    def get(self, key: str) -> ExploreResult | None:
        try:
            with open(self._path(key)) as f:
                rec = json.loads(f.readline())
            if rec.get("schema") != STORE_SCHEMA:
                raise ValueError("schema mismatch")
            out = deserialize_result(rec["result"])
        except (OSError, ValueError, KeyError, TypeError):
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        out.search["cache"] = "store"
        return out

    def put(self, key: str, result: ExploreResult) -> None:
        rec = {"schema": STORE_SCHEMA, "key": key,
               "created_s": time.time(),
               "result": serialize_result(result)}
        path = self._path(key)
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(path), suffix=".tmp")
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(rec) + "\n")
            os.replace(tmp, path)                      # atomic publish
        except OSError:                                # pragma: no cover
            return                                     # read-only FS etc.
        self.stats["puts"] += 1

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self) -> list[str]:
        out = []
        if not os.path.isdir(self.root):
            return out
        for shard in sorted(os.listdir(self.root)):
            d = os.path.join(self.root, shard)
            if os.path.isdir(d):
                out.extend(sorted(
                    f[:-len(".jsonl")] for f in os.listdir(d)
                    if f.endswith(".jsonl")))
        return out

    def clear(self) -> int:
        n = 0
        for key in self.keys():
            try:
                os.remove(self._path(key))
                n += 1
            except OSError:                            # pragma: no cover
                pass
        return n


def default_store() -> ResultStore | None:
    """The store the process-wide service uses; ``None`` (cache off) when
    ``CIM_TUNER_DISABLE_RESULT_STORE`` is set."""
    if os.environ.get("CIM_TUNER_DISABLE_RESULT_STORE"):
        return None
    return ResultStore()
