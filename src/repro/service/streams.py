"""Futures and streaming iterators for the async DSE service.

``JobQueue.submit`` returns an :class:`ExploreFuture`; :func:`as_completed`
turns any collection of them into an iterator that yields each future the
moment its micro-batch bucket finishes -- callers see the fast bucket's
results while the slow bucket is still annealing.  :func:`stream_pareto`
builds on the same machinery to stream per-workload Pareto frontiers.

Under the continuous-batching scheduler (docs/scheduler.md) a future may
resolve from *inside* another group's engine call: a submission admitted
at a rung boundary rides the in-flight race and its future resolves when
that race's group drains.  Nothing changes for consumers -- ``source``
still reads ``"engine"`` and every future resolves exactly once -- but
arrival order and resolution order decouple further than window batching
alone allowed, which is why every iterator here keys on completion
events rather than submission order.
"""
from __future__ import annotations

import queue as _queue
import threading
import typing

if typing.TYPE_CHECKING:                             # pragma: no cover
    from repro.core.engine import ExploreJob

__all__ = ["ExploreFuture", "as_completed", "stream_results",
           "stream_pareto"]


class ExploreFuture:
    """Single-job handle: resolves to an ``ExploreResult`` (explore jobs)
    or an ``np.ndarray`` of objective values (candidate-sweep jobs).

    ``source`` records where the result came from once done:
    ``"engine"`` (evaluated), ``"store"`` (persistent cache hit) or
    ``"inflight"`` (deduped onto an identical pending submission).
    """

    def __init__(self, job: "ExploreJob", method: str, key: str,
                 meta=None):
        self.job = job
        self.method = method
        self.key = key
        self.meta = meta                 # caller tag, round-tripped as-is
        self.source: str | None = None
        self._event = threading.Event()
        self._result = None
        self._exc: BaseException | None = None
        self._callbacks: list = []
        self._lock = threading.Lock()

    # ------------------------------------------------------------- #
    # consumer side
    # ------------------------------------------------------------- #
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until resolved (or ``timeout``); returns ``done()``.
        Unlike :meth:`result` this never raises -- the HTTP front door's
        long-poll path uses it to report failed jobs as data."""
        return self._event.wait(timeout)

    def result(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"job {self.key[:12]} not done "
                               f"after {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result

    def exception(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"job {self.key[:12]} not done "
                               f"after {timeout}s")
        return self._exc

    def add_done_callback(self, fn) -> None:
        """``fn(future)`` runs when the future resolves (immediately if it
        already has); exceptions in callbacks are swallowed."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:
            pass

    @classmethod
    def completed(
        cls,
        job,
        method: str,
        key: str,
        result=None,
        exc: BaseException | None = None,
        source: str = "store",
        meta=None,
    ) -> "ExploreFuture":
        """An already-resolved future -- how the HTTP server represents
        store-backed results and how the remote client materializes
        local-tier cache hits without touching a queue."""
        fut = cls(job, method, key, meta=meta)
        fut._finish(result, exc=exc, source=source)
        return fut

    # ------------------------------------------------------------- #
    # producer side (the queue worker)
    # ------------------------------------------------------------- #
    def _finish(self, result=None, exc: BaseException | None = None,
                source: str = "engine") -> None:
        with self._lock:
            if self._event.is_set():
                return                      # first resolution wins
            self._result = result
            self._exc = exc
            self.source = source
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            try:
                fn(self)
            except Exception:
                pass


def as_completed(
    futures: typing.Iterable[ExploreFuture],
    timeout: float | None = None,
) -> typing.Iterator[ExploreFuture]:
    """Yield futures in completion order (first finished bucket first).

    ``timeout`` is an overall deadline for the whole collection, matching
    ``concurrent.futures.as_completed`` semantics."""
    import time

    futures = list(futures)
    done: _queue.SimpleQueue = _queue.SimpleQueue()
    for f in futures:
        f.add_done_callback(done.put)
    deadline = None if timeout is None else time.monotonic() + timeout
    for _ in range(len(futures)):
        try:
            remaining = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            yield done.get(timeout=remaining)
        except _queue.Empty:
            raise TimeoutError(
                f"{len(futures)} futures not all done after {timeout}s"
            ) from None


def stream_results(
    futures: typing.Iterable[ExploreFuture],
    timeout: float | None = None,
) -> typing.Iterator[tuple]:
    """Yield ``(meta, result)`` pairs in completion order; failed jobs
    re-raise at their position in the stream."""
    for f in as_completed(futures, timeout=timeout):
        yield f.meta, f.result()


def stream_pareto(
    macro,
    workloads: typing.Sequence,
    area_budget_mm2: float,
    *,
    service=None,
    strategy_set: str = "st",
    space=None,
    bw: int = 256,
    timeout: float | None = None,
) -> typing.Iterator[tuple]:
    """Stream per-workload EE/Th Pareto frontiers: yields
    ``(workload_name, frontier)`` as each workload's candidate sweep
    completes.  All ``2 x len(workloads)`` sweep jobs go through the
    service queue, so overlapping submissions from other callers share
    executables and dedup."""
    import numpy as np

    from repro.core.engine import ExploreJob
    from repro.core.explorer import pareto_frontier_from_values
    from repro.core.pruning import DesignSpace, candidates_with_bw, prune_space

    if service is None:
        from repro.service.client import default_service
        service = default_service()

    space = space or DesignSpace()
    # candidate pruning depends only on (space, macro, budget, bw) -- one
    # prune serves every workload
    cands, _ = prune_space(space, macro, area_budget_mm2, bw)
    if len(cands) == 0:
        raise ValueError("no feasible hardware point under budget")
    rows = candidates_with_bw(cands, bw)

    futures = []
    per_wl: dict[str, dict] = {}
    for wl in workloads:
        per_wl[wl.name] = {"pending": 2, "vals": {}}
        for obj in ("th", "ee"):
            job = ExploreJob(
                macro=macro, workload=wl, area_budget_mm2=area_budget_mm2,
                objective=obj, strategy_set=strategy_set, bw=bw, space=space)
            futures.append(service.submit_values(
                job, rows, meta=(wl.name, obj)))

    wl_by_name = {wl.name: wl for wl in workloads}
    for f in as_completed(futures, timeout=timeout):
        name, obj = f.meta
        st = per_wl[name]
        st["vals"][obj] = np.asarray(f.result())
        st["pending"] -= 1
        if st["pending"] == 0:
            yield name, pareto_frontier_from_values(
                cands, st["vals"]["th"], st["vals"]["ee"],
                wl_by_name[name], macro, bw)
