"""Programmatic client of the async DSE service + process-wide default.

``ServiceClient`` wraps a :class:`~repro.service.queue.JobQueue` with the
call shapes consumers actually want: blocking ``explore`` (what the
``co_explore`` family delegates to), streaming ``explore(..., stream=True)``
(yields ``(meta, result)`` the moment each micro-batch bucket finishes), and
dict-based job specs so the CLI / JSON job files share one parser.

:func:`default_service` is the process-wide instance the blocking wrappers
in ``core/explorer.py`` use -- interleaved callers (tests, notebooks,
benchmark sweeps) therefore share one queue, one engine executable cache,
and one persistent result store.
"""
from __future__ import annotations

import atexit
import threading
import typing

from repro.core.annealing import SASettings
from repro.core.engine import ExplorationEngine, ExploreJob, valid_methods
from repro.core.ir import MatmulOp, Workload, bert_large_workload
from repro.core.macro import get_macro
from repro.core.pruning import DesignSpace
from repro.service.queue import JobQueue, QueueConfig
from repro.service.streams import ExploreFuture, stream_results

__all__ = ["ServiceClient", "default_service", "reset_default_service",
           "job_from_spec"]


# --------------------------------------------------------------------- #
# JSON job specs (CLI + programmatic)
# --------------------------------------------------------------------- #
def _workload_from_spec(spec) -> Workload:
    if isinstance(spec, dict) and "ops" in spec:
        ops = tuple(
            MatmulOp(m=o[0], k=o[1], n=o[2],
                     count=o[3] if len(o) > 3 else 1,
                     name=f"op{i}")
            for i, o in enumerate(spec["ops"]))
        return Workload(spec.get("name", "custom"), ops)
    name = spec["name"] if isinstance(spec, dict) else str(spec)
    seq = spec.get("seq", 512) if isinstance(spec, dict) else 512
    if name == "bert-large":
        return bert_large_workload(seq)
    from repro.configs import get_arch
    return get_arch(name).workload(seq=seq)


def job_from_spec(spec: dict) -> tuple[ExploreJob, str]:
    """``(ExploreJob, method)`` from one JSON job record.

    Minimal record::

        {"macro": "vanilla-dcim", "workload": "bert-large",
         "area_budget_mm2": 5.0}

    Optional keys: ``objective`` ("ee"|"th"|"edp"), ``strategy_set``
    ("st"|"so"), ``bw``, ``seq`` (inside workload dict), ``search`` --
    any registered ``repro.search`` backend ("sa", "genetic",
    "evolution", "sobol", "portfolio", ...) or "exhaustive" (``method``
    is the legacy spelling), ``space`` (axis-name -> value list), and
    inline workloads via
    ``{"workload": {"name": ..., "ops": [[m,k,n,count], ...]}}``.
    """
    space = None
    if "space" in spec:
        axes = {k: tuple(v) for k, v in spec["space"].items()}
        for k, v in axes.items():
            if not v:
                raise ValueError(f"space axis {k!r} must be non-empty")
        space = DesignSpace(**axes)
    method = spec.get("search", spec.get("method", "sa"))
    if method not in valid_methods():
        raise ValueError(
            f"unknown search {method!r}; valid: {sorted(valid_methods())}")
    job = ExploreJob(
        macro=get_macro(spec["macro"]),
        workload=_workload_from_spec(spec["workload"]),
        area_budget_mm2=float(spec["area_budget_mm2"]),
        objective=spec.get("objective", "ee"),
        strategy_set=spec.get("strategy_set", "st"),
        bw=int(spec.get("bw", 256)),
        space=space,
        search_method=method,
    )
    return job, method


# --------------------------------------------------------------------- #
# the client
# --------------------------------------------------------------------- #
class ServiceClient:
    """Convenience facade over one :class:`JobQueue`."""

    def __init__(
        self,
        queue: JobQueue | None = None,
        engine: ExplorationEngine | None = None,
        store="auto",
        config: QueueConfig = QueueConfig(),
    ):
        self.queue = queue or JobQueue(engine=engine, store=store,
                                       config=config)

    # passthroughs --------------------------------------------------- #
    def submit(self, job: ExploreJob, method: str | None = None,
               sa_settings: SASettings | None = None, priority: int = 0,
               meta=None, settings=None) -> ExploreFuture:
        return self.queue.submit(job, method, sa_settings, priority, meta,
                                 settings=settings)

    def submit_many(self, jobs, method=None, sa_settings=None,
                    priority=0, metas=None,
                    settings=None) -> list[ExploreFuture]:
        return self.queue.submit_many(jobs, method, sa_settings, priority,
                                      metas, settings=settings)

    def submit_values(self, job, candidates, priority=0, meta=None):
        return self.queue.submit_values(job, candidates, priority, meta)

    @property
    def stats(self) -> dict:
        return self.queue.stats

    @property
    def store(self):
        return self.queue.store

    # blocking / streaming ------------------------------------------- #
    def explore(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        sa_settings: SASettings | None = None,
        stream: bool = False,
        metas: typing.Sequence | None = None,
        timeout: float | None = None,
        settings=None,
    ):
        """Run a job list through the service.

        ``stream=False`` (default): blocking, returns results in
        submission order.  ``stream=True``: returns an iterator of
        ``(meta, result)`` in *completion* order -- metas default to the
        submission index.  ``method=None`` uses each job's own
        ``search_method``.
        """
        if metas is None:
            metas = list(range(len(jobs)))
        futures = self.submit_many(jobs, method, sa_settings, metas=metas,
                                   settings=settings)
        if stream:
            return stream_results(futures, timeout=timeout)
        return [f.result(timeout) for f in futures]

    def explore_specs(self, specs: typing.Sequence[dict],
                      stream: bool = False, timeout: float | None = None):
        """Dict-spec variant (the CLI path); method comes from each spec."""
        futures = []
        for i, spec in enumerate(specs):
            job, method = job_from_spec(spec)
            futures.append(self.submit(job, method, meta=i))
        if stream:
            return stream_results(futures, timeout=timeout)
        return [f.result(timeout) for f in futures]

    def close(self) -> None:
        self.queue.close()


# --------------------------------------------------------------------- #
# process-wide default service
# --------------------------------------------------------------------- #
_default_service: ServiceClient | None = None
_default_lock = threading.Lock()


def default_service() -> ServiceClient:
    """The shared always-on service (lazy; worker thread starts on first
    submission, drained at interpreter exit)."""
    global _default_service
    with _default_lock:
        if _default_service is None:
            _default_service = ServiceClient()
            atexit.register(_shutdown_default)
        return _default_service


def _shutdown_default() -> None:
    global _default_service
    with _default_lock:
        svc, _default_service = _default_service, None
    if svc is not None:
        svc.close()


def reset_default_service() -> None:
    """Tear down the shared service (tests / store re-pointing)."""
    _shutdown_default()
