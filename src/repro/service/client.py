"""Programmatic client of the async DSE service + process-wide default.

``ServiceClient`` wraps a :class:`~repro.service.queue.JobQueue` with the
call shapes consumers actually want: blocking ``explore`` (what the
``co_explore`` family delegates to), streaming ``explore(..., stream=True)``
(yields ``(meta, result)`` the moment each micro-batch bucket finishes), and
dict-based job specs so the CLI / JSON job files share one parser.

``ServiceClient(base_url=...)`` switches to **remote mode**: submissions go
over HTTP to a ``repro-service serve`` front door (``repro.service.server``)
instead of an in-process queue.  Jobs are shipped as the same JSON specs the
CLI reads (:func:`job_to_spec` inlines macros/tech/ops so arbitrary
in-memory jobs survive the wire bit-for-bit), results stream back over SSE
in completion order, and a read-through store tier
(:class:`~repro.service.store.RemoteStoreTier`) answers repeats from the
local disk cache first, then the server's shared store, before ever
submitting.

:func:`default_service` is the process-wide instance the blocking wrappers
in ``core/explorer.py`` use -- interleaved callers (tests, notebooks,
benchmark sweeps) therefore share one queue, one engine executable cache,
and one persistent result store.  When ``CIM_TUNER_SERVICE_URL`` is set it
transparently becomes a remote client of that server, so every
``co_explore`` / ``pareto_explore`` call in the process rides the shared
front door with zero code changes.
"""
from __future__ import annotations

import atexit
import dataclasses
import json
import os
import threading
import typing
import urllib.error
import urllib.parse
import urllib.request

import numpy as np

from repro.core.annealing import SASettings
from repro.core.calibration import TechConstants, resolve_tech
from repro.core.engine import (
    ExplorationEngine,
    ExploreJob,
    clone_result,
    valid_methods,
)
from repro.core.ir import MatmulOp, Workload, bert_large_workload
from repro.core.macro import MacroSpec, get_macro
from repro.core.pruning import DesignSpace
from repro.search.base import get_backend
from repro.service.queue import (
    JobQueue,
    QueueConfig,
    _normalize_submit_args,
    _tag_job_exc,
    values_key,
)
from repro.service.store import (
    RemoteStoreTier,
    ResultStore,
    default_store,
    deserialize_result,
)
from repro.service.streams import ExploreFuture, stream_results

__all__ = ["ServiceClient", "RemoteQueue", "default_service",
           "reset_default_service", "job_from_spec", "job_to_spec",
           "settings_from_spec", "settings_to_spec",
           "merge_spec_settings"]

#: environment variable that points every default-service consumer
#: (``co_explore`` & friends, benchmarks, the CLI) at a running
#: ``repro-service serve`` front door
SERVICE_URL_ENV = "CIM_TUNER_SERVICE_URL"

_SPACE_AXES = ("mr", "mc", "scr", "is_kb", "os_kb")


# --------------------------------------------------------------------- #
# JSON job specs (CLI + programmatic + the remote wire format)
# --------------------------------------------------------------------- #
def _op_from_spec(i: int, o) -> MatmulOp:
    if isinstance(o, dict):
        return MatmulOp(
            m=int(o["m"]), k=int(o["k"]), n=int(o["n"]),
            count=int(o.get("count", 1)),
            weights_static=bool(o.get("weights_static", True)),
            name=str(o.get("name", f"op{i}")))
    return MatmulOp(m=o[0], k=o[1], n=o[2],
                    count=o[3] if len(o) > 3 else 1,
                    name=str(o[4]) if len(o) > 4 else f"op{i}")


def _workload_from_spec(spec) -> Workload:
    if isinstance(spec, dict) and "ops" in spec:
        ops = tuple(_op_from_spec(i, o) for i, o in enumerate(spec["ops"]))
        return Workload(spec.get("name", "custom"), ops)
    name = spec["name"] if isinstance(spec, dict) else str(spec)
    seq = spec.get("seq", 512) if isinstance(spec, dict) else 512
    if name == "bert-large":
        return bert_large_workload(seq)
    from repro.configs import get_arch
    return get_arch(name).workload(seq=seq)


def _parse_search_spec(spec: dict) -> tuple[str, dict | None]:
    """``(method, settings-field-dict-or-None)`` from a job record's
    search keys.  ``"search"`` is either a backend-name string (legacy)
    or the structured form ``{"method": ..., "settings": {...},
    "allocator": "bandit"|"halving"}`` -- ``allocator`` is sugar for the
    portfolio's settings field of the same name.  A top-level
    ``"settings"`` dict (the original spelling) is still honoured, but
    giving settings in both places is ambiguous and rejected."""
    search = spec.get("search", spec.get("method", "sa"))
    top_settings = spec.get("settings")
    if isinstance(search, dict):
        unknown = set(search) - {"method", "settings", "allocator"}
        if unknown:
            raise ValueError(
                f"unknown 'search' keys {sorted(unknown)}; valid: "
                f"['method', 'settings', 'allocator']")
        method = search.get("method", "sa")
        settings_d = search.get("settings")
        if settings_d is not None and top_settings is not None:
            raise ValueError(
                "settings given both top-level and inside 'search' -- "
                "pick one spelling")
        settings_d = settings_d if settings_d is not None else top_settings
        allocator = search.get("allocator")
        if allocator is not None:
            settings_d = {**(settings_d or {}), "allocator": allocator}
    else:
        method, settings_d = search, top_settings
    if not isinstance(method, str) or method not in valid_methods():
        raise ValueError(
            f"unknown search {method!r}; valid: {sorted(valid_methods())}")
    return method, settings_d


def job_from_spec(spec: dict) -> tuple[ExploreJob, str]:
    """``(ExploreJob, method)`` from one JSON job record.

    Minimal record::

        {"macro": "vanilla-dcim", "workload": "bert-large",
         "area_budget_mm2": 5.0}

    Optional keys: ``objective`` ("ee"|"th"|"edp"), ``strategy_set``
    ("st"|"so"), ``bw``, ``seq`` (inside workload dict), ``search`` --
    any registered ``repro.search`` backend ("sa", "genetic",
    "evolution", "sobol", "portfolio", ...) or "exhaustive" as a plain
    string (``method`` is the legacy spelling), or the structured form
    ``{"method": "portfolio", "settings": {...}, "allocator": "bandit"}``
    carrying per-job backend settings (see :func:`_parse_search_spec`);
    ``settings`` (top-level backend settings fields, the original
    spelling), ``space`` (axis-name -> value list), ``merge_ops``, inline
    workloads via ``{"workload": {"name": ..., "ops": [[m,k,n,count],
    ...]}}`` (ops may also be field dicts), inline macros via
    ``{"macro": {<MacroSpec fields>}}``, and ``tech`` (TechConstants
    fields) -- the inline forms are what the remote client emits so any
    in-memory job round-trips the wire with its canonical key intact.
    Parsed settings land on ``ExploreJob.search_settings``, so they ride
    the job through every queue/engine layer and fold into ``job_key``.
    """
    space = None
    if "space" in spec:
        axes = {k: tuple(v) for k, v in spec["space"].items()}
        for k, v in axes.items():
            if not v:
                raise ValueError(f"space axis {k!r} must be non-empty")
        space = DesignSpace(**axes)
    method, settings_d = _parse_search_spec(spec)
    settings = settings_from_spec(method, settings_d)  # raises on bad fields
    macro = spec["macro"]
    macro = MacroSpec(**macro) if isinstance(macro, dict) else \
        get_macro(macro)
    tech = TechConstants(**spec["tech"]) if "tech" in spec else resolve_tech()
    job = ExploreJob(
        macro=macro,
        workload=_workload_from_spec(spec["workload"]),
        area_budget_mm2=float(spec["area_budget_mm2"]),
        objective=spec.get("objective", "ee"),
        strategy_set=spec.get("strategy_set", "st"),
        bw=int(spec.get("bw", 256)),
        tech=tech,
        space=space,
        merge_ops=bool(spec.get("merge_ops", True)),
        search_method=method,
        search_settings=settings,
    )
    return job, method


def job_to_spec(job: ExploreJob, method: str | None = None,
                settings=None) -> dict:
    """Inverse of :func:`job_from_spec` for arbitrary in-memory jobs (the
    remote client's wire format).  Macro and tech constants are inlined as
    full dataclass dicts and every op keeps its name, so
    :func:`repro.core.engine.job_key` of the round-tripped job matches the
    original bit-for-bit -- cross-host store sharing depends on it.
    ``settings`` (default: the job's own ``search_settings``) emits the
    structured ``"search": {"method": ..., "settings": {...}}`` form so
    per-job backend settings survive the wire too."""
    space = job.design_space()
    method = method or job.search_method
    if settings is None:
        settings = job.search_settings
    search: dict | str = method
    if settings is not None:
        search = {"method": method, "settings": settings_to_spec(settings)}
    return {
        "macro": dataclasses.asdict(job.macro),
        "workload": {
            "name": job.workload.name,
            "ops": [dataclasses.asdict(op) for op in job.workload.ops],
        },
        "area_budget_mm2": job.area_budget_mm2,
        "objective": job.objective,
        "strategy_set": job.strategy_set,
        "bw": job.bw,
        "tech": dataclasses.asdict(job.tech),
        "space": {k: list(v) for k, v in zip(_SPACE_AXES, space.axes())},
        "merge_ops": job.merge_ops,
        "search": search,
    }


def merge_spec_settings(spec: dict, override: dict) -> dict:
    """A copy of ``spec`` with ``override`` merged over its backend
    settings (whichever spelling the spec used) -- what the CLI's
    ``--search-settings`` flag applies to every record of a jobs file.
    A spec carrying settings in BOTH spellings is as ambiguous here as it
    is to :func:`job_from_spec`, and rejected the same way."""
    out = dict(spec)
    search = out.get("search")
    if isinstance(search, dict):
        search = dict(search)
        if search.get("settings") is not None and \
                out.get("settings") is not None:
            raise ValueError(
                "settings given both top-level and inside 'search' -- "
                "pick one spelling")
        if "allocator" in override:      # the override wins over the sugar
            search.pop("allocator", None)
        search["settings"] = {**(search.get("settings") or {}),
                              **(out.pop("settings", None) or {}),
                              **override}
        out["search"] = search
    else:
        out["settings"] = {**(out.get("settings") or {}), **override}
    return out


def settings_to_spec(settings) -> dict | None:
    """Backend settings dataclass -> JSON-able field dict (``None`` stays
    ``None`` -- exhaustive / server-side defaults)."""
    return None if settings is None else dataclasses.asdict(settings)


def settings_from_spec(method: str, d: dict | None):
    """Field dict -> the backend's settings dataclass (lists become tuples
    so the reconstructed object is hashable for the executable cache).
    ``None`` means "use the backend's defaults server-side"."""
    if d is None or method == "exhaustive":
        return None
    cls = get_backend(method).settings_cls
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    if unknown:
        raise ValueError(
            f"unknown {cls.__name__} fields {sorted(unknown)}; "
            f"valid: {sorted(names)}")
    return cls(**{k: tuple(v) if isinstance(v, list) else v
                  for k, v in d.items()})


# --------------------------------------------------------------------- #
# remote mode: HTTP client of repro.service.server
# --------------------------------------------------------------------- #
def _read_sse(resp) -> typing.Iterator[tuple[str | None, dict]]:
    """Minimal SSE reader: yields ``(event, parsed-json-data)`` records."""
    event: str | None = None
    data: list[str] = []
    for raw in resp:
        line = raw.decode("utf-8").rstrip("\r\n")
        if not line:
            if data:
                yield event, json.loads("".join(data))
            event, data = None, []
        elif line.startswith(":"):
            continue                                   # keep-alive ping
        elif line.startswith("event:"):
            event = line[len("event:"):].strip()
        elif line.startswith("data:"):
            data.append(line[len("data:"):].strip())


class RemoteQueue:
    """Drop-in ``JobQueue`` replacement that talks to a ``repro-service
    serve`` front door over HTTP.

    Admission tiers mirror the local queue: **local store -> remote store
    (read-through GET) -> POST /v1/jobs**.  Posted jobs resolve through one
    ``GET /v1/stream`` SSE connection per submission batch, so futures
    complete in the server's per-bucket completion order exactly like
    in-process callers.  Engine results arriving over the wire are written
    into the local store tier, so the next identical query on this host is
    answered without any network traffic at all.

    Batches larger than :attr:`REMOTE_PROBE_MAX_JOBS` skip the per-job
    remote GET (each cold probe is a full round-trip) and go local-tier ->
    POST directly; the server still answers warm keys inline from the
    shared store at admission, so nothing is recomputed either way.
    """

    #: largest submission batch that still probes the remote store tier
    #: per job before POSTing
    REMOTE_PROBE_MAX_JOBS = 4

    def __init__(
        self,
        base_url: str,
        store: ResultStore | None | str = "auto",
        timeout_s: float = 600.0,
    ):
        """Connect to the front door at ``base_url`` (scheme optional).

        ``store`` is the local read-through tier (``"auto"`` resolves via
        :func:`repro.service.store.default_store`, honouring
        ``CIM_TUNER_RESULT_STORE`` / ``CIM_TUNER_DISABLE_RESULT_STORE``;
        ``None`` disables local caching); ``timeout_s`` bounds how long a
        posted batch's SSE stream may stay open.
        """
        if "://" not in base_url:
            base_url = "http://" + base_url
        self.base_url = base_url.rstrip("/")
        local = default_store() if store == "auto" else store
        self.store = RemoteStoreTier(self.base_url, local=local)
        self.timeout_s = float(timeout_s)
        self.stats = {"submitted": 0, "store_hits": 0, "remote_store_hits": 0,
                      "posted": 0, "completed": 0, "failed": 0}
        self._lock = threading.Lock()
        self._streamers: list[threading.Thread] = []
        self._closed = False

    def _bump(self, counter: str) -> None:
        """Locked counter increment (submissions and streamer threads
        mutate the same stats dict concurrently)."""
        with self._lock:
            self.stats[counter] += 1

    # ------------------------------------------------------------- #
    # submission API (JobQueue-compatible surface)
    # ------------------------------------------------------------- #
    def submit(self, job: ExploreJob, method: str | None = None,
               sa_settings: SASettings | None = None, priority: int = 0,
               meta=None, settings=None,
               fidelity: str | None = None) -> ExploreFuture:
        """Admit one job (a batch of one through :meth:`submit_many`)."""
        return self.submit_many([job], method, sa_settings, priority,
                                metas=[meta], settings=settings,
                                fidelity=fidelity)[0]

    def submit_many(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        sa_settings: SASettings | None = None,
        priority: int = 0,
        metas: typing.Sequence | None = None,
        settings=None,
        fidelity: str | None = None,
    ) -> list[ExploreFuture]:
        """Admit a job batch; returns one future per job immediately.

        Same surface as :meth:`JobQueue.submit_many`: ``method=None``
        uses each job's own ``search_method``; ``settings=None`` resolves
        per job (``job.search_settings``, then backend defaults) and the
        RESOLVED settings ship over the wire, so the server keys every
        job exactly as this client just did.
        """
        metas = metas if metas is not None else [None] * len(jobs)
        if len(metas) != len(jobs):
            raise ValueError(
                f"metas length {len(metas)} != jobs length {len(jobs)}")
        if self._closed:
            raise RuntimeError("remote service client is closed")
        futures: list[ExploreFuture] = []
        post_specs: list[dict] = []
        post_futs: list[ExploreFuture] = []
        # the read-through chain (local -> remote GET -> submit) costs one
        # synchronous round-trip per COLD job; past a few jobs the batched
        # POST is strictly cheaper, because the server answers warm keys
        # inline from the same store at admission anyway
        probe_remote = len(jobs) <= self.REMOTE_PROBE_MAX_JOBS
        for job, meta in zip(jobs, metas):
            # the one shared submit contract (repro.service.queue): the
            # canonical key computed here matches the server's exactly
            m, eff, key = _normalize_submit_args(
                job, method, settings, sa_settings, fidelity)
            fut = ExploreFuture(job, m, key, meta=meta)
            futures.append(fut)
            self._bump("submitted")
            cached = self.store.get(key) if probe_remote else (
                self.store.local.get(key)
                if self.store.local is not None else None)
            if cached is not None:
                tier = cached.search.get("cache")
                self._bump("remote_store_hits" if tier == "remote-store"
                           else "store_hits")
                fut._finish(cached, source="store")
                continue
            # ship the RESOLVED settings (structured "search" form), so
            # the server's queue keys the job exactly like we just did
            spec = job_to_spec(job, m, settings=eff)
            if priority:
                spec["priority"] = int(priority)
            post_specs.append(spec)
            post_futs.append(fut)
        if post_specs:
            self._post_jobs(post_specs, post_futs)
        return futures

    def submit_values(self, job: ExploreJob, candidates, priority: int = 0,
                      meta=None) -> ExploreFuture:
        """Remote candidate sweep (the Pareto path); resolves to the ``[C]``
        objective-value array computed server-side."""
        if self._closed:
            raise RuntimeError("remote service client is closed")
        rows = np.asarray(candidates, dtype=np.float64)
        fut = ExploreFuture(job, "values", values_key(job, rows), meta=meta)
        self._bump("submitted")
        spec = job_to_spec(job, "exhaustive")
        spec["candidates"] = rows.tolist()
        if priority:
            spec["priority"] = int(priority)
        self._post_jobs([spec], [fut])
        return fut

    def run_sync(self, jobs, method=None, sa_settings=None,
                 timeout: float | None = None, settings=None,
                 fidelity: str | None = None):
        """Blocking batch call: submit, then wait for every result in
        submission order (the remote analogue of ``JobQueue.run_sync``).
        """
        futures = self.submit_many(jobs, method, sa_settings,
                                   settings=settings, fidelity=fidelity)
        return [f.result(timeout) for f in futures]

    # ------------------------------------------------------------- #
    # introspection / lifecycle
    # ------------------------------------------------------------- #
    def depth(self) -> dict:
        """Client-side depth view: live SSE streamer threads (the server
        owns the real queue depth -- see :meth:`stats_snapshot`)."""
        with self._lock:
            live = sum(t.is_alive() for t in self._streamers)
        return {"pending": 0, "inflight": live}

    def stats_snapshot(self) -> dict:
        """Server-side ``/v1/stats`` merged with this client's counters."""
        snap = self._get_json("/v1/stats")
        snap["client"] = {**self.stats, "store": dict(self.store.stats)}
        return snap

    def close(self, timeout: float | None = 10.0) -> None:
        """Refuse new submissions and join the live SSE streamers (the
        server keeps running; only this client's connections drain)."""
        self._closed = True
        with self._lock:
            streamers = list(self._streamers)
        for t in streamers:
            t.join(timeout)

    def __enter__(self):
        """Context-manager support: ``with RemoteQueue(url) as q:``."""
        return self

    def __exit__(self, *exc):
        """Close on context exit (see :meth:`close`)."""
        self.close()

    # ------------------------------------------------------------- #
    # wire internals
    # ------------------------------------------------------------- #
    def _get_json(self, path: str) -> dict:
        with urllib.request.urlopen(self.base_url + path,
                                    timeout=30.0) as resp:
            return json.loads(resp.read().decode("utf-8"))

    def _post_jobs(self, specs: list[dict],
                   futures: list[ExploreFuture]) -> None:
        req = urllib.request.Request(
            self.base_url + "/v1/jobs",
            data=json.dumps(specs).encode("utf-8"),
            headers={"Content-Type": "application/json"},
            method="POST")
        try:
            with urllib.request.urlopen(req, timeout=60.0) as resp:
                out = json.loads(resp.read().decode("utf-8"))
            states = out["jobs"]
            if len(states) != len(futures):
                raise ValueError(
                    f"server answered {len(states)} states for "
                    f"{len(futures)} jobs")
        except Exception as exc:                       # noqa: BLE001
            err = self._wire_error(exc)
            for fut in futures:
                self._fail(fut, err)
            return
        with self._lock:
            self.stats["posted"] += len(specs)
        pending: dict[str, list[ExploreFuture]] = {}
        for state, fut in zip(states, futures):
            if state.get("status") in ("done", "failed"):
                self._resolve_safe(fut, state)
            else:
                pending.setdefault(state["key"], []).append(fut)
        if pending:
            t = threading.Thread(target=self._stream_worker, args=(pending,),
                                 name="cim-tuner-remote-stream", daemon=True)
            with self._lock:
                # prune finished streamers so a long-lived client doesn't
                # accumulate one dead Thread per submission batch
                self._streamers = [x for x in self._streamers
                                   if x.is_alive()]
                self._streamers.append(t)
            t.start()

    def _stream_worker(self, pending: dict[str, list[ExploreFuture]]) -> None:
        query = urllib.parse.urlencode(
            {"keys": ",".join(pending), "timeout": f"{self.timeout_s:g}"})
        url = f"{self.base_url}/v1/stream?{query}"
        err: BaseException | None = None
        try:
            with urllib.request.urlopen(url, timeout=120.0) as resp:
                for event, obj in _read_sse(resp):
                    if event == "result":
                        for i, fut in enumerate(pending.pop(obj["key"], ())):
                            self._resolve_safe(fut, obj, fan_out=i > 0)
                    elif event == "end":
                        break
                    if not pending:
                        break
        except Exception as exc:                       # noqa: BLE001
            err = self._wire_error(exc)
        if pending:
            # the stream ended (server timeout event, clean EOF, or wire
            # error) with futures unresolved -- fail them rather than
            # leaving callers blocked forever
            if err is None:
                err = TimeoutError(
                    f"DSE server {self.base_url} stream ended with "
                    f"{len(pending)} job(s) unresolved")
            for futs in pending.values():
                for fut in futs:
                    self._fail(fut, err)

    def _resolve_safe(self, fut: ExploreFuture, state: dict,
                      fan_out: bool = False) -> None:
        """A malformed/incompatible server payload must FAIL the future,
        never abandon it (the caller may be blocked with timeout=None)."""
        try:
            self._resolve(fut, state, fan_out=fan_out)
        except Exception as exc:                       # noqa: BLE001
            self._fail(fut, ValueError(
                f"undecodable server response for job: {exc!r}"))

    def _resolve(self, fut: ExploreFuture, state: dict,
                 fan_out: bool = False) -> None:
        status = state.get("status")
        if status == "failed":
            exc: BaseException = RuntimeError(
                f"remote job failed ({state.get('error_type', 'Error')}): "
                f"{state.get('error', 'unknown error')}")
            self._fail(fut, exc)
            return
        source = state.get("source") or "engine"
        if "values" in state:
            fut._finish(np.asarray(state["values"], dtype=np.float64),
                        source=source)
        else:
            result = deserialize_result(state["result"])
            result.search["remote"] = True
            if fan_out:
                result = clone_result(result)
            # read-through: engine answers computed server-side become
            # local-tier records, so this host's next identical query
            # never touches the network
            self.store.put(fut.key, result)
            fut._finish(result, source=source)
        self._bump("completed")

    def _fail(self, fut: ExploreFuture, exc: BaseException) -> None:
        # per-future copy tagged with ITS key (one wire error can fail a
        # whole batch; sharing the object would stamp every future with
        # the first one's job_key)
        self._bump("failed")
        fut._finish(exc=_tag_job_exc(exc, fut.key), source="remote")

    def _wire_error(self, exc: Exception) -> BaseException:
        if isinstance(exc, urllib.error.HTTPError):
            try:
                detail = exc.read().decode("utf-8", "replace")[:500]
            except Exception:                          # noqa: BLE001
                detail = ""
            return ConnectionError(
                f"DSE server {self.base_url} answered HTTP {exc.code}: "
                f"{detail}")
        return ConnectionError(
            f"DSE server {self.base_url} unreachable: {exc!r}")


# --------------------------------------------------------------------- #
# the client
# --------------------------------------------------------------------- #
class ServiceClient:
    """Convenience facade over one :class:`JobQueue` (in-process) or one
    :class:`RemoteQueue` (``base_url=`` / ``CIM_TUNER_SERVICE_URL``)."""

    def __init__(
        self,
        queue: JobQueue | RemoteQueue | None = None,
        engine: ExplorationEngine | None = None,
        store="auto",
        config: QueueConfig = QueueConfig(),
        base_url: str | None = None,
    ):
        """Wrap an explicit ``queue``, or build one: ``base_url=`` makes
        a :class:`RemoteQueue` (remote mode), otherwise an in-process
        :class:`JobQueue` over ``engine`` (``None`` = the process-wide
        default engine) with the given ``store``/``config``."""
        if queue is not None:
            self.queue: JobQueue | RemoteQueue = queue
        elif base_url:
            self.queue = RemoteQueue(base_url, store=store)
        else:
            self.queue = JobQueue(engine=engine, store=store, config=config)

    @property
    def remote(self) -> bool:
        """True when submissions go over HTTP to a serve front door."""
        return isinstance(self.queue, RemoteQueue)

    # passthroughs --------------------------------------------------- #
    def submit(self, job: ExploreJob, method: str | None = None,
               sa_settings: SASettings | None = None, priority: int = 0,
               meta=None, settings=None,
               fidelity: str | None = None) -> ExploreFuture:
        """Admit one job (see :meth:`JobQueue.submit`); per-job
        ``job.search_settings`` apply when ``settings`` is ``None``."""
        return self.queue.submit(job, method, sa_settings, priority, meta,
                                 settings=settings, fidelity=fidelity)

    def submit_many(self, jobs, method=None, sa_settings=None,
                    priority=0, metas=None, settings=None,
                    fidelity: str | None = None) -> list[ExploreFuture]:
        """Admit a job batch (see :meth:`JobQueue.submit_many`)."""
        return self.queue.submit_many(jobs, method, sa_settings, priority,
                                      metas, settings=settings,
                                      fidelity=fidelity)

    def submit_values(self, job, candidates, priority=0, meta=None):
        """Admit a ``[C, 6]`` candidate sweep; the future resolves to the
        ``[C]`` objective-value array (the Pareto path)."""
        return self.queue.submit_values(job, candidates, priority, meta)

    @property
    def stats(self) -> dict:
        """The underlying queue's counter dict (live, not a snapshot)."""
        return self.queue.stats

    @property
    def store(self):
        """The queue's result-store tier (``None`` when caching is off)."""
        return self.queue.store

    def stats_snapshot(self) -> dict:
        """Full counter view: the server's ``/v1/stats`` in remote mode,
        the local queue/store/engine snapshot otherwise."""
        return self.queue.stats_snapshot()

    # blocking / streaming ------------------------------------------- #
    def explore(
        self,
        jobs: typing.Sequence[ExploreJob],
        method: str | None = None,
        sa_settings: SASettings | None = None,
        stream: bool = False,
        metas: typing.Sequence | None = None,
        timeout: float | None = None,
        settings=None,
        fidelity: str | None = None,
    ):
        """Run a job list through the service.

        ``stream=False`` (default): blocking, returns results in
        submission order.  ``stream=True``: returns an iterator of
        ``(meta, result)`` in *completion* order -- metas default to the
        submission index.  ``method=None`` uses each job's own
        ``search_method``.
        """
        if metas is None:
            metas = list(range(len(jobs)))
        futures = self.submit_many(jobs, method, sa_settings, metas=metas,
                                   settings=settings, fidelity=fidelity)
        if stream:
            return stream_results(futures, timeout=timeout)
        return [f.result(timeout) for f in futures]

    def explore_specs(self, specs: typing.Sequence[dict],
                      stream: bool = False, timeout: float | None = None):
        """Dict-spec variant (the CLI path).  Each spec's method AND
        backend settings ride the parsed job itself
        (``ExploreJob.search_method`` / ``.search_settings``), so the
        whole file is ONE ``submit_many`` batch regardless of how
        heterogeneous it is -- a remote client ships one POST + one SSE
        stream, and the server stacks every (bucket, method, settings)
        group into shared micro-batch dispatches."""
        jobs = [job_from_spec(spec)[0] for spec in specs]
        futures = self.submit_many(jobs, metas=list(range(len(specs))))
        if stream:
            return stream_results(futures, timeout=timeout)
        return [f.result(timeout) for f in futures]

    def close(self) -> None:
        """Drain and stop the underlying queue (in-process: waits for
        pending micro-batches; remote: joins live streams)."""
        self.queue.close()


# --------------------------------------------------------------------- #
# process-wide default service
# --------------------------------------------------------------------- #
_default_service: ServiceClient | None = None
_default_lock = threading.Lock()


def default_service() -> ServiceClient:
    """The shared always-on service (lazy; worker thread starts on first
    submission, drained at interpreter exit).  With ``CIM_TUNER_SERVICE_URL``
    set this is a remote client of that front door instead of an in-process
    queue -- every blocking wrapper in the process transparently shares the
    fleet-wide engine and store."""
    global _default_service
    with _default_lock:
        if _default_service is None:
            url = os.environ.get(SERVICE_URL_ENV)
            _default_service = ServiceClient(base_url=url) if url \
                else ServiceClient()
            atexit.register(_shutdown_default)
        return _default_service


def _shutdown_default() -> None:
    global _default_service
    with _default_lock:
        svc, _default_service = _default_service, None
    if svc is not None:
        svc.close()


def reset_default_service() -> None:
    """Tear down the shared service (tests / store or URL re-pointing)."""
    _shutdown_default()
