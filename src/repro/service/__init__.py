"""Async DSE service over the batched exploration engine.

Turns ``ExplorationEngine`` into an always-on exploration service:

* ``queue.py``   -- thread-backed job queue: priorities, micro-batching
  (submissions coalesce for a small window / size threshold), canonical-key
  dedup, one engine ``run()`` per executable bucket;
* ``streams.py`` -- ``submit() -> ExploreFuture``, ``as_completed()``,
  ``stream_pareto()``: callers receive each job's result the moment its
  bucket finishes, not when the whole submission drains;
* ``store.py``   -- persistent on-disk result store (content-addressed by
  job key, JSONL records, atomic rename) so repeated queries across
  processes hit cache instead of re-annealing;
* ``client.py``  -- programmatic client + process-wide
  :func:`default_service`, which ``co_explore`` / ``co_explore_macros`` /
  ``pareto_explore`` use as their synchronous front door;
* ``python -m repro.service`` -- CLI: stream result batches as they arrive.

Quickstart::

    from repro.service import default_service
    svc = default_service()
    futures = svc.submit_many(jobs, method="exhaustive")
    for fut in as_completed(futures):
        print(fut.result().summary())
"""
from repro.service.client import (ServiceClient, default_service,
                                  job_from_spec, reset_default_service)
from repro.service.queue import JobQueue, QueueConfig
from repro.service.store import (ResultStore, default_store,
                                 deserialize_result, serialize_result)
from repro.service.streams import (ExploreFuture, as_completed,
                                   stream_pareto, stream_results)

__all__ = [
    "ServiceClient", "default_service", "reset_default_service",
    "job_from_spec",
    "JobQueue", "QueueConfig",
    "ResultStore", "default_store", "serialize_result",
    "deserialize_result",
    "ExploreFuture", "as_completed", "stream_results", "stream_pareto",
]
