"""Async DSE service over the batched exploration engine.

Turns ``ExplorationEngine`` into an always-on exploration service:

* ``queue.py``   -- thread-backed job queue: priorities, micro-batching
  (submissions coalesce for a small window / size threshold), canonical-key
  dedup, one engine ``run()`` per executable bucket;
* ``streams.py`` -- ``submit() -> ExploreFuture``, ``as_completed()``,
  ``stream_pareto()``: callers receive each job's result the moment its
  bucket finishes, not when the whole submission drains;
* ``store.py``   -- persistent on-disk result store (content-addressed by
  job key, JSONL records, atomic rename) so repeated queries across
  processes hit cache instead of re-annealing;
* ``client.py``  -- programmatic client + process-wide
  :func:`default_service`, which ``co_explore`` / ``co_explore_macros`` /
  ``pareto_explore`` use as their synchronous front door;
  ``ServiceClient(base_url=...)`` (or ``CIM_TUNER_SERVICE_URL``) switches
  to remote mode against a running HTTP front door;
* ``server.py``  -- ``repro-service serve``: stdlib HTTP front door (job
  POSTs, SSE streaming, shared-store GETs, /healthz + /v1/stats) so many
  OS processes and hosts share ONE warm engine and result store;
* ``python -m repro.service`` -- CLI: stream result batches as they
  arrive, serve the front door, inspect stats/store.

Quickstart::

    from repro.service import default_service
    svc = default_service()
    futures = svc.submit_many(jobs, method="exhaustive")
    for fut in as_completed(futures):
        print(fut.result().summary())
"""
from repro.service.client import (RemoteQueue, ServiceClient,
                                  default_service, job_from_spec,
                                  job_to_spec, merge_spec_settings,
                                  reset_default_service,
                                  settings_from_spec, settings_to_spec)
from repro.service.queue import JobQueue, QueueConfig, values_key
from repro.service.store import (RemoteStoreTier, ResultStore,
                                 default_store, deserialize_result,
                                 serialize_result)
from repro.service.streams import (ExploreFuture, as_completed,
                                   stream_pareto, stream_results)

__all__ = [
    "ServiceClient", "RemoteQueue", "default_service",
    "reset_default_service",
    "job_from_spec", "job_to_spec", "settings_from_spec",
    "settings_to_spec", "merge_spec_settings",
    "JobQueue", "QueueConfig", "values_key",
    "ResultStore", "RemoteStoreTier", "default_store", "serialize_result",
    "deserialize_result",
    "ExploreFuture", "as_completed", "stream_results", "stream_pareto",
]
