"""CLI front door of the async DSE service.

    python -m repro.service explore jobs.json --stream
    python -m repro.service explore jobs.json --json
    python -m repro.service explore jobs.json --url http://host:8731
    python -m repro.service serve --host 0.0.0.0 --port 8731
    python -m repro.service stats --url http://host:8731
    python -m repro.service store --info
    python -m repro.service store --clear
    python -m repro.service trace --export chrome -o trace.json

``jobs.json`` is a list of job specs (see
:func:`repro.service.client.job_from_spec`)::

    [{"macro": "vanilla-dcim", "workload": "bert-large",
      "area_budget_mm2": 5.0, "objective": "ee", "search": "exhaustive"},
     {"macro": "tpdcim-macro", "workload": {"name": "yi-6b", "seq": 512},
      "area_budget_mm2": 2.23, "objective": "th", "search": "portfolio"}]

Each spec's ``"search"`` key picks the optimizer per job: any registered
``repro.search`` backend ("sa", "genetic", "evolution", "sobol",
"portfolio") or "exhaustive" as a plain name, or the structured per-job
form ``{"method": "portfolio", "settings": {"total_evals": 8000},
"allocator": "bandit"}`` (a top-level ``"settings"`` dict is the legacy
spelling).  ``explore --search NAME`` overrides every spec's backend;
``--search-settings '{"total_evals": 8000}'`` merges a JSON dict over
every spec's backend settings.  With ``--stream`` each result line
prints the moment its micro-batch bucket finishes (completion order);
without it, results print in submission order once all are done.

``explore``/``stats`` run against a remote ``serve`` instance when
``--url`` (or the ``CIM_TUNER_SERVICE_URL`` environment variable) points
at one -- CI fleets and multi-host sweeps share that server's warm engine
executables and result store instead of each paying their own warm-up.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import threading
import time


def _resolved_url(args) -> str | None:
    return args.url or os.environ.get("CIM_TUNER_SERVICE_URL") or None


def _cmd_explore(args) -> int:
    from repro.service import ServiceClient, serialize_result

    with open(args.jobs_file) as f:
        specs = json.load(f)
    if not isinstance(specs, list) or not specs:
        print("error: jobs file must be a non-empty JSON list",
              file=sys.stderr)
        return 2
    if args.search:
        # override drops any structured search dict (its settings belong
        # to the replaced backend); --search-settings can re-supply knobs
        specs = [{**spec, "search": args.search} for spec in specs]
        for spec in specs:
            spec.pop("settings", None)
    if args.search_settings:
        from repro.service import merge_spec_settings
        try:
            override = json.loads(args.search_settings)
            if not isinstance(override, dict):
                raise ValueError("must be a JSON object")
            # raises on ambiguous specs (settings in both spellings)
            specs = [merge_spec_settings(spec, override) for spec in specs]
        except ValueError as exc:
            print(f"error: bad --search-settings: {exc}", file=sys.stderr)
            return 2
    # validate every spec (including the --search/--search-settings
    # overrides) up front, so a typo'd backend name or settings field
    # fails fast with a clean error, not a traceback out of the running
    # service
    from repro.service import job_from_spec
    try:
        for spec in specs:
            job_from_spec(spec)
    except (KeyError, TypeError, ValueError) as exc:
        print(f"error: bad job spec: {exc}", file=sys.stderr)
        return 2

    svc = ServiceClient(store=None if args.no_store else "auto",
                        base_url=_resolved_url(args))
    t0 = time.perf_counter()

    def emit(i, result):
        dt = time.perf_counter() - t0
        cache = result.search.get("cache")
        if args.json:
            rec = {"index": i, "elapsed_s": round(dt, 3),
                   "source": cache or "engine",
                   "result": serialize_result(result)}
            print(json.dumps(rec), flush=True)
        else:
            src = f" [{cache}]" if cache else ""
            print(f"[{dt:7.2f}s] #{i} {result.summary()}{src}", flush=True)

    try:
        if args.stream:
            for i, result in svc.explore_specs(specs, stream=True):
                emit(i, result)
        else:
            for i, result in enumerate(svc.explore_specs(specs)):
                emit(i, result)
    finally:
        svc.close()
    if not args.json:
        print(f"# {len(specs)} jobs in {time.perf_counter()-t0:.2f}s "
              f"(stats: {svc.stats})", flush=True)
    return 0


def _cmd_serve(args) -> int:
    from repro.service.server import DSEServer, ServerConfig

    cfg = ServerConfig(host=args.host, port=args.port, quiet=not args.verbose)
    server = DSEServer(store=None if args.no_store else "auto", config=cfg)
    server.start()
    print(f"serving on {server.url}", flush=True)
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(server.port))
    stop = threading.Event()
    for sig in (signal.SIGINT, signal.SIGTERM):
        signal.signal(sig, lambda *_: stop.set())
    try:
        while not stop.is_set():       # short waits keep signals prompt
            stop.wait(1.0)
    finally:
        print("draining in-flight buckets ...", flush=True)
        server.shutdown(drain=True)
        print(f"stopped ({server.http_stats['requests']} requests served)",
              flush=True)
    return 0


def _cmd_stats(args) -> int:
    from repro.service import ServiceClient, default_service

    url = _resolved_url(args)
    svc = ServiceClient(base_url=url, store=None) if url \
        else default_service()
    print(json.dumps(svc.stats_snapshot(), indent=2))
    return 0


def _cmd_trace(args) -> int:
    from repro import obs

    events: list = []
    if args.url or (not args.input and os.environ.get(
            "CIM_TUNER_SERVICE_URL") and not os.environ.get(
            "CIM_TUNER_TRACE")):
        # live ring buffer of a running serve instance
        import urllib.request
        url = (args.url or os.environ["CIM_TUNER_SERVICE_URL"]).rstrip("/")
        with urllib.request.urlopen(f"{url}/v1/trace", timeout=30) as resp:
            doc = json.loads(resp.read().decode("utf-8"))
        events = doc.get("traceEvents", [])
    else:
        path = args.input or os.environ.get("CIM_TUNER_TRACE")
        if not path:
            print("error: no trace source -- pass --input FILE / --url URL "
                  "or set CIM_TUNER_TRACE", file=sys.stderr)
            return 2
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if line:
                        events.append(json.loads(line))
        except (OSError, ValueError) as exc:
            print(f"error: cannot read trace {path!r}: {exc}",
                  file=sys.stderr)
            return 2

    if args.export == "chrome":
        out = args.output or "trace.json"
        with open(out, "w") as f:
            json.dump(obs.chrome_trace(events), f)
        print(f"wrote {len(events)} spans to {out} "
              f"(load in Perfetto / chrome://tracing)")
    else:                                              # jsonl
        stream = open(args.output, "w") if args.output else sys.stdout
        try:
            for ev in events:
                stream.write(json.dumps(ev) + "\n")
        finally:
            if args.output:
                stream.close()
                print(f"wrote {len(events)} spans to {args.output}")
    return 0


def _cmd_timeline(args) -> int:
    from repro.obs.recorder import render_timeline

    url = _resolved_url(args)
    timeline = None
    if url:
        import urllib.error
        import urllib.request
        endpoint = f"{url.rstrip('/')}/v1/jobs/{args.key}/timeline"
        try:
            with urllib.request.urlopen(endpoint, timeout=30) as resp:
                doc = json.loads(resp.read().decode("utf-8"))
            timeline = doc.get("timeline")
        except urllib.error.HTTPError as exc:
            if exc.code != 404:
                raise
    else:
        from repro.service import default_store
        store = default_store()
        timeline = store.get_timeline(args.key) \
            if store is not None else None
    if timeline is None:
        print(f"error: no timeline for job {args.key!r}", file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(timeline, indent=2, sort_keys=True))
    else:
        print(render_timeline(timeline))
    return 0


def _cmd_profile(args) -> int:
    os.environ["CIM_TUNER_PROFILE"] = "1"
    from repro import obs

    kernels = [k for k in (args.kernels or "").split(",") if k] or None
    try:
        records = obs.profile.run_microbench(kernels=kernels,
                                             repeats=args.repeats)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    rows = obs.profile.summary(records)
    if args.json:
        print(json.dumps(rows, indent=2))
    else:
        print(f"{'kernel':<16} {'bucket':<18} {'calls':>6} "
              f"{'us/call':>12} {'flops':>12} {'bytes':>12} {'roofline':>9}")
        for r in rows:
            print(f"{r['kernel']:<16} {r['bucket']:<18} "
                  f"{r['calls']:>6} {r['us_per_call']:>12.1f} "
                  f"{r['flops']:>12.3g} {r['bytes']:>12.3g} "
                  f"{r['roofline_utilization']:>9.2e}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(obs.registry().render())
        print(f"# wrote metrics exposition to {args.metrics_out}",
              file=sys.stderr)
    return 0


def _cmd_calibrate(args) -> int:
    """Measure -> fit -> (optionally) pin: the calibration tier's CLI.

    Runs the kernel microbench sweep (or reads measurements from a prior
    artifact via ``--input``), fits correction factors with a held-out
    split, prints the fit report, and writes a calibration artifact that
    ``CIM_TUNER_CALIBRATION`` can pin (see docs/calibration.md)."""
    from repro.core import calibration as cal

    if args.input:
        try:
            _cf, payload = cal.load_calibration(args.input)
            records = payload.get("measurements") or []
            if not records:
                raise ValueError("artifact carries no measurements")
        except (OSError, ValueError) as exc:
            print(f"error: cannot reuse {args.input!r}: {exc}",
                  file=sys.stderr)
            return 2
    else:
        from repro import obs
        kernels = [k for k in (args.kernels or "").split(",") if k] or None
        try:
            records = obs.run_microbench(kernels=kernels,
                                         repeats=args.repeats,
                                         seed=args.seed)
        except ValueError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
    try:
        report = cal.fit_report(records, holdout_fraction=args.holdout,
                                seed=args.seed)
        corrections = cal.fit_corrections(records)
    except ValueError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    payload = None
    if args.output:
        payload = cal.save_calibration(args.output, corrections,
                                       records=records, report=report)
    if args.json:
        out = {"records": len(records), "report": report,
               "corrections": corrections.as_dict(),
               "version": cal.calibration_version(corrections)}
        if args.output:
            out["artifact"] = args.output
        print(json.dumps(out, indent=2))
        return 0
    print(f"measurements : {len(records)} records")
    print(f"corrections  : compute={corrections.compute:.4g} "
          f"memory={corrections.memory:.4g} "
          f"update={corrections.update:.4g}")
    print(f"version      : {cal.calibration_version(corrections)}")
    print(f"holdout RMS  : uncalibrated "
          f"{report['uncalibrated_rms_us']:.2f}us -> calibrated "
          f"{report['calibrated_rms_us']:.2f}us "
          f"(improvement {report['improvement']:.2f}x)")
    if payload is not None:
        print(f"artifact     : {args.output}  "
              f"(pin with {cal.CALIBRATION_ENV}={args.output})")
    return 0


def _cmd_store(args) -> int:
    from repro.service import default_store

    store = default_store()
    if store is None:
        print("result store disabled (CIM_TUNER_DISABLE_RESULT_STORE)")
        return 0
    if args.clear:
        print(f"cleared {store.clear()} records from {store.root}")
        return 0
    keys = store.keys()
    print(f"store root : {store.root}")
    print(f"records    : {len(keys)}")
    for k in keys[:20]:
        print(f"  {k}")
    if len(keys) > 20:
        print(f"  ... {len(keys) - 20} more")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-service",
        description="Async DSE service over the batched exploration engine")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("explore", help="run a JSON job file")
    ex.add_argument("jobs_file")
    ex.add_argument("--stream", action="store_true",
                    help="print each result as its bucket finishes")
    ex.add_argument("--json", action="store_true",
                    help="machine-readable JSONL output")
    ex.add_argument("--no-store", action="store_true",
                    help="bypass the persistent result store")
    ex.add_argument("--search", default=None, metavar="BACKEND",
                    help="override every spec's search backend (sa, "
                         "genetic, evolution, sobol, portfolio, "
                         "exhaustive)")
    ex.add_argument("--search-settings", default=None, metavar="JSON",
                    help="JSON dict merged over every spec's backend "
                         "settings, e.g. "
                         "'{\"total_evals\": 8000, \"allocator\": "
                         "\"bandit\"}'")
    ex.add_argument("--url", default=None, metavar="URL",
                    help="submit to a running `repro-service serve` "
                         "instance (default: $CIM_TUNER_SERVICE_URL, "
                         "else in-process)")
    ex.set_defaults(fn=_cmd_explore)

    sv = sub.add_parser("serve",
                        help="run the multi-process HTTP front door")
    sv.add_argument("--host", default="127.0.0.1")
    sv.add_argument("--port", type=int, default=8731,
                    help="0 binds an ephemeral port (printed on startup)")
    sv.add_argument("--port-file", default=None, metavar="PATH",
                    help="write the bound port here (CI scripting)")
    sv.add_argument("--no-store", action="store_true",
                    help="serve without a persistent result store")
    sv.add_argument("--verbose", action="store_true",
                    help="per-request access logging on stderr")
    sv.set_defaults(fn=_cmd_serve)

    st = sub.add_parser("stats", help="print service counters as JSON")
    st.add_argument("--url", default=None, metavar="URL",
                    help="query a remote serve instance "
                         "(default: $CIM_TUNER_SERVICE_URL)")
    st.set_defaults(fn=_cmd_stats)

    so = sub.add_parser("store", help="inspect / clear the result store")
    so.add_argument("--info", action="store_true", default=True)
    so.add_argument("--clear", action="store_true")
    so.set_defaults(fn=_cmd_store)

    tr = sub.add_parser(
        "trace", help="export the span trace buffer "
                      "(Chrome trace_event / JSONL)")
    tr.add_argument("--input", default=None, metavar="FILE",
                    help="JSONL trace file written via CIM_TUNER_TRACE "
                         "(default: $CIM_TUNER_TRACE)")
    tr.add_argument("--url", default=None, metavar="URL",
                    help="fetch the live ring buffer from a running "
                         "serve instance (GET /v1/trace)")
    tr.add_argument("--export", choices=("chrome", "jsonl"),
                    default="chrome",
                    help="chrome: Perfetto-loadable trace.json (default); "
                         "jsonl: raw span lines")
    tr.add_argument("-o", "--output", default=None, metavar="PATH",
                    help="output file (chrome default: trace.json; "
                         "jsonl default: stdout)")
    tr.set_defaults(fn=_cmd_trace)

    tl = sub.add_parser(
        "timeline", help="render one job's search decision timeline "
                         "(regret-vs-budget curve + convergence summary)")
    tl.add_argument("key", help="canonical job key")
    tl.add_argument("--url", default=None, metavar="URL",
                    help="fetch GET /v1/jobs/<key>/timeline from a "
                         "running serve instance (default: "
                         "$CIM_TUNER_SERVICE_URL, else the local store)")
    tl.add_argument("--json", action="store_true",
                    help="print the raw timeline record instead of the "
                         "rendered view")
    tl.set_defaults(fn=_cmd_timeline)

    pr = sub.add_parser(
        "profile", help="run the kernel micro-profile pass "
                        "(cim_kernel_us / roofline utilization)")
    pr.add_argument("--kernels", default=None, metavar="A,B",
                    help="comma-separated kernel subset (default: all of "
                         "cim_matmul, flash_attention, selective_scan, "
                         "strategy_eval)")
    pr.add_argument("--repeats", type=int, default=3,
                    help="profiled calls per kernel (default 3)")
    pr.add_argument("--json", action="store_true",
                    help="machine-readable summary rows")
    pr.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="also dump the Prometheus exposition here")
    pr.set_defaults(fn=_cmd_profile)

    ca = sub.add_parser(
        "calibrate", help="fit measured-kernel correction factors and "
                          "write a calibration artifact")
    ca.add_argument("--kernels", default=None, metavar="A,B",
                    help="comma-separated kernel subset to microbench "
                         "(default: all)")
    ca.add_argument("--repeats", type=int, default=3,
                    help="timed calls per kernel/tiling case (default 3)")
    ca.add_argument("--seed", type=int, default=0,
                    help="seed for microbench inputs and the held-out "
                         "split (default 0)")
    ca.add_argument("--input", default=None, metavar="PATH",
                    help="refit from the measurements stored in an "
                         "existing artifact instead of re-running the "
                         "microbench")
    ca.add_argument("--holdout", type=float, default=0.25,
                    help="held-out fraction for the fit report "
                         "(default 0.25)")
    ca.add_argument("-o", "--output", default=None, metavar="PATH",
                    help="write the calibration artifact here (pin it "
                         "via CIM_TUNER_CALIBRATION)")
    ca.add_argument("--json", action="store_true",
                    help="machine-readable report")
    ca.set_defaults(fn=_cmd_calibrate)

    args = ap.parse_args(argv)
    from repro.obs import configure_logging
    configure_logging()                    # honour CIM_TUNER_LOG in CLIs
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
