"""CLI front door of the async DSE service.

    python -m repro.service explore jobs.json --stream
    python -m repro.service explore jobs.json --json
    python -m repro.service store --info
    python -m repro.service store --clear

``jobs.json`` is a list of job specs (see
:func:`repro.service.client.job_from_spec`)::

    [{"macro": "vanilla-dcim", "workload": "bert-large",
      "area_budget_mm2": 5.0, "objective": "ee", "search": "exhaustive"},
     {"macro": "tpdcim-macro", "workload": {"name": "yi-6b", "seq": 512},
      "area_budget_mm2": 2.23, "objective": "th", "search": "portfolio"}]

Each spec's ``"search"`` key picks the optimizer per job: any registered
``repro.search`` backend ("sa", "genetic", "evolution", "sobol",
"portfolio") or "exhaustive"; ``explore --search NAME`` overrides every
spec in the file.  With ``--stream`` each result line prints the moment
its micro-batch bucket finishes (completion order); without it, results
print in submission order once all are done.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _cmd_explore(args) -> int:
    from repro.service import ServiceClient, serialize_result

    with open(args.jobs_file) as f:
        specs = json.load(f)
    if not isinstance(specs, list) or not specs:
        print("error: jobs file must be a non-empty JSON list",
              file=sys.stderr)
        return 2
    if args.search:
        specs = [{**spec, "search": args.search} for spec in specs]
    # validate every spec (including the --search override) up front, so
    # a typo'd backend name fails fast with a clean error, not a traceback
    # out of the running service
    from repro.service import job_from_spec
    try:
        for spec in specs:
            job_from_spec(spec)
    except (KeyError, TypeError, ValueError) as exc:
        print(f"error: bad job spec: {exc}", file=sys.stderr)
        return 2

    svc = ServiceClient(store=None if args.no_store else "auto")
    t0 = time.perf_counter()

    def emit(i, result):
        dt = time.perf_counter() - t0
        if args.json:
            rec = {"index": i, "elapsed_s": round(dt, 3),
                   "source": "store" if result.search.get("cache") == "store"
                   else "engine",
                   "result": serialize_result(result)}
            print(json.dumps(rec), flush=True)
        else:
            src = " [cached]" if result.search.get("cache") == "store" else ""
            print(f"[{dt:7.2f}s] #{i} {result.summary()}{src}", flush=True)

    try:
        if args.stream:
            for i, result in svc.explore_specs(specs, stream=True):
                emit(i, result)
        else:
            for i, result in enumerate(svc.explore_specs(specs)):
                emit(i, result)
    finally:
        svc.close()
    if not args.json:
        print(f"# {len(specs)} jobs in {time.perf_counter()-t0:.2f}s "
              f"(stats: {svc.stats})", flush=True)
    return 0


def _cmd_store(args) -> int:
    from repro.service import default_store

    store = default_store()
    if store is None:
        print("result store disabled (CIM_TUNER_DISABLE_RESULT_STORE)")
        return 0
    if args.clear:
        print(f"cleared {store.clear()} records from {store.root}")
        return 0
    keys = store.keys()
    print(f"store root : {store.root}")
    print(f"records    : {len(keys)}")
    for k in keys[:20]:
        print(f"  {k}")
    if len(keys) > 20:
        print(f"  ... {len(keys) - 20} more")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-service",
        description="Async DSE service over the batched exploration engine")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("explore", help="run a JSON job file")
    ex.add_argument("jobs_file")
    ex.add_argument("--stream", action="store_true",
                    help="print each result as its bucket finishes")
    ex.add_argument("--json", action="store_true",
                    help="machine-readable JSONL output")
    ex.add_argument("--no-store", action="store_true",
                    help="bypass the persistent result store")
    ex.add_argument("--search", default=None, metavar="BACKEND",
                    help="override every spec's search backend (sa, "
                         "genetic, evolution, sobol, portfolio, "
                         "exhaustive)")
    ex.set_defaults(fn=_cmd_explore)

    st = sub.add_parser("store", help="inspect / clear the result store")
    st.add_argument("--info", action="store_true", default=True)
    st.add_argument("--clear", action="store_true")
    st.set_defaults(fn=_cmd_store)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
