"""Fault-tolerant checkpointing.

Design goals (scaled for 1000+ nodes, exercised here on CPU):
  * atomic: a step directory is written under ``<step>.tmp`` and renamed
    only after every leaf + manifest landed -- a crash mid-write can never
    corrupt the latest checkpoint;
  * mesh-agnostic: leaves are stored as full (unsharded) arrays keyed by
    pytree path, so a restart may use a different mesh/device count -- the
    loader re-shards via ``jax.device_put`` with the new sharding tree
    (elastic restart);
  * self-describing: ``manifest.json`` carries step, leaf paths, shapes and
    dtypes for validation before any array is touched;
  * bounded retention: ``keep`` newest checkpoints are retained.

On a real multi-host pod each host would write only its addressable shards
(per-shard files + a global manifest); the single-process layout here keeps
the same API surface.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree) -> str:
        flat = _flatten(tree)
        tmp = os.path.join(self.dir, f"step_{step:09d}.tmp")
        final = os.path.join(self.dir, f"step_{step:09d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None):
        """Restore into the structure of ``tree_like`` (arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic re-sharding on load."""
        steps = self._steps()
        if not steps:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        step = steps[-1] if step is None else step
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        flat_like = _flatten(tree_like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        missing = set(flat_like) - set(manifest["leaves"])
        if missing:
            raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")

        restored = {}
        for key, spec in flat_like.items():
            meta = manifest["leaves"][key]
            if list(spec.shape) != meta["shape"]:
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {meta['shape']} "
                    f"vs expected {list(spec.shape)}")
            arr = np.load(os.path.join(d, meta["file"]))
            sh = flat_sh.get(key)
            restored[key] = jax.device_put(arr, sh) if sh is not None \
                else jax.numpy.asarray(arr)

        # unflatten back into the original structure
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in paths]
        leaves = [restored[k] for k in keys]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree_like), leaves), step

    # ------------------------------------------------------------------ #
    def _steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
