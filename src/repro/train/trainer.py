"""Production training loop: jit'd sharded step, checkpoint/restart, NaN
guard, straggler telemetry, elastic resume.

Fault-tolerance model (designed for 1000+ nodes, exercised on CPU):
  * checkpoint every ``ckpt_every`` steps through the atomic
    CheckpointManager; on (re)start the trainer restores the newest
    checkpoint -- a preempted/failed node set simply relaunches the same
    command (the data pipeline is stateless-by-step so batches resume
    bit-exact);
  * elastic: the restore path re-shards to whatever mesh the relaunch has;
  * NaN guard: a step whose grad-norm is non-finite is *skipped* (params
    and optimizer state keep their donated identity) -- a single corrupt
    host batch cannot poison the run;
  * straggler telemetry: per-step wall times keep an EWMA and a p95
    estimate; steps slower than ``straggler_factor`` x EWMA are counted and
    logged -- on a real cluster this signal feeds the preemption/hot-spare
    controller.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, SyntheticLMStream, make_batch_iterator
from repro.models import sharding as sh
from repro.models.model import build_model
from repro.optim import AdamW, AdamWConfig
from repro.train.checkpoint import CheckpointManager


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    ckpt_keep: int = 3
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 2.0
    optimizer: AdamWConfig = AdamWConfig()


def _nan_guarded(step_fn):
    """Skip the update when the grad norm is non-finite."""
    def guarded(params, opt_state, batch):
        new_params, new_opt, metrics = step_fn(params, opt_state, batch)
        ok = jnp.isfinite(metrics["grad_norm"])
        sel = lambda a, b: jax.tree.map(
            lambda x, y: jnp.where(ok, x, y), a, b)
        metrics = dict(metrics, skipped=jnp.logical_not(ok))
        return sel(new_params, params), sel(new_opt, opt_state), metrics
    return guarded


class Trainer:
    def __init__(self, cfg: ArchConfig, tcfg: TrainerConfig, mesh: Mesh,
                 stream=None):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.model = build_model(cfg, shard_act=sh.make_shard_act(mesh))
        self.optimizer = AdamW(tcfg.optimizer)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.stream = stream or SyntheticLMStream(DataConfig(
            seq_len=tcfg.seq_len, global_batch=tcfg.global_batch,
            vocab=cfg.vocab, seed=tcfg.seed,
            memory_tokens=cfg.n_memory, d_model=cfg.d_model))

        a_params = self.model.abstract_params(tcfg.seed)
        self.p_sh = sh.param_shardings(cfg, a_params, mesh)
        a_opt = jax.eval_shape(self.optimizer.init, a_params)
        self.o_sh = sh.tree_shardings(
            a_opt, mesh, lambda n, s: sh.param_rule(cfg, n, s, mesh))

        from repro.launch.steps import make_train_step
        rep = NamedSharding(mesh, P())
        self.step_fn = jax.jit(
            _nan_guarded(make_train_step(self.model, self.optimizer)),
            in_shardings=(self.p_sh, self.o_sh, None),
            out_shardings=(self.p_sh, self.o_sh, rep),
            donate_argnums=(0, 1),
        )
        self.history: list[dict] = []
        self.straggler_steps = 0

    # ------------------------------------------------------------------ #
    def init_state(self):
        params = jax.jit(
            self.model.init, out_shardings=self.p_sh
        )(jax.random.PRNGKey(self.tcfg.seed))
        opt = jax.jit(self.optimizer.init, out_shardings=self.o_sh)(params)
        return params, opt, 0

    def restore_or_init(self):
        if self.ckpt.latest_step() is not None:
            a_params = self.model.abstract_params(self.tcfg.seed)
            a_opt = jax.eval_shape(self.optimizer.init, a_params)
            (params, opt), step = self.ckpt.restore(
                (a_params, a_opt),
                shardings=(self.p_sh, self.o_sh))
            return params, opt, step
        return self.init_state()

    # ------------------------------------------------------------------ #
    def train(self, log: Callable[[str], None] = print):
        tc = self.tcfg
        params, opt, start = self.restore_or_init()
        it = make_batch_iterator(self.stream, self.mesh, start_step=start)
        ewma = None
        try:
            for step in range(start, tc.steps):
                batch = next(it)
                t0 = time.perf_counter()
                params, opt, metrics = self.step_fn(params, opt, batch)
                loss = float(metrics["loss"])   # blocks; CPU-scale is fine
                dt = time.perf_counter() - t0
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
                if dt > tc.straggler_factor * ewma and step > start + 3:
                    self.straggler_steps += 1
                rec = {"step": step + 1, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "lr": float(metrics["lr"]),
                       "skipped": bool(metrics["skipped"]),
                       "sec_per_step": dt}
                self.history.append(rec)
                if (step + 1) % tc.log_every == 0 or step == start:
                    log(f"step {rec['step']:5d} loss {loss:8.4f} "
                        f"gnorm {rec['grad_norm']:8.3f} lr {rec['lr']:.2e} "
                        f"{dt*1e3:7.1f} ms"
                        + (" [SKIPPED:nan]" if rec["skipped"] else ""))
                if (step + 1) % tc.ckpt_every == 0 or step + 1 == tc.steps:
                    path = self.ckpt.save(step + 1, (params, opt))
                    log(f"checkpoint @ {path}")
        finally:
            # close the generator so its producer thread stops now --
            # leaked producers otherwise keep allocating batches forever
            it.close()
        return params, opt
