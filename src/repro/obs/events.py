"""Per-job-key progress bus feeding SSE ``progress`` events.

The portfolio racer publishes one event per rung / bandit wave
(allocator, backend, pulls, best-so-far, device); the server's
``/v1/stream`` handler subscribes per requested job key and interleaves
``event: progress`` lines with the final ``event: result`` -- a client
watches the race converge instead of only seeing the winner.

Publishing is fire-and-forget from the engine's perspective; each key
keeps a small bounded history so a subscriber that attaches *after* the
rung fired (POST then GET /v1/stream is two round-trips) still replays
what it missed.  ``subscribe`` registers the live sink and returns the
history snapshot under one lock: no event is lost or duplicated between
replay and live delivery.
"""
from __future__ import annotations

import collections
import threading
import typing

__all__ = ["ProgressBus", "progress_bus"]

_HISTORY_PER_KEY = 64
_MAX_KEYS = 1024


class ProgressBus:
    """Bounded per-key pub/sub with atomic history-replay subscribe."""

    def __init__(self, history_per_key: int = _HISTORY_PER_KEY,
                 max_keys: int = _MAX_KEYS):
        self._history_per_key = history_per_key
        self._max_keys = max_keys
        self._lock = threading.Lock()
        # key -> deque of events, LRU-ordered for key eviction
        self._history: collections.OrderedDict[str, collections.deque] = \
            collections.OrderedDict()
        self._seq: dict[str, int] = {}
        # sink -> frozenset of keys it wants
        self._sinks: dict[typing.Callable[[str, dict], None],
                          frozenset] = {}

    def publish(self, key: str, **fields) -> dict:
        """Record an event for ``key`` and push it to live sinks.

        Adds a per-key monotonic ``seq`` so clients can detect the
        replay/live boundary; returns the event dict.
        """
        with self._lock:
            seq = self._seq.get(key, 0)
            self._seq[key] = seq + 1
            ev = {"key": key, "seq": seq, **fields}
            dq = self._history.get(key)
            if dq is None:
                dq = collections.deque(maxlen=self._history_per_key)
                self._history[key] = dq
                while len(self._history) > self._max_keys:
                    old, _ = self._history.popitem(last=False)
                    self._seq.pop(old, None)
            else:
                self._history.move_to_end(key)
            dq.append(ev)
            sinks = [s for s, keys in self._sinks.items() if key in keys]
        for sink in sinks:      # outside the lock: sinks may block
            try:
                sink(key, ev)
            except Exception:
                pass            # a dead subscriber must not stall the race
        return ev

    def subscribe(self, keys: typing.Iterable[str],
                  sink: typing.Callable[[str, dict], None],
                  ) -> list[dict]:
        """Register ``sink`` for ``keys`` and return the missed history.

        Registration and the history snapshot happen under one lock, so
        replaying the returned events then consuming live sink calls
        yields every event exactly once, in order.
        """
        keyset = frozenset(keys)
        with self._lock:
            self._sinks[sink] = keyset
            history: list[dict] = []
            for key in keyset:
                dq = self._history.get(key)
                if dq:
                    history.extend(dq)
            history.sort(key=lambda ev: (ev["key"], ev["seq"]))
            return history

    def unsubscribe(self, sink) -> None:
        """Detach a sink (idempotent)."""
        with self._lock:
            self._sinks.pop(sink, None)


# --------------------------------------------------------------------- #
_BUS = ProgressBus()


def progress_bus() -> ProgressBus:
    """The process-wide :class:`ProgressBus` shared by engine and
    server."""
    return _BUS
