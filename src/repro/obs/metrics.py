"""Process-wide metrics registry: counters, gauges, histograms.

This is the unified telemetry core the whole DSE stack reports into --
engine, queue, store tiers and the HTTP front door all bump children of
one process-wide :class:`Registry` (:func:`registry`) instead of the four
hand-rolled counter dicts (each behind its own lock) they grew over PRs
2-5.  Stdlib only, matching the service's no-new-dependencies rule.

Three instrument types, all label-aware and thread-safe:

``Counter``
    Monotonic float; ``inc(amount, **labels)``.
``Gauge``
    Settable float; ``set`` / ``inc`` / ``dec``.
``Histogram``
    Fixed cumulative buckets plus ``_sum`` / ``_count`` (Prometheus
    histogram semantics); ``observe(value, **labels)``.

Exports: :meth:`Registry.render` emits the Prometheus text exposition
format (what ``GET /v1/metrics`` serves), :meth:`Registry.snapshot` a flat
JSON-able dict (what ``benchmarks/run.py`` embeds in ``results.jsonl``).

:class:`StatCounters` is the migration bridge: a read-only-``Mapping``
facade with the exact shape of the legacy per-instance ``stats`` dicts
(``stats["submitted"]`` reads, ``dict(stats)`` snapshots, ``/v1/stats``
JSON unchanged) whose ``bump`` increments both the per-instance value and
the process-wide registry family behind one audited lock.
"""
from __future__ import annotations

import json
import math
import os
import re
import threading
import time
import typing

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "StatCounters",
    "registry",
    "exemplars_enabled",
    "DEFAULT_BUCKETS",
]

#: when truthy, ``Histogram.render_into`` appends each bucket's last
#: exemplar in OpenMetrics syntax (``... # {span_id="..."} value ts``);
#: off by default so the exposition stays strict text-format 0.0.4
_EXEMPLARS_ENV = "CIM_TUNER_EXEMPLARS"


def exemplars_enabled() -> bool:
    """Whether ``CIM_TUNER_EXEMPLARS`` asks for OpenMetrics exemplar
    suffixes on histogram bucket lines."""
    return os.environ.get(_EXEMPLARS_ENV, "") not in ("", "0", "false",
                                                      "no")

#: default latency buckets (seconds): sub-ms HTTP handling up to multi-
#: second cold compiles; +Inf is implicit
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")


def _escape(value: str) -> str:
    """Prometheus label-value escaping (backslash, quote, newline)."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(v: float) -> str:
    """Sample-value formatting: integers render bare, floats via repr."""
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Family:
    """Shared base: one named metric family holding labeled children."""

    kind = "untyped"

    def __init__(self, name: str, help: str,  # noqa: A002 -- prometheus term
                 labelnames: tuple[str, ...] = ()):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"bad label name {ln!r}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._children: dict[tuple[str, ...], typing.Any] = {}

    def _child_values(self) -> typing.Any:
        raise NotImplementedError

    def _key(self, labels: dict) -> tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name} expects labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def labels(self, **labels):
        """The child for one label-value combination (created on first
        use); with no labelnames there is a single anonymous child."""
        key = self._key(labels)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._child_values()
            return child

    def samples(self) -> list[tuple[tuple[str, ...], typing.Any]]:
        """``(label-values, child)`` pairs, insertion-ordered."""
        with self._lock:
            return list(self._children.items())

    def _label_str(self, values: tuple[str, ...],
                   extra: tuple[tuple[str, str], ...] = ()) -> str:
        pairs = [f'{ln}="{_escape(v)}"'
                 for ln, v in zip(self.labelnames, values)]
        pairs += [f'{ln}="{_escape(v)}"' for ln, v in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""


class _Value:
    """One float cell behind its own lock (counter/gauge child)."""

    __slots__ = ("_v", "_lock")

    def __init__(self) -> None:
        self._v = 0.0
        self._lock = threading.Lock()

    @property
    def value(self) -> float:
        with self._lock:
            return self._v

    def add(self, amount: float) -> None:
        with self._lock:
            self._v += amount

    def set(self, value: float) -> None:
        with self._lock:
            self._v = float(value)


class Counter(_Family):
    """Monotonically increasing metric family."""

    kind = "counter"

    def _child_values(self) -> _Value:
        return _Value()

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Increment by ``amount`` (must be >= 0) for the given labels."""
        if amount < 0:
            raise ValueError("counters only go up")
        self.labels(**labels).add(amount)

    def value(self, **labels) -> float:
        """Current value of one child (0.0 if never incremented)."""
        return self.labels(**labels).value

    def render_into(self, out: list[str]) -> None:
        for values, child in self.samples():
            out.append(f"{self.name}{self._label_str(values)} "
                       f"{_fmt(child.value)}")


class Gauge(_Family):
    """Settable point-in-time metric family."""

    kind = "gauge"

    def _child_values(self) -> _Value:
        return _Value()

    def set(self, value: float, **labels) -> None:
        """Set the child to ``value``."""
        self.labels(**labels).set(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        """Add ``amount`` (may be negative) to the child."""
        self.labels(**labels).add(amount)

    def dec(self, amount: float = 1.0, **labels) -> None:
        """Subtract ``amount`` from the child."""
        self.labels(**labels).add(-amount)

    def value(self, **labels) -> float:
        """Current value of one child."""
        return self.labels(**labels).value

    def render_into(self, out: list[str]) -> None:
        for values, child in self.samples():
            out.append(f"{self.name}{self._label_str(values)} "
                       f"{_fmt(child.value)}")


class _HistChild:
    """Bucket counts + sum + count for one label combination.

    ``exemplars`` holds, per non-cumulative bucket, the most recent
    ``(labels, value, unix_ts)`` exemplar handed to :meth:`observe`
    (typically ``{"span_id": ...}`` from ``obs.span``) -- rendered as
    OpenMetrics suffixes when :func:`exemplars_enabled`."""

    __slots__ = ("buckets", "counts", "sum", "count", "exemplars",
                 "_lock")

    def __init__(self, buckets: tuple[float, ...]):
        self.buckets = buckets
        self.counts = [0] * (len(buckets) + 1)   # +1 for +Inf
        self.exemplars: list[tuple[dict, float, float] | None] = \
            [None] * (len(buckets) + 1)
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, value: float, exemplar: dict | None = None) -> None:
        value = float(value)
        with self._lock:
            self.sum += value
            self.count += 1
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    break
            else:
                i = len(self.buckets)
            self.counts[i] += 1
            if exemplar:
                self.exemplars[i] = (dict(exemplar), value, time.time())

    def exemplars_snapshot(self) -> list:
        with self._lock:
            return list(self.exemplars)

    def cumulative(self) -> list[int]:
        with self._lock:
            out, acc = [], 0
            for c in self.counts:
                acc += c
                out.append(acc)
            return out

    def snapshot(self) -> tuple[float, int]:
        with self._lock:
            return self.sum, self.count


class Histogram(_Family):
    """Fixed-bucket cumulative histogram family (latency distributions)."""

    kind = "histogram"

    def __init__(self, name, help, labelnames=(),  # noqa: A002
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError("histogram needs at least one bucket")
        self.buckets = b

    def _child_values(self) -> _HistChild:
        return _HistChild(self.buckets)

    def observe(self, value: float, exemplar: dict | None = None,
                **labels) -> None:
        """Record one observation for the given labels; ``exemplar`` is
        an optional dict of exemplar labels (e.g. ``{"span_id": ...}``)
        remembered as the bucket's latest exemplar."""
        self.labels(**labels).observe(value, exemplar=exemplar)

    def render_into(self, out: list[str]) -> None:
        show_ex = exemplars_enabled()
        for values, child in self.samples():
            cum = child.cumulative()
            exs = child.exemplars_snapshot() if show_ex \
                else [None] * len(cum)
            for i, ub in enumerate((*self.buckets, math.inf)):
                line = (f"{self.name}_bucket"
                        f"{self._label_str(values, (('le', _fmt(ub)),))} "
                        f"{cum[i]}")
                if exs[i] is not None:
                    ex_labels, ex_value, ex_ts = exs[i]
                    pairs = ",".join(f'{k}="{_escape(v)}"'
                                     for k, v in ex_labels.items())
                    line += (f" # {{{pairs}}} {_fmt(ex_value)} "
                             f"{ex_ts:.3f}")
                out.append(line)
            s, n = child.snapshot()
            out.append(f"{self.name}_sum{self._label_str(values)} {_fmt(s)}")
            out.append(f"{self.name}_count{self._label_str(values)} {n}")


class Registry:
    """A namespace of metric families; see :func:`registry` for the
    process-wide instance every subsystem reports into.

    Family constructors are idempotent: asking for an existing name with
    the same type/labelnames returns the existing family (so modules can
    declare their instruments at import time without double-registration
    hazards); a mismatch raises.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._families: dict[str, _Family] = {}

    def _get_or_make(self, cls, name, help, labelnames, **kw):  # noqa: A002
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if type(fam) is not cls or \
                        fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind} with labels {fam.labelnames}")
                return fam
            fam = cls(name, help, tuple(labelnames), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str,  # noqa: A002
                labelnames: tuple[str, ...] = ()) -> Counter:
        """Get-or-create a :class:`Counter` family."""
        return self._get_or_make(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str,  # noqa: A002
              labelnames: tuple[str, ...] = ()) -> Gauge:
        """Get-or-create a :class:`Gauge` family."""
        return self._get_or_make(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str,  # noqa: A002
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        """Get-or-create a :class:`Histogram` family."""
        return self._get_or_make(Histogram, name, help, labelnames,
                                 buckets=buckets)

    def families(self) -> list[_Family]:
        """Every registered family, registration-ordered."""
        with self._lock:
            return list(self._families.values())

    def render(self) -> str:
        """The Prometheus text exposition format (``text/plain;
        version=0.0.4``) of every family -- what ``GET /v1/metrics``
        serves."""
        out: list[str] = []
        for fam in self.families():
            out.append(f"# HELP {fam.name} {fam.help}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            fam.render_into(out)
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict[str, float]:
        """Flat JSON-able view: ``{"name{label=\\"v\\"}": value}``.

        Histograms contribute their ``_sum`` and ``_count`` series only
        (the bucket vector is scrape detail, not trend signal) -- this is
        the record ``benchmarks/run.py`` embeds per module in
        ``results.jsonl``."""
        out: dict[str, float] = {}
        for fam in self.families():
            for values, child in fam.samples():
                label_s = fam._label_str(values)
                if isinstance(fam, Histogram):
                    s, n = child.snapshot()
                    out[f"{fam.name}_sum{label_s}"] = s
                    out[f"{fam.name}_count{label_s}"] = float(n)
                else:
                    out[f"{fam.name}{label_s}"] = child.value
        return out


class StatCounters(typing.Mapping):
    """Legacy-shaped per-instance counters, mirrored into the registry.

    Drop-in replacement for the hand-rolled ``self.stats`` dicts of the
    queue / store / engine / server: reads (``stats["submitted"]``,
    ``dict(stats)``, iteration) behave exactly like the old dict so the
    ``/v1/stats`` JSON shape and every existing assertion are unchanged,
    while writes go through :meth:`bump`, which updates the per-instance
    value AND the mapped process-wide registry child under one lock --
    the single audited locking scheme replacing the three independent
    ones.

    ``mirror`` maps each legacy key to a registry child (a
    ``family.labels(...)`` handle) or ``None`` for keys that stay
    instance-local.
    """

    def __init__(self, mirror: dict[str, typing.Any]):
        self._mirror = dict(mirror)
        self._vals = dict.fromkeys(mirror, 0)
        self._lock = threading.Lock()

    def bump(self, key: str, n: int = 1) -> None:
        """Add ``n`` to ``key`` locally and in the mirrored registry
        child (registry mirrors are counters: negative local corrections
        are applied locally only)."""
        with self._lock:
            self._vals[key] += n
        child = self._mirror[key]
        if child is not None and n > 0:
            child.add(n)

    # Mapping protocol: the legacy read surface ----------------------- #
    def __getitem__(self, key: str) -> int:
        with self._lock:
            return self._vals[key]

    def __iter__(self):
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:          # legacy dicts printed in CLIs
        with self._lock:
            return repr(dict(self._vals))

    def snapshot(self) -> dict[str, int]:
        """Plain-dict copy (one lock acquisition, no torn multi-key
        reads)."""
        with self._lock:
            return dict(self._vals)


# --------------------------------------------------------------------- #
# the process-wide registry
# --------------------------------------------------------------------- #
_REGISTRY = Registry()


def registry() -> Registry:
    """The process-wide :class:`Registry` every repro subsystem reports
    into; ``GET /v1/metrics`` renders it."""
    return _REGISTRY


def render_json(reg: Registry | None = None) -> str:
    """JSON spelling of :meth:`Registry.snapshot` (debug helper)."""
    return json.dumps((reg or _REGISTRY).snapshot(), indent=2,
                      sort_keys=True)
