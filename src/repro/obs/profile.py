"""Kernel profiling tier: wall-clock histograms + roofline utilization.

Profiling hooks around the Pallas kernel wrappers (``repro.kernels.ops``)
record, per ``(kernel, bucket)`` series:

``cim_kernel_us`` (histogram)
    Wall-clock per call in microseconds (``block_until_ready`` timed), with
    the producing span's id as the bucket exemplar -- a latency outlier in
    ``/v1/metrics`` links to its span in ``/v1/trace``.
``cim_kernel_flops_per_call`` / ``cim_kernel_bytes_per_call`` (gauges)
    XLA's compiled cost analysis (via
    :func:`repro.compat.compiled_cost_analysis`), computed once per series.
``cim_kernel_roofline_utilization`` (gauge)
    Achieved FLOP/s over the roofline-attainable rate
    ``min(peak_flops, peak_bw * arithmetic_intensity)`` -- the measurement
    substrate the ROADMAP calibration tier fits correction factors from.

Everything is gated on ``CIM_TUNER_PROFILE`` (checked per call, so the
hooks cost one env lookup when off).  Peak rates default to the TPU v5e
constants shared with ``repro.launch.roofline`` and can be overridden via
``CIM_TUNER_PEAK_FLOPS`` / ``CIM_TUNER_PEAK_BW`` (interpret-mode CPU runs
report honest-but-tiny utilizations against TPU peaks).

This module is a STABLE PUBLIC SURFACE (re-exported from ``repro.obs``):
:func:`run_microbench` is the measurement half of the calibration tier --
it times the real Pallas kernels over a small tiling sweep and returns
:class:`MeasurementRecord` dicts with the documented schema

    {"kernel": str,   # cim_matmul | flash_attention | selective_scan
                      # | strategy_eval
     "bucket": str,   # shape bucket, e.g. "128x128x128"
     "tiling": str,   # tiling variant, e.g. "AF", "bq64xbk64", "ct16xci16"
     "us":     float, # one call's wall clock, microseconds
     "flops":  float | None,   # compiled cost analysis (None: unavailable)
     "bytes":  float | None,
     "seed":   int}   # RNG seed the inputs were drawn from

which ``repro.core.calibration.fit_corrections`` consumes.  Names with a
leading underscore (``_cost_analysis``, ``_env_float``, ...) are
implementation details and may change without notice.
"""
from __future__ import annotations

import os
import threading
import time
import typing

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = [
    "PROFILE_ENV",
    "KERNEL_US_BUCKETS",
    "MeasurementRecord",
    "profiling_enabled",
    "instrument",
    "roofline_utilization",
    "peak_flops",
    "peak_bw",
    "summary",
    "run_microbench",
    "record_measurements",
    "take_measurements",
]

PROFILE_ENV = "CIM_TUNER_PROFILE"

#: per-call kernel wall clock is microseconds, not seconds -- interpret
#: mode on CPU reaches well into the ms range, compiled TPU kernels sit
#: in the single-digit us range
KERNEL_US_BUCKETS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                     500.0, 1e3, 2.5e3, 5e3, 1e4, 2.5e4, 1e5, 2.5e5, 1e6)

#: defaults mirror repro.launch.roofline (TPU v5e: bf16 FLOP/s per chip,
#: HBM bandwidth)
DEFAULT_PEAK_FLOPS = 197e12
DEFAULT_PEAK_BW = 819e9

_REG = _metrics.registry()
_M_US = _REG.histogram(
    "cim_kernel_us", "Per-call kernel wall clock (microseconds)",
    ("kernel", "bucket"), buckets=KERNEL_US_BUCKETS)
_M_FLOPS = _REG.gauge(
    "cim_kernel_flops_per_call",
    "Compiled cost analysis: FLOPs per kernel call", ("kernel", "bucket"))
_M_BYTES = _REG.gauge(
    "cim_kernel_bytes_per_call",
    "Compiled cost analysis: bytes accessed per kernel call",
    ("kernel", "bucket"))
_M_ROOF = _REG.gauge(
    "cim_kernel_roofline_utilization",
    "Achieved FLOP/s over the roofline-attainable rate",
    ("kernel", "bucket"))
_M_RUNTIME = _REG.gauge(
    "cim_kernel_profile_runtime_seconds",
    "Wall clock of the last kernel micro-profile pass")

#: one cost analysis per (kernel, bucket); None caches failures so a
#: non-lowerable callable is probed once, not per call
_COST_CACHE: dict[tuple[str, str], tuple[float, float] | None] = {}
_COST_LOCK = threading.Lock()


def profiling_enabled() -> bool:
    """Whether ``CIM_TUNER_PROFILE`` turns the kernel hooks on."""
    return os.environ.get(PROFILE_ENV, "") not in ("", "0", "false", "no")


def _env_float(var: str, default: float) -> float:
    raw = os.environ.get(var)
    try:
        return float(raw) if raw else default
    except ValueError:
        return default


def peak_flops() -> float:
    """Peak FLOP/s the roofline is drawn against
    (``CIM_TUNER_PEAK_FLOPS``, default TPU v5e bf16)."""
    return _env_float("CIM_TUNER_PEAK_FLOPS", DEFAULT_PEAK_FLOPS)


def peak_bw() -> float:
    """Peak memory bandwidth in bytes/s (``CIM_TUNER_PEAK_BW``, default
    TPU v5e HBM)."""
    return _env_float("CIM_TUNER_PEAK_BW", DEFAULT_PEAK_BW)


def roofline_utilization(flops: float, nbytes: float,
                         seconds: float) -> float:
    """Achieved FLOP/s over the roofline-attainable rate for one call.

    Attainable is ``min(peak_flops, peak_bw * intensity)`` with
    ``intensity = flops / nbytes``; zero-byte kernels are compute-bound
    by definition."""
    if seconds <= 0 or flops <= 0:
        return 0.0
    achieved = flops / seconds
    if nbytes > 0:
        attainable = min(peak_flops(), peak_bw() * (flops / nbytes))
    else:
        attainable = peak_flops()
    return achieved / attainable if attainable > 0 else 0.0


def _cost_analysis(kernel: str, bucket: str, fn, args,
                   kwargs) -> tuple[float, float] | None:
    """(flops, bytes accessed) of one jitted call, cached per series."""
    key = (kernel, bucket)
    with _COST_LOCK:
        if key in _COST_CACHE:
            return _COST_CACHE[key]
    result = None
    lower = getattr(fn, "lower", None)
    if callable(lower):
        try:
            from repro.compat import compiled_cost_analysis
            ca = compiled_cost_analysis(lower(*args, **kwargs).compile())
            result = (float(ca.get("flops", 0.0) or 0.0),
                      float(ca.get("bytes accessed", 0.0) or 0.0))
        except Exception:        # noqa: BLE001 -- profiling never raises
            result = None
    with _COST_LOCK:
        _COST_CACHE[key] = result
    return result


def profiled_call(kernel: str, fn, bucket: str, args: tuple,
                  kwargs: dict):
    """Run ``fn(*args, **kwargs)`` timed to completion, recording the
    ``cim_kernel_*`` series for ``(kernel, bucket)``."""
    import jax

    with _trace.span(f"kernel.{kernel}", cat="kernel", kernel=kernel,
                     bucket=bucket) as sp:
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
    _M_US.observe(sp.duration_s * 1e6,
                  exemplar={"span_id": sp.span_id},
                  kernel=kernel, bucket=bucket)
    cost = _cost_analysis(kernel, bucket, fn, args, kwargs)
    if cost is not None:
        flops, nbytes = cost
        _M_FLOPS.set(flops, kernel=kernel, bucket=bucket)
        _M_BYTES.set(nbytes, kernel=kernel, bucket=bucket)
        _M_ROOF.set(roofline_utilization(flops, nbytes, sp.duration_s),
                    kernel=kernel, bucket=bucket)
    return out


def instrument(kernel: str, fn, bucket_fn) -> typing.Callable:
    """Wrap one kernel entry point with the profiling hook.

    ``bucket_fn(*args, **kwargs) -> str`` derives the shape-bucket label;
    with profiling off the wrapper is a single env lookup, so the
    default path stays effectively free."""
    def wrapper(*args, **kwargs):
        if not profiling_enabled():
            return fn(*args, **kwargs)
        return profiled_call(kernel, fn, bucket_fn(*args, **kwargs),
                             args, kwargs)
    wrapper.__name__ = getattr(fn, "__name__", kernel)
    wrapper.__qualname__ = getattr(fn, "__qualname__", kernel)
    wrapper.__doc__ = getattr(fn, "__doc__", None)
    wrapper.__wrapped__ = fn
    wrapper.__bucket_fn__ = bucket_fn
    return wrapper


class MeasurementRecord(typing.TypedDict):
    """One timed kernel call -- the calibration tier's unit of evidence.

    The documented schema (see the module docstring): ``kernel``,
    ``bucket``, ``tiling``, ``us``, ``flops``, ``bytes``, ``seed``.
    ``flops``/``bytes`` are ``None`` when XLA's compiled cost analysis
    was unavailable for the series (the fit skips such records)."""
    kernel: str
    bucket: str
    tiling: str
    us: float
    flops: typing.Optional[float]
    bytes: typing.Optional[float]
    seed: int


def summary(records: typing.Sequence[MeasurementRecord] | None = None,
            ) -> list[dict]:
    """Per-(kernel, bucket) profile rows, sorted: call count, mean
    microseconds, FLOPs/bytes and roofline utilization (0.0 when cost
    analysis was unavailable).

    With ``records`` (e.g. the return of :func:`run_microbench`) the rows
    aggregate exactly those measurements; without, they come from the
    process-wide metrics registry (everything profiled so far)."""
    if records is not None:
        acc: dict[tuple[str, str], list[MeasurementRecord]] = {}
        for r in records:
            acc.setdefault((r["kernel"], r["bucket"]), []).append(r)
        rows = []
        for (kernel, bucket), group in acc.items():
            us = sum(r["us"] for r in group) / len(group)
            flops = next((r["flops"] for r in group
                          if r["flops"] is not None), 0.0) or 0.0
            nbytes = next((r["bytes"] for r in group
                           if r["bytes"] is not None), 0.0) or 0.0
            rows.append({
                "kernel": kernel,
                "bucket": bucket,
                "calls": len(group),
                "us_per_call": us,
                "flops": flops,
                "bytes": nbytes,
                "roofline_utilization": roofline_utilization(
                    flops, nbytes, us * 1e-6),
            })
        rows.sort(key=lambda r: (r["kernel"], r["bucket"]))
        return rows
    rows = []
    for values, child in _M_US.samples():
        kernel, bucket = values
        s, n = child.snapshot()
        if n == 0:
            continue
        rows.append({
            "kernel": kernel,
            "bucket": bucket,
            "calls": n,
            "us_per_call": s / n,
            "flops": _M_FLOPS.value(kernel=kernel, bucket=bucket),
            "bytes": _M_BYTES.value(kernel=kernel, bucket=bucket),
            "roofline_utilization": _M_ROOF.value(kernel=kernel,
                                                  bucket=bucket),
        })
    rows.sort(key=lambda r: (r["kernel"], r["bucket"]))
    return rows


# --------------------------------------------------------------------- #
# standard micro-profile pass
# --------------------------------------------------------------------- #
_ALL_KERNELS = ("cim_matmul", "flash_attention", "selective_scan",
                "strategy_eval")


def _microbench_cases(kernels: tuple[str, ...], rng) -> list[tuple]:
    """(kernel, tiling, fn, args, kwargs) cases for the tiling sweep.

    Inputs are drawn once from ``rng`` (shared across tiling variants of
    a kernel) so variant timings differ only by tiling, not data."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops

    cases: list[tuple] = []
    if "cim_matmul" in kernels:
        a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        for tiling in ("AF", "PF"):
            cases.append(("cim_matmul", tiling, ops.cim_matmul, (a, b),
                          {"tiling": tiling}))
    if "flash_attention" in kernels:
        q = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 128, 64)), jnp.float32)
        for bq, bk in ((128, 128), (64, 64)):
            cases.append(("flash_attention", f"bq{bq}xbk{bk}",
                          ops.flash_attention, (q, k, v),
                          {"causal": True, "bq": bq, "bk": bk}))
    if "selective_scan" in kernels:
        bs, t, i, s = 1, 64, 32, 8
        xi = jnp.asarray(rng.standard_normal((bs, t, i)), jnp.float32)
        dt = jnp.asarray(np.abs(rng.standard_normal((bs, t, i))) * 0.1,
                         jnp.float32)
        bm = jnp.asarray(rng.standard_normal((bs, t, s)), jnp.float32)
        cm = jnp.asarray(rng.standard_normal((bs, t, s)), jnp.float32)
        aa = jnp.asarray(-np.abs(rng.standard_normal((i, s))),
                         jnp.float32)
        h0 = jnp.zeros((bs, i, s), jnp.float32)
        for ct, ci in ((16, 16), (32, 32)):
            cases.append(("selective_scan", f"ct{ct}xci{ci}",
                          ops.selective_scan, (xi, dt, bm, cm, aa, h0),
                          {"ct": ct, "ci": ci}))
    if "strategy_eval" in kernels:
        from repro.core.ir import bert_large_workload
        from repro.core.macro import get_macro
        from repro.core.pruning import (
            DesignSpace,
            candidates_with_bw,
            enumerate_space,
        )
        cands = candidates_with_bw(enumerate_space(DesignSpace(
            mr=(1, 2), mc=(1, 2), scr=(1, 4), is_kb=(4, 64),
            os_kb=(4, 64))), 256)
        wl = bert_large_workload().merged().as_arrays()
        cases.append(("strategy_eval", "default", ops.strategy_eval,
                      (cands, wl, get_macro("vanilla-dcim")), {}))
    return cases


def run_microbench(kernels: typing.Sequence[str] | None = None,
                   repeats: int = 3, seed: int = 0,
                   ) -> list[MeasurementRecord]:
    """Time the real Pallas kernels over a small tiling sweep and return
    one :class:`MeasurementRecord` per (case, repeat).

    This is the measurement half of the two-fidelity calibration tier
    (``repro.core.calibration.fit_corrections`` fits correction factors
    from these records) and the shared body of ``repro-service profile``
    / ``calibrate``, the server's ``CIM_TUNER_PROFILE`` warm-up and
    ``benchmarks/run.py --profile-kernels`` -- tiny canonical shapes,
    interpret mode on CPU hosts.  Each case is warmed once (tracing +
    cost analysis) before the timed repeats, and the ``cim_kernel_*``
    registry families are populated as a side effect.  Enables
    ``CIM_TUNER_PROFILE`` for this process if unset."""
    if not profiling_enabled():
        os.environ[PROFILE_ENV] = "1"
    import jax

    import numpy as np

    kernels = tuple(kernels) if kernels else _ALL_KERNELS
    unknown = sorted(set(kernels) - set(_ALL_KERNELS))
    if unknown:
        raise ValueError(f"unknown kernels {unknown}; "
                         f"pick from {_ALL_KERNELS}")
    rng = np.random.default_rng(seed)
    t0 = time.perf_counter()
    records: list[MeasurementRecord] = []
    for kernel, tiling, fn, args, kwargs in _microbench_cases(kernels,
                                                              rng):
        bucket_fn = getattr(fn, "__bucket_fn__", None)
        bucket = bucket_fn(*args, **kwargs) if bucket_fn else tiling
        # warm-up: tracing/compile + one-time cost analysis stay out of
        # the timed repeats
        jax.block_until_ready(fn(*args, **kwargs))
        with _COST_LOCK:
            cost = _COST_CACHE.get((kernel, bucket))
        for _ in range(max(1, repeats)):
            t1 = time.perf_counter()
            jax.block_until_ready(fn(*args, **kwargs))
            records.append(MeasurementRecord(
                kernel=kernel, bucket=bucket, tiling=tiling,
                us=(time.perf_counter() - t1) * 1e6,
                flops=cost[0] if cost else None,
                bytes=cost[1] if cost else None, seed=seed))
    _M_RUNTIME.set(time.perf_counter() - t0)
    return records


# --------------------------------------------------------------------- #
# per-job measurement stash (engine -> queue -> store sidecar)
# --------------------------------------------------------------------- #
#: measured-fidelity runs park their records here keyed by job key; the
#: queue drains the stash into the result store's ``.measurements.json``
#: sidecar right before publishing the result (mirrors the timeline
#: recorder hand-off)
_MEASUREMENTS: dict[str, list[MeasurementRecord]] = {}
_MEAS_LOCK = threading.Lock()
_MEAS_CAP = 512


def record_measurements(key: str,
                        records: typing.Sequence[MeasurementRecord],
                        ) -> None:
    """Stash the measurement records backing one job's measured-fidelity
    re-score, keyed by the job's content address (bounded FIFO)."""
    with _MEAS_LOCK:
        if len(_MEASUREMENTS) >= _MEAS_CAP and key not in _MEASUREMENTS:
            _MEASUREMENTS.pop(next(iter(_MEASUREMENTS)))
        _MEASUREMENTS[key] = list(records)


def take_measurements(key: str) -> list[MeasurementRecord] | None:
    """Pop (and return) the stashed records for ``key``, or None."""
    with _MEAS_LOCK:
        return _MEASUREMENTS.pop(key, None)
