"""Env-gated ``repro.*`` logging hierarchy.

Replaces the server's blanket stderr-silencing with real loggers: every
subsystem logs through ``get_logger("server")`` -> ``repro.server`` etc.,
quiet (WARNING) by default, and ``CIM_TUNER_LOG`` turns subsystems on
lumos-style with comma-separated selectors::

    CIM_TUNER_LOG=server              # repro.server at DEBUG
    CIM_TUNER_LOG=engine,queue=INFO   # engine DEBUG, queue INFO
    CIM_TUNER_LOG=all=INFO            # whole repro.* tree at INFO

One tagged ``StreamHandler`` is installed on the ``repro`` root logger
(``propagate=False`` keeps host applications' root handlers out of it);
request-line logging from the HTTP server lands at DEBUG so it only
appears when an operator asks for it.
"""
from __future__ import annotations

import logging
import os
import sys
import threading

__all__ = ["configure_logging", "get_logger", "ROOT"]

ROOT = "repro"
_FORMAT = "%(asctime)s %(levelname)-7s %(name)s: %(message)s"
_configured = False
_lock = threading.Lock()


def _parse_spec(spec: str) -> dict[str, int]:
    """``"engine,queue=INFO"`` -> ``{"engine": DEBUG, "queue": INFO}``."""
    levels: dict[str, int] = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, _, level_s = part.partition("=")
        level = logging.DEBUG
        if level_s:
            level = logging.getLevelName(level_s.strip().upper())
            if not isinstance(level, int):
                level = logging.DEBUG
        levels[name.strip().lower()] = level
    return levels


def configure_logging(spec: str | None = None, *,
                      force: bool = False) -> logging.Logger:
    """Install the ``repro`` handler and apply ``CIM_TUNER_LOG``.

    Idempotent: the handler is installed once per process; pass
    ``force=True`` to re-read ``spec`` / the environment (tests).
    Returns the ``repro`` root logger.
    """
    global _configured
    root = logging.getLogger(ROOT)
    with _lock:
        if _configured and not force:
            return root
        if not any(getattr(h, "_repro_obs", False) for h in root.handlers):
            handler = logging.StreamHandler(sys.stderr)
            handler.setFormatter(logging.Formatter(_FORMAT))
            handler._repro_obs = True        # type: ignore[attr-defined]
            root.addHandler(handler)
        root.propagate = False
        root.setLevel(logging.WARNING)
        if spec is None:
            spec = os.environ.get("CIM_TUNER_LOG", "")
        for name, level in _parse_spec(spec).items():
            if name in ("all", ROOT, "*"):
                root.setLevel(level)
            else:
                logging.getLogger(f"{ROOT}.{name}").setLevel(level)
        _configured = True
    return root


def get_logger(subsystem: str) -> logging.Logger:
    """The ``repro.<subsystem>`` logger (configuring the hierarchy on
    first use)."""
    configure_logging()
    return logging.getLogger(f"{ROOT}.{subsystem}")
