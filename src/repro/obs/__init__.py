"""Unified telemetry: metrics registry, span tracer, logging, progress.

One stdlib-only subsystem behind every counter, latency histogram, trace
span, log line and SSE progress event in the DSE stack::

    from repro import obs

    REQS = obs.registry().counter("cim_http_requests_total", "...",
                                  ("endpoint", "method"))
    with obs.span("engine.compile", bucket=str(key)):
        ...
    obs.get_logger("server").debug("GET /v1/stats 200")
    obs.progress_bus().publish(job_key, phase="race", rung=1, best=2.4)

See ``docs/observability.md`` for the metric catalog and span names.
"""
from repro.obs import profile
from repro.obs.events import ProgressBus, progress_bus
from repro.obs.profile import (
    MeasurementRecord,
    record_measurements,
    run_microbench,
    take_measurements,
)
from repro.obs.log import configure_logging, get_logger
from repro.obs.metrics import (
    DEFAULT_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Registry,
    StatCounters,
    exemplars_enabled,
    registry,
)
from repro.obs.recorder import (
    TIMELINE_SCHEMA,
    FlightRecorder,
    flight_recorder,
    regret_curve,
    render_timeline,
)
from repro.obs.trace import Span, Tracer, chrome_trace, span, tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Registry",
    "StatCounters",
    "registry",
    "exemplars_enabled",
    "DEFAULT_BUCKETS",
    "Span",
    "Tracer",
    "tracer",
    "span",
    "chrome_trace",
    "FlightRecorder",
    "flight_recorder",
    "render_timeline",
    "regret_curve",
    "TIMELINE_SCHEMA",
    "profile",
    "MeasurementRecord",
    "run_microbench",
    "record_measurements",
    "take_measurements",
    "configure_logging",
    "get_logger",
    "ProgressBus",
    "progress_bus",
]
