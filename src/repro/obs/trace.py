"""Span tracer: timed sections -> ring buffer -> Chrome trace_event.

``with span("engine.compile", bucket=key):`` times a section, records it
as a completed-event dict in a bounded in-memory ring buffer, optionally
appends it as JSONL to ``$CIM_TUNER_TRACE``, and (when the span was given
a histogram) feeds the duration into the metrics registry -- one
instrumentation point serves both the trace timeline and the latency
distributions.

Events are stored directly in Chrome ``trace_event`` shape (``ph: "X"``
complete events, ``ts``/``dur`` in microseconds), so export is a thin
wrapper: ``repro-service trace --export chrome`` writes a
``{"traceEvents": [...]}`` file Perfetto / ``chrome://tracing`` loads
as-is.

Environment:

``CIM_TUNER_TRACE``
    Path; every finished span is appended there as one JSON line.
``CIM_TUNER_TRACE_BUFFER``
    Ring-buffer capacity (default 8192 spans); 0 disables buffering.
"""
from __future__ import annotations

import collections
import contextlib
import itertools
import json
import os
import threading
import time
import typing

__all__ = ["Span", "Tracer", "tracer", "span", "chrome_trace"]

_DEF_CAPACITY = 8192

#: process-unique span-id sequence (itertools.count increments atomically
#: under the GIL, so ids are race-free without a lock)
_SPAN_SEQ = itertools.count(1)


def _next_span_id() -> str:
    return f"{os.getpid():x}-{next(_SPAN_SEQ):x}"


class Span:
    """One in-flight timed section; attributes land in the event's
    ``args``.  ``span_id`` is the process-unique id the event carries in
    ``/v1/trace`` -- histogram exemplars reference it (see
    ``obs/metrics.py``)."""

    __slots__ = ("name", "cat", "args", "t0", "duration_s", "span_id")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = time.perf_counter()
        self.duration_s: float | None = None
        self.span_id = _next_span_id()

    def set(self, **kw) -> None:
        """Attach extra args discovered mid-span (e.g. result counts)."""
        self.args.update(kw)


class Tracer:
    """Bounded ring buffer of finished spans with optional JSONL sink."""

    def __init__(self, capacity: int | None = None,
                 jsonl_path: str | None = None):
        if capacity is None:
            capacity = int(os.environ.get("CIM_TUNER_TRACE_BUFFER",
                                          _DEF_CAPACITY))
        if jsonl_path is None:
            jsonl_path = os.environ.get("CIM_TUNER_TRACE") or None
        self.capacity = max(0, capacity)
        self.jsonl_path = jsonl_path
        self._lock = threading.Lock()
        self._events: collections.deque = collections.deque(
            maxlen=self.capacity or 1)
        self._pid = os.getpid()
        # epoch anchor so perf_counter offsets become absolute-ish ts
        self._epoch_us = time.time() * 1e6 - time.perf_counter() * 1e6

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "repro",
             histogram=None, **args) -> typing.Iterator[Span]:
        """Time a ``with`` block as one complete trace event.

        ``histogram`` is an optional :class:`repro.obs.metrics.Histogram`
        child or family (no labels) whose ``observe`` receives the span
        duration in seconds on exit, tagged with this span's id as an
        exemplar (so a latency outlier in ``/v1/metrics`` links back to
        its span in ``/v1/trace``).  Extra keyword args become the
        event's ``args`` payload.
        """
        sp = Span(name, cat, dict(args))
        try:
            yield sp
        finally:
            sp.duration_s = time.perf_counter() - sp.t0
            self._record(sp)
            if histogram is not None:
                try:
                    histogram.observe(sp.duration_s,
                                      exemplar={"span_id": sp.span_id})
                except TypeError:      # foreign histogram, no exemplars
                    histogram.observe(sp.duration_s)

    def _record(self, sp: Span) -> None:
        ev = {
            "name": sp.name,
            "cat": sp.cat,
            "id": sp.span_id,
            "ph": "X",
            "ts": round(self._epoch_us + sp.t0 * 1e6, 3),
            "dur": round(sp.duration_s * 1e6, 3),
            "pid": self._pid,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": sp.args,
        }
        if self.capacity:
            with self._lock:
                self._events.append(ev)
        if self.jsonl_path:
            line = json.dumps(ev, default=str)
            with self._lock:
                try:
                    with open(self.jsonl_path, "a") as f:
                        f.write(line + "\n")
                except OSError:
                    # tracing must never take the workload down
                    self.jsonl_path = None

    def events(self) -> list[dict]:
        """Snapshot of the buffered events, oldest first."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        """Drop all buffered events (tests)."""
        with self._lock:
            self._events.clear()


def chrome_trace(events: typing.Iterable[dict]) -> dict:
    """Wrap raw span events as a Chrome/Perfetto trace document."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms"}


# --------------------------------------------------------------------- #
# the process-wide tracer
# --------------------------------------------------------------------- #
_TRACER: Tracer | None = None
_TRACER_LOCK = threading.Lock()


def tracer() -> Tracer:
    """The process-wide :class:`Tracer` (lazily built so env vars set by
    tests before first use are honoured)."""
    global _TRACER
    if _TRACER is None:
        with _TRACER_LOCK:
            if _TRACER is None:
                _TRACER = Tracer()
    return _TRACER


def span(name: str, *, cat: str = "repro", histogram=None, **args):
    """``tracer().span(...)`` shorthand -- the one-liner subsystems use."""
    return tracer().span(name, cat=cat, histogram=histogram, **args)
