"""Per-job search flight recorder: structured decision timelines.

Every portfolio race the engine runs leaves a compact, JSON-able record
of HOW it spent its budget -- per-rung best-so-far, per-backend pulls
and bandit rewards, UCB scores and the chosen arm, device assignments,
dedup fan-out -- keyed by the job's canonical :func:`job_key`.  The
engine feeds the process-wide :func:`flight_recorder` alongside its SSE
progress events (same payloads, so the two reconcile exactly); the
service queue persists each finished timeline into the result store
next to the result itself; ``GET /v1/jobs/<key>/timeline`` and the
``repro-service timeline`` CLI read it back.

Timeline shape (``TIMELINE_SCHEMA`` guards evolution)::

    {"schema": 1, "key": ..., "method": "portfolio",
     "allocator": "bandit", "backends": [...], "devices": 1,
     "device_map": {backend: device}, "total_evals": ..., "rungs": ...,
     "created_s": ..., "events": [{"phase": "race", "rung": 0,
        "best": ..., "backend_best": {...}, "pulls": {...},
        "rewards": {...}, "ucb": {...}, "chosen": ...}, ...,
        {"phase": "final", "winner": ..., "final": ..., ...}],
     "provenance": {"dedup_fanout": ...},
     "summary": {"winner": ..., "best": ..., "final": ..., "pulls": ...}}

Environment:

``CIM_TUNER_TIMELINE_BUFFER``
    How many per-job timelines the in-memory recorder retains (LRU,
    default 1024); the store-persisted copies are unaffected.
"""
from __future__ import annotations

import collections
import copy
import os
import threading
import time

__all__ = ["FlightRecorder", "flight_recorder", "render_timeline",
           "regret_curve", "TIMELINE_SCHEMA"]

#: bump when the timeline record layout changes shape
TIMELINE_SCHEMA = 1

_DEF_CAPACITY = 1024
_ENV_CAPACITY = "CIM_TUNER_TIMELINE_BUFFER"


class FlightRecorder:
    """Bounded LRU of per-job decision timelines (thread-safe)."""

    def __init__(self, capacity: int | None = None):
        if capacity is None:
            capacity = int(os.environ.get(_ENV_CAPACITY, _DEF_CAPACITY))
        self.capacity = max(1, int(capacity))
        self._lock = threading.Lock()
        self._timelines: collections.OrderedDict[str, dict] = \
            collections.OrderedDict()

    def start(self, key: str, **header) -> None:
        """Open (or reset) the timeline for one job key; ``header``
        carries the race-invariant fields (method, allocator, backends,
        devices, budget)."""
        tl = {"schema": TIMELINE_SCHEMA, "key": key, **header,
              "created_s": time.time(), "events": [], "provenance": {},
              "summary": None}
        with self._lock:
            self._timelines[key] = tl
            self._timelines.move_to_end(key)
            while len(self._timelines) > self.capacity:
                self._timelines.popitem(last=False)

    def event(self, key: str, payload: dict) -> None:
        """Append one decision event (a race wave or the final phase);
        no-op for keys without an open timeline."""
        with self._lock:
            tl = self._timelines.get(key)
            if tl is not None:
                tl["events"].append(copy.deepcopy(payload))

    def annotate(self, key: str, **fields) -> None:
        """Merge provenance facts (dedup fan-out, batch size, ...) into
        an open timeline; no-op for unknown keys."""
        with self._lock:
            tl = self._timelines.get(key)
            if tl is not None:
                tl["provenance"].update(copy.deepcopy(fields))

    def finish(self, key: str, **fields) -> None:
        """Close the timeline with its convergence summary."""
        with self._lock:
            tl = self._timelines.get(key)
            if tl is not None:
                tl["summary"] = copy.deepcopy(fields)

    def timeline(self, key: str) -> dict | None:
        """Deep-copied snapshot of one timeline (``None`` if unknown)."""
        with self._lock:
            tl = self._timelines.get(key)
            return copy.deepcopy(tl) if tl is not None else None

    def keys(self) -> list[str]:
        """Keys with an in-memory timeline, oldest first."""
        with self._lock:
            return list(self._timelines)

    def clear(self) -> None:
        """Drop every in-memory timeline (tests)."""
        with self._lock:
            self._timelines.clear()


# --------------------------------------------------------------------- #
# analysis + rendering (the `repro-service timeline` CLI body)
# --------------------------------------------------------------------- #
def regret_curve(timeline: dict) -> list[dict]:
    """``{"rung", "pulls", "regret"}`` per race rung, where regret is
    the rung's incumbent best minus the overall best the job ever
    reached (race and final phases included).  Rungs without a finite
    best are skipped."""
    events = timeline.get("events") or []
    bests = [ev.get("best") for ev in events
             if isinstance(ev.get("best"), (int, float))]
    finals = [ev.get("final") for ev in events
              if isinstance(ev.get("final"), (int, float))]
    if not bests:
        return []
    floor = min(bests + finals)
    curve = []
    for ev in events:
        if ev.get("phase") != "race" or \
                not isinstance(ev.get("best"), (int, float)):
            continue
        curve.append({
            "rung": ev.get("rung"),
            "pulls": int(sum((ev.get("pulls") or {}).values())),
            "regret": float(ev["best"]) - floor,
        })
    return curve


def _num(v, digits: int = 6) -> str:
    return "-" if not isinstance(v, (int, float)) else f"{v:.{digits}g}"


def render_timeline(timeline: dict, width: int = 28) -> str:
    """Deterministic human rendering of one timeline: the rung table, a
    regret-vs-budget bar curve, and a convergence summary.  Contains no
    wall-clock data, so fixed-seed runs render identically."""
    backends = list(timeline.get("backends") or [])
    lines = [
        f"job       {timeline.get('key', '?')}",
        f"method    {timeline.get('method', '?')} "
        f"allocator={timeline.get('allocator', '?')} "
        f"devices={timeline.get('devices', '?')}",
        f"backends  {', '.join(backends) or '?'}",
        f"budget    total_evals={timeline.get('total_evals', '?')} "
        f"rungs={timeline.get('rungs', '?')}",
    ]
    prov = timeline.get("provenance") or {}
    if prov:
        lines.append("provenance " + " ".join(
            f"{k}={prov[k]}" for k in sorted(prov)))

    events = timeline.get("events") or []
    races = [ev for ev in events if ev.get("phase") == "race"]
    if races:
        lines.append("")
        lines.append(f"{'rung':>4}  {'best':>12}  {'chosen':>10}  "
                     f"pulls({'/'.join(backends)})")
        for ev in races:
            pulls = ev.get("pulls") or {}
            lines.append(
                f"{ev.get('rung', '?'):>4}  {_num(ev.get('best')):>12}  "
                f"{ev.get('chosen') or '-':>10}  "
                f"{'/'.join(str(pulls.get(b, 0)) for b in backends)}")

    curve = regret_curve(timeline)
    if curve:
        lines.append("")
        lines.append("regret vs budget (pulls -> best-so-far - overall "
                     "best)")
        top = max(pt["regret"] for pt in curve) or 1.0
        for pt in curve:
            bar = "#" * int(round(width * pt["regret"] / top))
            lines.append(f"  {pt['pulls']:>5} {pt['regret']:>12.6g} "
                         f"|{bar}")

    summary = timeline.get("summary") or {}
    finals = [ev for ev in events if ev.get("phase") == "final"]
    final_ev = finals[-1] if finals else {}
    winner = summary.get("winner", final_ev.get("winner"))
    best = summary.get("best", final_ev.get("best"))
    final = summary.get("final", final_ev.get("final"))
    lines.append("")
    conv = "-"
    if curve:
        top = max(pt["regret"] for pt in curve)
        idx = next((i for i, pt in enumerate(curve)
                    if pt["regret"] <= 0.01 * top), None)
        if idx is not None:
            conv = f"rung {curve[idx]['rung']} of {len(curve)}"
    lines.append(f"converged {conv} (first rung with <= 1% of peak "
                 f"regret)")
    lines.append(f"winner    {winner or '?'} best={_num(best)} "
                 f"final={_num(final)}")
    return "\n".join(lines)


# --------------------------------------------------------------------- #
# the process-wide recorder
# --------------------------------------------------------------------- #
_RECORDER: FlightRecorder | None = None
_RECORDER_LOCK = threading.Lock()


def flight_recorder() -> FlightRecorder:
    """The process-wide :class:`FlightRecorder` the engine feeds (lazily
    built so env vars set by tests before first use are honoured)."""
    global _RECORDER
    if _RECORDER is None:
        with _RECORDER_LOCK:
            if _RECORDER is None:
                _RECORDER = FlightRecorder()
    return _RECORDER
