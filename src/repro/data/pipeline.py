"""Deterministic, restartable data pipeline.

The stream is a stateless function of (seed, step, host) so a restarted run
resumes bit-exact mid-epoch without replaying data, and elastic re-sharding
(different host count after resume) keeps global batches identical: batches
are defined globally and each host materializes only its slice.

``SyntheticLMStream`` generates structured pseudo-text (Zipfian unigrams +
a deterministic bigram mixing rule) rather than uniform noise so models can
actually learn (the quickstart's loss curve falls), while needing no files.
A binary-tokens file reader with the same interface covers real corpora.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 1234
    memory_tokens: int = 0     # stub-frontend embeddings (vlm/audio)
    d_model: int = 0
    prefetch: int = 2


class SyntheticLMStream:
    """Deterministic synthetic LM token stream with learnable structure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed Zipfian unigram table + deterministic "grammar" permutation
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)
        self._perm = rng.permutation(cfg.vocab)

    def global_batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, t = cfg.global_batch, cfg.seq_len
        base = rng.choice(cfg.vocab, size=(b, t + 1), p=self._probs)
        # bigram structure: with p=.5 the next token is a fixed function of
        # the previous one -- gives the model something to learn
        follow = self._perm[base[:, :-1]]
        coin = rng.random((b, t)) < 0.5
        toks = base[:, 1:].copy()
        toks[coin] = follow[coin]
        tokens = np.concatenate([base[:, :1], toks], axis=1).astype(np.int32)
        batch = {"tokens": tokens[:, :-1],
                 "labels": tokens[:, 1:].astype(np.int32)}
        if cfg.memory_tokens:
            batch["memory"] = rng.standard_normal(
                (b, cfg.memory_tokens, cfg.d_model)).astype(np.float32)
        return batch


class TokenFileStream:
    """Pre-tokenized flat binary (int32) corpus reader, deterministic by
    (seed, step): each batch gathers global_batch random windows."""

    def __init__(self, cfg: DataConfig, path: str):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=np.int32, mode="r")
        if len(self._data) < cfg.seq_len + 1:
            raise ValueError("corpus shorter than one sequence")

    def global_batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        starts = rng.integers(0, len(self._data) - cfg.seq_len - 1,
                              size=cfg.global_batch)
        seqs = np.stack([self._data[s: s + cfg.seq_len + 1] for s in starts])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}


def shard_batch(batch: dict, mesh: Mesh) -> dict:
    """Place a global numpy batch onto the mesh (batch dim over data axes)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def put(name, arr):
        spec = [dp] + [None] * (arr.ndim - 1)
        size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
        if arr.shape[0] % size != 0:
            spec[0] = None
        return jax.device_put(arr, NamedSharding(mesh, P(*spec)))

    return {k: put(k, v) for k, v in batch.items()}


def make_batch_iterator(stream, mesh: Mesh, start_step: int = 0,
                        prefetch: int = 2) -> Iterator[dict]:
    """Background-threaded, prefetching, restartable iterator."""
    q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
    stop = threading.Event()

    def producer():
        step = start_step
        pending = None
        while not stop.is_set():
            if pending is None:
                # build the batch once; a full queue must not re-build it
                # on every put retry
                pending = stream.global_batch_at(step)
                step += 1
            try:
                q.put(pending, timeout=0.5)
                pending = None
            except queue.Full:
                continue

    th = threading.Thread(target=producer, daemon=True)
    th.start()
    try:
        while True:
            yield shard_batch(q.get(), mesh)
    finally:
        stop.set()
        th.join(timeout=2.0)
