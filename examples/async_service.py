"""Async DSE service: submit, stream, and hit the warm result store.

    PYTHONPATH=src python examples/async_service.py

Demonstrates the service tiers over the batched exploration engine
(``ServiceClient`` wraps the micro-batching queue; set
``CIM_TUNER_SERVICE_URL`` or pass ``ServiceClient(base_url=...)`` and the
identical code runs against a remote ``repro-service serve`` front door):

1. submit a heterogeneous job list and consume results in COMPLETION order
   (each executable bucket resolves the moment it finishes);
2. resubmit an identical job -> deduped in flight / served from the
   persistent result store with zero engine work;
3. run a pluggable search backend per job (``method=`` /
   ``ExploreJob.search_method``, with per-job ``search_settings``);
4. stream per-workload Pareto frontiers.
"""
import sys
import time

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core import ExploreJob, bert_large_workload, get_macro
from repro.search import PortfolioSettings
from repro.service import ServiceClient, as_completed, stream_pareto

macro = get_macro("vanilla-dcim")
workloads = {
    "bert-large": bert_large_workload(),
    "yi-6b": get_arch("yi-6b").workload(seq=512),
    "whisper-small": get_arch("whisper-small").workload(seq=512),
}

svc = ServiceClient()

# -- 1. streaming: results arrive per executable bucket ----------------- #
print("== streaming submission (completion order) ==")
t0 = time.perf_counter()
futures = svc.submit_many(
    [ExploreJob(macro, wl, 5.0, objective="ee") for wl in workloads.values()],
    method="exhaustive", metas=list(workloads))
for fut in as_completed(futures, timeout=600):
    print(f"  [{time.perf_counter()-t0:5.1f}s] {fut.result().summary()}")

# -- 2. warm path: identical job, zero engine invocations --------------- #
print("\n== warm resubmission ==")
t0 = time.perf_counter()
again = svc.submit(ExploreJob(macro, workloads["bert-large"], 5.0,
                              objective="ee"), method="exhaustive")
r = again.result(timeout=60)
print(f"  [{time.perf_counter()-t0:5.3f}s] source={again.source}  "
      f"{r.summary()}")
print(f"  service stats: {svc.stats}")

# -- 3. pluggable search backend with per-job settings ------------------ #
# a small bandit-allocated portfolio race (SA vs GA vs DE vs Sobol, UCB
# budget allocation) on a pinned space; settings ride the job itself
print("\n== portfolio search (bandit allocator) ==")
from repro.core import DesignSpace
small = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))
pf_job = ExploreJob(
    macro, workloads["bert-large"], 5.0, objective="ee", space=small,
    search_method="portfolio",
    search_settings=PortfolioSettings(total_evals=4000, allocator="bandit"))
pf = svc.submit(pf_job).result(timeout=600)
print(f"  {pf.summary()}")
print(f"  portfolio: {pf.search['portfolio']}")

# -- 4. streaming Pareto frontiers -------------------------------------- #
print("\n== streaming EE/Th Pareto frontiers ==")
for name, frontier in stream_pareto(
        macro, list(workloads.values())[:2], 5.0, service=svc, timeout=600):
    pts = ", ".join(f"({p['gops']:.0f} GOPS, {p['tops_w']:.2f} TOPS/W)"
                    for p in frontier[:3])
    print(f"  {name}: {len(frontier)} frontier points  [{pts}, ...]")

svc.close()
