"""End-to-end driver: train a ~100M-parameter yi-family LM on the synthetic
pipeline with checkpoint/restart, through the full production trainer.

    # full run (multi-core host): ~115M params, a few hundred steps
    PYTHONPATH=src python examples/train_lm.py

    # constrained host (e.g. 1-core CI): shrink via flags
    PYTHONPATH=src python examples/train_lm.py --dim 256 --layers 8 \
        --steps 60 --seq 128 --batch 4

Kill it mid-run and start it again: it resumes from the newest checkpoint
(the data stream is stateless-by-step, so batches line up bit-exact).
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.launch.mesh import make_debug_mesh
from repro.optim import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=16384)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm_100m")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_arch("yi-6b"),
        name="yi-100m",
        n_layers=args.layers, d_model=args.dim,
        n_heads=max(4, args.dim // 64), n_kv_heads=max(2, args.dim // 128),
        head_dim=64, d_ff=args.dim * 3, vocab=args.vocab,
    )
    tcfg = TrainerConfig(
        steps=args.steps, seq_len=args.seq, global_batch=args.batch,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(10, args.steps // 6),
        log_every=max(1, args.steps // 30),
        optimizer=AdamWConfig(peak_lr=6e-4, warmup_steps=args.steps // 10,
                              total_steps=args.steps),
    )
    trainer = Trainer(cfg, tcfg, make_debug_mesh())
    from repro.models.model import build_model
    n = build_model(cfg).param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")
    trainer.train()
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
              f"min={min(losses):.4f}")
        print(f"straggler steps: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
