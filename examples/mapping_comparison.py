"""Fig. 7-style comparison on one network: CIM-Tuner's full scheduling +
tiling space (ST) vs the spatial-only space of prior work [19] (SO), under
identical co-exploration.

All four (strategy-set x objective) explorations are submitted to the
batched engine as ONE job list, so they share a single compiled executable
instead of re-jitting per call.

    PYTHONPATH=src python examples/mapping_comparison.py [arch-id]
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core import ExplorationEngine, ExploreJob, get_macro

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"
wl = get_arch(arch).workload(seq=512)
macro = get_macro("vanilla-dcim")

print(f"workload: {arch} ({len(wl.ops)} merged GEMM shapes, "
      f"{wl.total_macs/1e9:.1f} GMACs)")

engine = ExplorationEngine()
jobs = [ExploreJob(macro, wl, 5.0, objective=obj, strategy_set=sset)
        for sset in ("so", "st") for obj in ("ee", "th")]
results = engine.run(jobs, method="exhaustive")
by_key = {(j.strategy_set, j.objective): r for j, r in zip(jobs, results)}
print(f"(engine: {len(jobs)} jobs in {results[0].search['runtime_s']:.1f}s, "
      f"{engine.stats['batches']} batch(es))")

for sset, label in (("so", "SO (spatial-only, prior work [19])"),
                    ("st", "ST (CIM-Tuner: scheduling + tiling)")):
    ee, th = by_key[(sset, "ee")], by_key[(sset, "th")]
    print(f"\n{label}")
    print(f"  best-EE {ee.config.as_tuple()}: "
          f"{ee.metrics['tops_w']:.2f} TOPS/W")
    print(f"  best-Th {th.config.as_tuple()}: {th.metrics['gops']:.0f} GOPS")
    if sset == "st":
        print("  per-op strategies (EE point):")
        for op, strat in ee.per_op_strategy.items():
            print(f"    {op:16s} {strat}")
