"""Fig. 7-style comparison on one network: CIM-Tuner's full scheduling +
tiling space (ST) vs the spatial-only space of prior work [19] (SO), under
identical co-exploration.

    PYTHONPATH=src python examples/mapping_comparison.py [arch-id]
"""
import sys

sys.path.insert(0, "src")

from repro.configs import get_arch
from repro.core import co_explore, get_macro

arch = sys.argv[1] if len(sys.argv) > 1 else "yi-6b"
wl = get_arch(arch).workload(seq=512)
macro = get_macro("vanilla-dcim")

print(f"workload: {arch} ({len(wl.ops)} merged GEMM shapes, "
      f"{wl.total_macs/1e9:.1f} GMACs)")
for sset, label in (("so", "SO (spatial-only, prior work [19])"),
                    ("st", "ST (CIM-Tuner: scheduling + tiling)")):
    ee = co_explore(macro, wl, 5.0, objective="ee", strategy_set=sset,
                    method="exhaustive")
    th = co_explore(macro, wl, 5.0, objective="th", strategy_set=sset,
                    method="exhaustive")
    print(f"\n{label}")
    print(f"  best-EE {ee.config.as_tuple()}: "
          f"{ee.metrics['tops_w']:.2f} TOPS/W")
    print(f"  best-Th {th.config.as_tuple()}: {th.metrics['gops']:.0f} GOPS")
    if sset == "st":
        print("  per-op strategies (EE point):")
        for op, strat in ee.per_op_strategy.items():
            print(f"    {op:16s} {strat}")
