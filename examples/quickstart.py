"""Quickstart: co-explore an SRAM-CIM accelerator for a workload.

    PYTHONPATH=src python examples/quickstart.py

Given a CIM macro, a network's GEMM mix and an area budget, CIM-Tuner
returns the balanced hardware sizing (MR, MC, SCR, IS, OS) and the optimal
per-operator mapping strategy.
"""
import sys

sys.path.insert(0, "src")

from repro.core import MatmulOp, SASettings, Workload, co_explore, get_macro

# 1. pick a macro from the library (or define your own MacroSpec)
macro = get_macro("vanilla-dcim")   # the paper's silicon-verified config

# 2. describe the workload (here: a small transformer block's GEMMs)
workload = Workload("demo-block", (
    MatmulOp(512, 768, 768, count=3, name="qkv"),
    MatmulOp(512, 768, 768, name="attn_out"),
    MatmulOp(512, 64, 512, count=12, weights_static=False, name="scores"),
    MatmulOp(512, 512, 64, count=12, weights_static=False, name="ctx"),
    MatmulOp(512, 768, 3072, name="ffn_up"),
    MatmulOp(512, 3072, 768, name="ffn_down"),
))

# 3. co-explore under a 3 mm^2 budget, optimizing energy efficiency.
#    method= accepts any registered repro.search backend ("sa", "genetic",
#    "evolution", "sobol", "portfolio") or "exhaustive"; settings= carries
#    that backend's settings dataclass (e.g. PortfolioSettings with
#    allocator="bandit" for the UCB-raced portfolio)
result = co_explore(
    macro, workload, area_budget_mm2=3.0, objective="ee",
    method="sa", settings=SASettings(n_chains=32, n_steps=200),
)

print(result.summary())
print("\nper-operator mapping strategies:")
for op, strat in result.per_op_strategy.items():
    print(f"  {op:12s} -> {strat}")
print(f"\nsearch: {result.search}")

# 4. compare against the exhaustive optimum (feasible: the evaluation is
#    one vmapped jnp expression)
exact = co_explore(macro, workload, area_budget_mm2=3.0, objective="ee",
                   method="exhaustive")
gap = result.metrics["energy_pj"] / exact.metrics["energy_pj"] - 1
print(f"\nexhaustive optimum: {exact.summary()}")
print(f"SA regret vs exhaustive: {gap*100:.2f}%")
