"""Multi-pod distributed co-exploration: SA chains sharded over a mesh with
best-candidate exchange, checkpointed and elastic.

    PYTHONPATH=src python examples/distributed_dse.py

On this CPU host the mesh is 1 device; on a pod the same code shards the
population over all chips (see core/distributed.py).  The checkpoint makes
the search preemption-safe: re-run the script and it resumes.
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.compat import make_mesh
from repro.core import SASettings, distributed_co_explore, get_macro
from repro.core.ir import bert_large_workload

mesh = make_mesh((jax.device_count(),), ("data",))
print(f"mesh: {jax.device_count()} device(s)")

res = distributed_co_explore(
    mesh, get_macro("vanilla-dcim"), bert_large_workload(),
    area_budget_mm2=5.0, objective="ee",
    settings=SASettings(seed=0), chains_per_device=16,
    rounds=6, sync_every=60,
    checkpoint_dir="checkpoints/dse", resume=True,
)
print(f"best config (MR,MC,SCR,IS,OS) = {res.config.as_tuple()}")
print(f"objective value: {res.best_value:.4g}")
print("incumbent best per round:", [f"{t:.3g}" for t in res.trace])
