"""Multi-pod distributed co-exploration: SA chains sharded over a mesh with
best-candidate exchange, checkpointed and elastic.

    PYTHONPATH=src python examples/distributed_dse.py
    # force N CPU devices to see the sharding (and the engine's
    # portfolio device-racing) on a laptop:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/distributed_dse.py

On this CPU host the mesh defaults to 1 device; on a pod the same code
shards the job x chain population over all chips (see
``core/distributed.py::distributed_co_explore_jobs`` for whole-batch
sharding -- ``distributed_co_explore(settings=SASettings(...))`` below is
its single-job wrapper).  The checkpoint makes the search preemption-safe:
re-run the script and it resumes.  Multi-device processes also get the
portfolio racer's device racing for free: ``co_explore(...,
method="portfolio")`` dispatches constituent backends round-robin across
the same devices (``repro.core.distributed.race_devices``).
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.compat import make_mesh
from repro.core import SASettings, distributed_co_explore, get_macro
from repro.core.ir import bert_large_workload

mesh = make_mesh((jax.device_count(),), ("data",))
print(f"mesh: {jax.device_count()} device(s)")

res = distributed_co_explore(
    mesh, get_macro("vanilla-dcim"), bert_large_workload(),
    area_budget_mm2=5.0, objective="ee",
    settings=SASettings(seed=0), chains_per_device=16,
    rounds=6, sync_every=60,
    checkpoint_dir="checkpoints/dse", resume=True,
)
print(f"best config (MR,MC,SCR,IS,OS) = {res.config.as_tuple()}")
print(f"objective value: {res.best_value:.4g}")
print("incumbent best per round:", [f"{t:.3g}" for t in res.trace])
