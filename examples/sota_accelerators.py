"""Reproduce paper Table II: rebalance TranCIM and TP-DCIM under their own
area budgets on Bert-Large.

    PYTHONPATH=src python examples/sota_accelerators.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import AcceleratorConfig, co_explore, evaluate_config
from repro.core.ir import bert_large_workload
from repro.core.macro import TPDCIM_MACRO, TRANCIM_MACRO
from repro.core.template import accelerator_area_mm2

wl = bert_large_workload()
for name, macro, base_cfg in (
    ("TranCIM", TRANCIM_MACRO, AcceleratorConfig(3, 1, 1, 64, 128)),
    ("TP-DCIM", TPDCIM_MACRO, AcceleratorConfig(2, 4, 1, 16, 16)),
):
    budget = accelerator_area_mm2(base_cfg, macro)
    base = evaluate_config(macro, base_cfg, wl)
    print(f"\n=== {name} (area budget {budget:.2f} mm^2) ===")
    print(f"  base {base_cfg.as_tuple()}: "
          f"{base['tops_w']:.2f} TOPS/W, {base['gops']:.0f} GOPS")
    for objective, label in (("ee", "EE."), ("th", "Th.")):
        opt = co_explore(macro, wl, budget, objective=objective,
                         method="exhaustive")
        key = "tops_w" if objective == "ee" else "gops"
        gain = opt.metrics[key] / base[key]
        print(f"  {label:4s} {opt.config.as_tuple()}: "
              f"{opt.metrics['tops_w']:.2f} TOPS/W, "
              f"{opt.metrics['gops']:.0f} GOPS, "
              f"{opt.metrics['area_mm2']:.2f} mm^2  (x{gain:.2f} on {key})")
