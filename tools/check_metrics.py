#!/usr/bin/env python
"""Prometheus text-exposition parser + assertion gate (stdlib only).

The service-fleet CI smoke pipes ``GET /v1/metrics`` output through this
to prove the endpoint is genuinely Prometheus-parseable (not just
200-OK text), that every histogram family is self-consistent (cumulative
bucket counts monotone non-decreasing, ``+Inf`` bucket == ``_count``),
and that the counters a healthy fleet run must move -- engine jobs,
store traffic -- are present and non-zero::

    curl -s "$URL/v1/metrics" | python tools/check_metrics.py \
        --min-families 12 \
        --require cim_http_request_seconds \
        --nonzero cim_engine_jobs_total --nonzero cim_store_ops_total \
        --require-exemplars cim_kernel_us \
        --catalog docs/observability.md --trace-json trace.json

OpenMetrics exemplar suffixes (``... # {span_id="..."} value ts``) are
accepted and parsed; ``--require-exemplars FAMILY`` asserts a family
actually carries them, ``--trace-json FILE`` asserts every exemplar's
``span_id`` points at a real span in a ``/v1/trace`` export, and
``--catalog FILE`` diffs the scraped families against the
``docs/observability.md`` metric-catalog table in both directions.

Also importable: :func:`parse` returns ``{family: {"type", "help",
"samples": {labeled-name: value}, "buckets": {...}, "exemplars":
{...}}}`` and raises ``ValueError`` on any malformed line, which the
unit tests use for a render/parse round-trip.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
# label values are quoted and may contain '}' (e.g. route templates like
# /v1/jobs/{key}), so the block must be matched pair-by-pair, not [^}]*
_LBLOCK = r'\{(?:\s*[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"\s*,?)*\}'
_NUM = r"-?[0-9.eE+-]+|[+-]Inf|NaN"
#: OpenMetrics exemplar suffix: `# {labels} value [timestamp]`
_EXEMPLAR = rf"#\s+({_LBLOCK})\s+({_NUM})(?:\s+({_NUM}))?"
_SAMPLE = re.compile(
    rf"^({_NAME})({_LBLOCK})?\s+({_NUM})(?:\s+{_EXEMPLAR})?\s*$")
_LABELS = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
#: histogram/summary series carry these suffixes on the family name
_SUFFIXES = ("_bucket", "_sum", "_count")
#: docs/observability.md catalog rows: `| `cim_family` | type | ... |`
_CATALOG_ROW = re.compile(rf"^\|\s*`({_NAME})`\s*\|")


def _family_of(sample_name: str, families: dict) -> str | None:
    if sample_name in families:
        return sample_name
    for suf in _SUFFIXES:
        if sample_name.endswith(suf) and sample_name[:-len(suf)] in families:
            return sample_name[:-len(suf)]
    return None


def _float(value_s: str) -> float:
    return float(value_s.replace("Inf", "inf"))


def _check_labels(labels: str, lineno: int) -> None:
    body = labels[1:-1].strip()
    if body and _LABELS.sub("", body).strip(", ") != "":
        raise ValueError(f"line {lineno}: malformed labels: {labels!r}")


def _series_key(labels: str, drop: tuple[str, ...] = ()) -> tuple:
    """Canonical (sorted label pairs) identity of one labeled series."""
    return tuple(sorted((k, v) for k, v in _LABELS.findall(labels or "")
                        if k not in drop))


def parse(text: str) -> dict:
    """Parse Prometheus text exposition; raises ValueError on bad lines.

    Every sample must belong to a ``# TYPE``-declared family (histogram
    ``_bucket``/``_sum``/``_count`` series resolve to their base
    family).  Per family the record carries ``samples`` (labeled name ->
    value), ``buckets`` (series key sans ``le`` -> {le: count}) and
    ``exemplars`` (labeled sample name -> {"labels", "value", "ts"}).
    """
    families: dict[str, dict] = {}

    def _family_rec(name: str) -> dict:
        return families.setdefault(
            name, {"type": None, "help": "", "samples": {},
                   "buckets": {}, "exemplars": {}})

    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                raise ValueError(f"line {lineno}: malformed HELP")
            _family_rec(parts[2])["help"] = \
                parts[3] if len(parts) > 3 else ""
        elif line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            _family_rec(parts[2])["type"] = parts[3]
        elif line.startswith("#"):
            continue                                   # plain comment
        else:
            m = _SAMPLE.match(line)
            if not m:
                raise ValueError(f"line {lineno}: malformed sample: {line!r}")
            name, labels, value_s = m.group(1), m.group(2) or "", m.group(3)
            ex_labels, ex_value_s, ex_ts_s = m.group(4), m.group(5), \
                m.group(6)
            fam = _family_of(name, families)
            if fam is None:
                raise ValueError(
                    f"line {lineno}: sample {name!r} has no TYPE family")
            if labels:
                _check_labels(labels, lineno)
            try:
                value = _float(value_s)
            except ValueError as exc:
                raise ValueError(
                    f"line {lineno}: bad value {value_s!r}") from exc
            rec = families[fam]
            rec["samples"][name + labels] = value
            if name.endswith("_bucket"):
                le = dict(_LABELS.findall(labels)).get("le")
                if le is None:
                    raise ValueError(
                        f"line {lineno}: bucket sample without le label")
                rec["buckets"].setdefault(
                    _series_key(labels, drop=("le",)), {})[le] = value
            if ex_labels is not None:
                _check_labels(ex_labels, lineno)
                try:
                    ex = {"labels": dict(_LABELS.findall(ex_labels)),
                          "value": _float(ex_value_s),
                          "ts": _float(ex_ts_s)
                          if ex_ts_s is not None else None}
                except ValueError as exc:
                    raise ValueError(
                        f"line {lineno}: bad exemplar: {line!r}") from exc
                rec["exemplars"][name + labels] = ex
    for fam, rec in families.items():
        if rec["type"] is None:
            raise ValueError(f"family {fam!r} has samples but no TYPE")
    return families


def histogram_errors(families: dict) -> list[str]:
    """Self-consistency violations across every histogram family:
    cumulative bucket counts must be monotone non-decreasing in ``le``,
    the ``+Inf`` bucket must exist and equal the series' ``_count``."""
    errors = []
    for fam, rec in families.items():
        if rec["type"] != "histogram":
            continue
        counts = {}
        for k, v in rec["samples"].items():
            if k.startswith(f"{fam}_count"):
                counts[_series_key(k[len(fam) + len("_count"):])] = v
        for key, buckets in rec["buckets"].items():
            label_s = "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"
            order = sorted(buckets, key=_float)
            vals = [buckets[le] for le in order]
            if any(b < a for a, b in zip(vals, vals[1:])):
                errors.append(f"{fam}{label_s}: bucket counts not "
                              f"monotone non-decreasing")
            if "+Inf" not in buckets:
                errors.append(f"{fam}{label_s}: missing +Inf bucket")
                continue
            count = counts.get(key)
            if count is None:
                errors.append(f"{fam}{label_s}: buckets without a "
                              f"_count sample")
            elif buckets["+Inf"] != count:
                errors.append(
                    f"{fam}{label_s}: +Inf bucket {buckets['+Inf']:g} "
                    f"!= _count {count:g}")
    return errors


def catalog_families(md_text: str) -> set[str]:
    """``cim_*`` family names listed in the docs metric-catalog table."""
    out = set()
    for line in md_text.splitlines():
        m = _CATALOG_ROW.match(line.strip())
        if m and m.group(1).startswith("cim_"):
            out.add(m.group(1))
    return out


def catalog_drift(families: dict, md_text: str) -> list[str]:
    """Two-way diff between the scraped ``cim_*`` families and the docs
    catalog: every scraped family must be documented and vice versa."""
    scraped = {f for f in families if f.startswith("cim_")}
    documented = catalog_families(md_text)
    errors = []
    for name in sorted(scraped - documented):
        errors.append(f"scraped family {name!r} missing from the docs "
                      f"catalog")
    for name in sorted(documented - scraped):
        errors.append(f"documented family {name!r} absent from the "
                      f"scrape")
    return errors


def exemplar_span_ids(families: dict) -> set[str]:
    """Every ``span_id`` referenced by an exemplar in the exposition."""
    out = set()
    for rec in families.values():
        for ex in rec["exemplars"].values():
            span_id = ex["labels"].get("span_id")
            if span_id:
                out.add(span_id)
    return out


def family_total(families: dict, name: str) -> float:
    """Sum of every sample in one family (histograms: the _count sum)."""
    rec = families.get(name)
    if rec is None:
        return 0.0
    if rec["type"] == "histogram":
        return sum(v for k, v in rec["samples"].items()
                   if k.startswith(f"{name}_count"))
    return sum(rec["samples"].values())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("file", nargs="?", default="-",
                    help="exposition text file ('-' = stdin)")
    ap.add_argument("--min-families", type=int, default=0,
                    help="fail unless at least this many families parse")
    ap.add_argument("--require", action="append", default=[],
                    metavar="FAMILY", help="family that must be present")
    ap.add_argument("--nonzero", action="append", default=[],
                    metavar="FAMILY",
                    help="family whose sample total must be > 0")
    ap.add_argument("--require-exemplars", action="append", default=[],
                    metavar="FAMILY",
                    help="family that must carry OpenMetrics exemplars")
    ap.add_argument("--catalog", default=None, metavar="FILE",
                    help="docs/observability.md to diff scraped families "
                         "against (two-way)")
    ap.add_argument("--trace-json", default=None, metavar="FILE",
                    help="Chrome trace export (/v1/trace); every "
                         "exemplar span_id must resolve to a span in it")
    args = ap.parse_args(argv)

    text = sys.stdin.read() if args.file == "-" else \
        open(args.file, encoding="utf-8").read()
    try:
        families = parse(text)
    except ValueError as exc:
        print(f"NOT Prometheus-parseable: {exc}", file=sys.stderr)
        return 1

    errors = histogram_errors(families)
    if len(families) < args.min_families:
        errors.append(f"only {len(families)} families, "
                      f"need >= {args.min_families}")
    for name in args.require + args.nonzero + args.require_exemplars:
        if name not in families:
            errors.append(f"missing family {name!r}")
    for name in args.nonzero:
        if name in families and family_total(families, name) <= 0:
            errors.append(f"family {name!r} total is zero")
    for name in args.require_exemplars:
        if name in families and not families[name]["exemplars"]:
            errors.append(f"family {name!r} carries no exemplars")
    if args.catalog:
        errors.extend(catalog_drift(
            families, open(args.catalog, encoding="utf-8").read()))
    if args.trace_json:
        with open(args.trace_json, encoding="utf-8") as f:
            doc = json.load(f)
        span_ids = {ev.get("id") for ev in doc.get("traceEvents", [])}
        for span_id in sorted(exemplar_span_ids(families)):
            if span_id not in span_ids:
                errors.append(f"exemplar span_id {span_id!r} not found "
                              f"in {args.trace_json}")
    for e in errors:
        print(e, file=sys.stderr)
    print(f"parsed {len(families)} metric families: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
