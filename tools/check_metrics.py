#!/usr/bin/env python
"""Prometheus text-exposition parser + assertion gate (stdlib only).

The service-fleet CI smoke pipes ``GET /v1/metrics`` output through this
to prove the endpoint is genuinely Prometheus-parseable (not just
200-OK text) and that the counters a healthy fleet run must move --
engine jobs, store traffic -- are present and non-zero::

    curl -s "$URL/v1/metrics" | python tools/check_metrics.py \
        --min-families 12 \
        --require cim_http_request_seconds \
        --nonzero cim_engine_jobs_total --nonzero cim_store_ops_total

Also importable: :func:`parse` returns ``{family: {"type", "help",
"samples": {labeled-name: value}}}`` and raises ``ValueError`` on any
malformed line, which the unit tests use for a render/parse round-trip.
"""
from __future__ import annotations

import argparse
import re
import sys

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
# label values are quoted and may contain '}' (e.g. route templates like
# /v1/jobs/{key}), so the block must be matched pair-by-pair, not [^}]*
_LBLOCK = r'\{(?:\s*[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"\s*,?)*\}'
_SAMPLE = re.compile(
    rf"^({_NAME})({_LBLOCK})?\s+(-?[0-9.eE+-]+|[+-]Inf|NaN)\s*$")
_LABELS = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
#: histogram/summary series carry these suffixes on the family name
_SUFFIXES = ("_bucket", "_sum", "_count")


def _family_of(sample_name: str, families: dict) -> str | None:
    if sample_name in families:
        return sample_name
    for suf in _SUFFIXES:
        if sample_name.endswith(suf) and sample_name[:-len(suf)] in families:
            return sample_name[:-len(suf)]
    return None


def parse(text: str) -> dict:
    """Parse Prometheus text exposition; raises ValueError on bad lines.

    Every sample must belong to a ``# TYPE``-declared family (histogram
    ``_bucket``/``_sum``/``_count`` series resolve to their base family).
    """
    families: dict[str, dict] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.rstrip()
        if not line:
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                raise ValueError(f"line {lineno}: malformed HELP")
            families.setdefault(
                parts[2], {"type": None, "help": "", "samples": {}})
            families[parts[2]]["help"] = parts[3] if len(parts) > 3 else ""
        elif line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {lineno}: malformed TYPE: {line!r}")
            families.setdefault(
                parts[2], {"type": None, "help": "", "samples": {}})
            families[parts[2]]["type"] = parts[3]
        elif line.startswith("#"):
            continue                                   # plain comment
        else:
            m = _SAMPLE.match(line)
            if not m:
                raise ValueError(f"line {lineno}: malformed sample: {line!r}")
            name, labels, value_s = m.group(1), m.group(2) or "", m.group(3)
            fam = _family_of(name, families)
            if fam is None:
                raise ValueError(
                    f"line {lineno}: sample {name!r} has no TYPE family")
            if labels:
                body = labels[1:-1].strip()
                if body and _LABELS.sub("", body).strip(", ") != "":
                    raise ValueError(
                        f"line {lineno}: malformed labels: {labels!r}")
            try:
                value = float(value_s.replace("Inf", "inf"))
            except ValueError as exc:
                raise ValueError(
                    f"line {lineno}: bad value {value_s!r}") from exc
            families[fam]["samples"][name + labels] = value
    for fam, rec in families.items():
        if rec["type"] is None:
            raise ValueError(f"family {fam!r} has samples but no TYPE")
    return families


def family_total(families: dict, name: str) -> float:
    """Sum of every sample in one family (histograms: the _count sum)."""
    rec = families.get(name)
    if rec is None:
        return 0.0
    if rec["type"] == "histogram":
        return sum(v for k, v in rec["samples"].items()
                   if k.startswith(f"{name}_count"))
    return sum(rec["samples"].values())


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("file", nargs="?", default="-",
                    help="exposition text file ('-' = stdin)")
    ap.add_argument("--min-families", type=int, default=0,
                    help="fail unless at least this many families parse")
    ap.add_argument("--require", action="append", default=[],
                    metavar="FAMILY", help="family that must be present")
    ap.add_argument("--nonzero", action="append", default=[],
                    metavar="FAMILY",
                    help="family whose sample total must be > 0")
    args = ap.parse_args(argv)

    text = sys.stdin.read() if args.file == "-" else \
        open(args.file, encoding="utf-8").read()
    try:
        families = parse(text)
    except ValueError as exc:
        print(f"NOT Prometheus-parseable: {exc}", file=sys.stderr)
        return 1

    errors = []
    if len(families) < args.min_families:
        errors.append(f"only {len(families)} families, "
                      f"need >= {args.min_families}")
    for name in args.require + args.nonzero:
        if name not in families:
            errors.append(f"missing family {name!r}")
    for name in args.nonzero:
        if name in families and family_total(families, name) <= 0:
            errors.append(f"family {name!r} total is zero")
    for e in errors:
        print(e, file=sys.stderr)
    print(f"parsed {len(families)} metric families: "
          f"{'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
