#!/usr/bin/env python
"""Markdown code-block linter (stdlib only; part of the CI `docs` job).

Walks the given markdown files/directories and checks every fenced code
block:

- fences must balance (an unclosed ``` swallows the rest of the file);
- ``python`` / ``py`` blocks must at least compile (``compile(...,
  "exec")``) -- blocks holding REPL transcripts (``>>>``) have their
  prompts stripped first;
- ``json`` blocks must ``json.loads``;
- every fence's info string must come from a known vocabulary, so typos
  like ```pyhton don't silently disable highlighting AND linting.

    python tools/lint_docs.py docs README.md ROADMAP.md
"""
from __future__ import annotations

import json
import os
import re
import sys

_FENCE = re.compile(r"^(```+|~~~+)\s*([\w+-]*)\s*$")
#: info strings we expect in this repo's docs; extend as docs grow
_KNOWN = {"", "python", "py", "json", "jsonl", "bash", "sh", "shell",
          "console", "text", "yaml", "toml", "ini", "diff", "makefile",
          "mermaid", "csv"}
_CHECK_PY = {"python", "py"}
_CHECK_JSON = {"json"}


def blocks_of(body: str, path: str) -> tuple[list[tuple[int, str, str]],
                                             list[str]]:
    """Fenced blocks of one file -> ([(lineno, lang, code)], errors)."""
    out: list[tuple[int, str, str]] = []
    errors: list[str] = []
    fence = None                     # (marker, lang, start_lineno, lines)
    for lineno, line in enumerate(body.splitlines(), 1):
        m = _FENCE.match(line.strip())
        if fence is None:
            if m:
                fence = (m.group(1)[0] * 3, m.group(2).lower(), lineno, [])
                if fence[1] not in _KNOWN:
                    errors.append(f"{path}:{lineno}: unknown code-fence "
                                  f"language {fence[1]!r}")
        elif m and m.group(1).startswith(fence[0]) and not m.group(2):
            out.append((fence[2], fence[1], "\n".join(fence[3])))
            fence = None
        else:
            fence[3].append(line)
    if fence is not None:
        errors.append(f"{path}:{fence[2]}: unclosed code fence")
    return out, errors


def _parse_json_stream(code: str) -> None:
    """Accept one JSON document OR several concatenated ones (docs often
    show alternative spellings of a request body in a single block)."""
    dec = json.JSONDecoder()
    idx, n = 0, len(code)
    while idx < n:
        while idx < n and code[idx].isspace():
            idx += 1
        if idx >= n:
            return
        _, idx = dec.raw_decode(code, idx)


def _strip_repl(code: str) -> str:
    """``>>> x`` / ``... y`` transcript -> the statements themselves."""
    lines = []
    for line in code.splitlines():
        s = line.strip()
        if s.startswith(">>> "):
            lines.append(s[4:])
        elif s.startswith("... "):
            lines.append(s[4:])
        elif s in (">>>", "..."):
            continue
        # plain lines in a transcript are output: drop them
    return "\n".join(lines)


def check_file(path: str) -> list[str]:
    """Code-block lint messages for one markdown file (empty = clean)."""
    with open(path, encoding="utf-8") as f:
        body = f.read()
    blocks, errors = blocks_of(body, path)
    for lineno, lang, code in blocks:
        if lang in _CHECK_PY:
            src = _strip_repl(code) if ">>>" in code else code
            try:
                compile(src, f"{path}:{lineno}", "exec")
            except SyntaxError as exc:
                errors.append(
                    f"{path}:{lineno}: python block does not compile: "
                    f"{exc.msg} (block line {exc.lineno})")
        elif lang in _CHECK_JSON:
            try:
                _parse_json_stream(code)
            except ValueError as exc:
                errors.append(f"{path}:{lineno}: invalid JSON block: {exc}")
    return errors


def collect(paths: list[str]) -> list[str]:
    """Every .md file under the given files/directories, sorted."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in files
                           if f.endswith(".md"))
        elif p.endswith(".md"):
            out.append(p)
        else:
            raise SystemExit(f"not a markdown file or directory: {p}")
    return sorted(out)


def main(argv: list[str]) -> int:
    files = collect(argv or ["docs", "README.md", "ROADMAP.md"])
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    errors = []
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"linted code blocks in {len(files)} files: "
          f"{'FAIL (' + str(len(errors)) + ')' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
