#!/usr/bin/env python
"""Grafana-dashboard <-> docs-catalog drift gate (stdlib only).

Walks every panel target in ``tools/grafana/cim-tuner.json``, extracts
the ``cim_*`` metric families referenced by PromQL ``expr`` strings
(normalizing ``_bucket`` / ``_sum`` / ``_count`` histogram-series
suffixes back to the family name), and fails unless each one appears in
the ``docs/observability.md`` metric-catalog table.  The docs CI job
runs::

    python tools/check_dashboard.py \
        --dashboard tools/grafana/cim-tuner.json \
        --catalog docs/observability.md

so a panel can never reference a metric the catalog does not document
-- the same catalog the service-fleet smoke diffs against the live
``/v1/metrics`` scrape, closing the dashboard -> docs -> scrape loop.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from check_metrics import catalog_families  # noqa: E402

_METRIC = re.compile(r"\bcim_[a-z0-9_]+\b")
_SUFFIXES = ("_bucket", "_sum", "_count")


def _panels(doc: dict):
    """Every panel, including ones nested inside row panels."""
    stack = list(doc.get("panels", []))
    while stack:
        panel = stack.pop()
        stack.extend(panel.get("panels", []))
        yield panel


def dashboard_families(doc: dict) -> dict[str, list[str]]:
    """``{family: [panel titles referencing it]}`` across the board."""
    out: dict[str, list[str]] = {}
    for panel in _panels(doc):
        title = panel.get("title", f"panel {panel.get('id', '?')}")
        for target in panel.get("targets", []):
            for name in _METRIC.findall(target.get("expr", "")):
                for suf in _SUFFIXES:
                    if name.endswith(suf):
                        name = name[:-len(suf)]
                        break
                out.setdefault(name, []).append(title)
    return out


def main(argv: list[str] | None = None) -> int:
    here = os.path.dirname(os.path.abspath(__file__))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dashboard",
                    default=os.path.join(here, "grafana", "cim-tuner.json"))
    ap.add_argument("--catalog",
                    default=os.path.join(here, os.pardir, "docs",
                                         "observability.md"))
    args = ap.parse_args(argv)

    with open(args.dashboard, encoding="utf-8") as f:
        doc = json.load(f)
    with open(args.catalog, encoding="utf-8") as f:
        documented = catalog_families(f.read())

    referenced = dashboard_families(doc)
    if not referenced:
        print("dashboard references no cim_* metrics", file=sys.stderr)
        return 1
    errors = []
    for name in sorted(set(referenced) - documented):
        panels = ", ".join(sorted(set(referenced[name])))
        errors.append(f"dashboard metric {name!r} (panels: {panels}) "
                      f"missing from the docs catalog")
    for e in errors:
        print(e, file=sys.stderr)
    print(f"dashboard references {len(referenced)} documented metric "
          f"families: {'FAIL' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
