#!/usr/bin/env python
"""Markdown intra-repo link checker (stdlib only; the CI `docs` job).

Scans the given markdown files/directories for inline links and images
(``[text](target)``), reference definitions (``[ref]: target``) and bare
relative targets, then fails (exit 1) when a non-external target does not
resolve to an existing file/directory, or when a ``#fragment`` does not
match any heading anchor in the target file (GitHub-style slugs).

External schemes (http/https/mailto) are deliberately NOT fetched -- CI
must stay hermetic and flake-free; this checker only guards the links we
fully control.

    python tools/check_links.py docs README.md ROADMAP.md
"""
from __future__ import annotations

import os
import re
import sys

# inline links/images: [text](target "title")  -- target up to ) or space
_INLINE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")
# reference definitions: [ref]: target
_REFDEF = re.compile(r"^\s*\[[^\]]+\]:\s+<?(\S+?)>?\s*$", re.M)
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$", re.M)
_CODE_FENCE = re.compile(r"```.*?```", re.S)
_INLINE_CODE = re.compile(r"`[^`\n]*`")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://", "data:")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of a heading line."""
    text = re.sub(r"[`*_]", "", heading)            # strip md emphasis
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)   # [t](url) -> t
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


def _anchors(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        body = _CODE_FENCE.sub("", f.read())
    return {_slug(m.group(2)) for m in _HEADING.finditer(body)}


def _targets(body: str) -> list[str]:
    body = _CODE_FENCE.sub("", body)
    body = _INLINE_CODE.sub("", body)
    return [m.group(1) for m in _INLINE.finditer(body)] + \
        [m.group(1) for m in _REFDEF.finditer(body)]


def check_file(md_path: str) -> list[str]:
    """Broken-link messages for one markdown file (empty = clean)."""
    errors = []
    with open(md_path, encoding="utf-8") as f:
        body = f.read()
    base = os.path.dirname(os.path.abspath(md_path))
    for target in _targets(body):
        if target.startswith(_EXTERNAL):
            continue
        path, _, frag = target.partition("#")
        if not path:                                   # same-file anchor
            dest = os.path.abspath(md_path)
        else:
            dest = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(dest):
                errors.append(f"{md_path}: broken link -> {target}")
                continue
        if frag and dest.endswith(".md") and os.path.isfile(dest):
            if _slug(frag) not in _anchors(dest):
                errors.append(
                    f"{md_path}: missing anchor -> {target}")
    return errors


def collect(paths: list[str]) -> list[str]:
    """Every .md file under the given files/directories, sorted."""
    out = []
    for p in paths:
        if os.path.isdir(p):
            for root, _dirs, files in os.walk(p):
                out.extend(os.path.join(root, f) for f in files
                           if f.endswith(".md"))
        elif p.endswith(".md"):
            out.append(p)
        else:
            raise SystemExit(f"not a markdown file or directory: {p}")
    return sorted(out)


def main(argv: list[str]) -> int:
    files = collect(argv or ["docs", "README.md", "ROADMAP.md"])
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1
    errors = []
    for md in files:
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'FAIL (' + str(len(errors)) + ' broken)' if errors else 'ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
