"""Paper Fig. 8: energy breakdown of three Bert-large operators under two
CIM macros (FPCIM-like long-AL vs LCC-CIM-like short-AL) for the MS-1
(NR-IP-AF) vs MS-2 (NR-IP-PF) strategies on fixed hardware
(MR,MC,SCR,IS,OS) = (2,2,16,1024,128).

Claims reproduced: AF trades Input-SRAM energy for Output-SRAM relief; PF
the reverse; with the limited 128 KB OS, PF spills partial sums to external
memory (EMA), which blows up energy -- worse for the short-AL macro."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_line, timed
from repro.core import AcceleratorConfig, Strategy, get_macro
from repro.core.calibration import DEFAULT_TECH
from repro.core.cost_model import area_mm2_jnp, matmul_cost
from repro.core.ir import bert_large_fig8_ops

CFG = AcceleratorConfig(2, 2, 16, 1024, 128)
STRATS = {"MS-1": Strategy("NR", "IP", "AF"), "MS-2": Strategy("NR", "IP", "PF")}


def breakdown(macro, op, strat) -> dict:
    cfg_row = jnp.asarray(
        [CFG.mr, CFG.mc, CFG.scr, CFG.is_kb, CFG.os_kb, CFG.bw], dtype=float)
    cb = matmul_cost(
        op.m, op.k, op.n,
        float(strat.spatial == "R"), float(strat.temporal == "WP"),
        float(strat.tiling == "PF"),
        CFG.mr, CFG.mc, CFG.scr, CFG.is_kb, CFG.os_kb, CFG.bw,
        area_mm2_jnp(cfg_row, macro), macro)
    t = DEFAULT_TECH
    return {
        "mac": float(cb.macs) * macro.mac_energy_pj(t),
        "is": float(cb.is_rd_bits + cb.is_wr_bits) * t.e_sram_rd_pj_bit,
        "os": float(cb.os_rd_bits + cb.os_wr_bits) * t.e_sram_rd_pj_bit,
        "ema": float(cb.ema_bits) * t.e_ema_pj_bit,
        "spill": float(cb.spill_ema_bits) * t.e_ema_pj_bit,
    }


def run() -> list[str]:
    lines = []
    checks = []
    for mname in ("fpcim", "lcc-cim"):
        macro = get_macro(mname)
        for op in bert_large_fig8_ops().ops:
            rows, dt = timed(lambda: {
                k: breakdown(macro, op, s) for k, s in STRATS.items()})
            af, pf = rows["MS-1"], rows["MS-2"]
            checks.append((mname, op.name,
                           af["is"] >= pf["is"],       # AF reads IS more
                           pf["os"] >= af["os"],       # PF hits OS more
                           pf["spill"] >= af["spill"]))
            tot_af = sum(af.values()) - af["spill"]
            tot_pf = sum(pf.values()) - pf["spill"]
            lines.append(csv_line(
                f"fig8_{mname}_{op.name}", dt * 1e6,
                f"AF(pJ): is={af['is']:.3g} os={af['os']:.3g} "
                f"ema={af['ema']:.3g} total={tot_af:.3g} | "
                f"PF: is={pf['is']:.3g} os={pf['os']:.3g} "
                f"ema={pf['ema']:.3g} (spill={pf['spill']:.3g}) "
                f"total={tot_pf:.3g}"))
    ok = all(c[2] and c[3] and c[4] for c in checks)
    lines.append(csv_line(
        "fig8_claims", 0.0,
        f"AF>=PF IS-energy, PF>=AF OS-energy, PF>=AF spill: all={ok}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
