"""Poisson load test of the continuous-batching scheduler.

Drives ``JobQueue.submit()`` with a seeded Poisson arrival stream and
measures what the ROADMAP's serving-engine rewrite is judged on:
sustained jobs/sec, p50/p95 submit-to-resolve latency, and the
admission-join rate (fraction of submissions that entered an in-flight
race at a rung boundary instead of waiting out a window).

Two scheduler legs run the SAME arrival schedule at equal budget:

* ``continuous`` -- ``QueueConfig(continuous=True)``: late arrivals
  matching the in-flight ``(bucket, method, settings)`` group join its
  next bandit wave (docs/scheduler.md);
* ``window`` -- ``QueueConfig(continuous=False)``: the pre-scheduler
  fixed-window path, where every dispatch is a closed world and late
  arrivals queue for the next window behind it.

Both legs share the same ``max_batch_jobs`` lane cap (the per-dispatch
ceiling of one batched executable).  Under saturation that cap is what
separates the schedulers: the window leg must run ``ceil(N / cap)``
full races back to back, while the continuous leg streams the backlog
into one race in ``cap``-sized slices at rung boundaries, overlapping
newcomers' early waves with veterans' late waves.

The default engine is :class:`RungSimEngine`, a deterministic stub that
models the engine's batched race at wall-clock fidelity: every bandit
wave costs a fixed sleep REGARDLESS of how many jobs ride it (the vmap
property -- per-job rows are lanes of one batched executable), and the
admission hook is polled between waves exactly like the real engine
does.  That isolates scheduling policy from JAX compile noise, so the
CI smoke gate (``--min-speedup``) is stable; ``--engine real`` runs the
same arrival stream against a real :class:`ExplorationEngine` (nightly
soak -- asserts every future resolves, reports the same stats).

    PYTHONPATH=src python -m benchmarks.load_test --smoke --min-speedup 1.5
    PYTHONPATH=src python -m benchmarks.load_test --jobs 32 --rate 8
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

import numpy as np

from repro.core import DesignSpace, ExploreJob, bert_large_workload
from repro.core.macro import TPDCIM_MACRO
from repro.search import PortfolioSettings
from repro.service.queue import JobQueue, QueueConfig

#: tiny space shared by every generated job (one executable bucket, so
#: every submission is admission-compatible with the in-flight group)
SPACE = DesignSpace(mr=(1, 2, 3), mc=(1, 2), scr=(1, 4, 16),
                    is_kb=(2, 16, 128), os_kb=(2, 16, 64))


class RungSimEngine:
    """Deterministic stand-in for ``ExplorationEngine`` (stub leg).

    ``run()`` simulates a bandit-portfolio race: each wave is one
    ``wave_s`` sleep shared by every job currently racing, each job
    needs ``waves`` waves to finish, and the ``admit`` hook -- when the
    queue provides one -- is polled between waves; admitted jobs start
    their own ``waves``-wave schedule mid-race and their results come
    back appended behind the dispatched batch, exactly like the real
    engine's contract."""

    def __init__(self, waves: int = 8, wave_s: float = 0.025):
        self.waves = int(waves)
        self.wave_s = float(wave_s)
        self.calls = 0
        self.waves_run = 0

    def bucket_key(self, job, method=None) -> tuple:
        """Every load-test job shares one executable bucket."""
        return (method or "portfolio", 8, 8)

    def run(self, jobs, method=None, settings=None, sa_settings=None,
            keys=None, admit=None):
        """Race ``jobs`` (plus any rung admissions) to completion."""
        self.calls += 1
        remaining = {i: self.waves for i in range(len(jobs))}
        order = list(range(len(jobs)))
        finished = {}
        while remaining:
            if admit is not None:
                for _job, _key in admit():
                    i = len(order)
                    order.append(i)
                    remaining[i] = self.waves
            time.sleep(self.wave_s)
            self.waves_run += 1
            for i in list(remaining):
                remaining[i] -= 1
                if remaining[i] <= 0:
                    del remaining[i]
                    finished[i] = {"search": {"method": "portfolio",
                                              "waves": self.waves}}
        return [finished[i] for i in order]


def make_jobs(n: int) -> list[ExploreJob]:
    """``n`` distinct jobs (unique area budgets -> unique job keys) that
    all share one executable bucket and settings signature."""
    wl = bert_large_workload()
    return [ExploreJob(TPDCIM_MACRO, wl, 2.23 + i * 1e-6,
                       objective="ee", space=SPACE)
            for i in range(n)]


def poisson_offsets(n: int, rate: float, seed: int) -> np.ndarray:
    """Seeded cumulative Poisson arrival offsets (seconds from t0)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def run_leg(scheduler: str, jobs: list[ExploreJob],
            offsets: np.ndarray, settings: PortfolioSettings,
            engine, max_batch: int = 4,
            window_s: float = 0.01) -> dict:
    """Submit ``jobs`` at ``offsets`` against a fresh queue and collect
    the leg's throughput/latency/admission stats."""
    q = JobQueue(engine=engine, store=None,
                 config=QueueConfig(batch_window_s=window_s,
                                    max_batch_jobs=max_batch,
                                    continuous=scheduler == "continuous"))
    resolved_at = {}
    lock = threading.Lock()

    def on_done(f, i=None):
        with lock:
            resolved_at[i] = time.perf_counter()

    t0 = time.perf_counter()
    submit_at = {}
    futures = []
    for i, job in enumerate(jobs):
        delay = t0 + float(offsets[i]) - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        submit_at[i] = time.perf_counter()
        f = q.submit(job, method="portfolio", settings=settings)
        f.add_done_callback(
            lambda fut, i=i: on_done(fut, i))
        futures.append(f)
    for f in futures:
        f.wait(120)
    t_end = max(resolved_at.values())
    snap = q.stats_snapshot()
    q.close()
    lat = np.asarray(sorted(resolved_at[i] - submit_at[i]
                            for i in range(len(jobs))))
    failed = sum(1 for f in futures if f.exception(0) is not None)
    return {
        "scheduler": scheduler,
        "jobs": len(jobs),
        "failed": failed,
        "jobs_per_s": len(jobs) / (t_end - submit_at[0]),
        "p50_s": float(np.percentile(lat, 50)),
        "p95_s": float(np.percentile(lat, 95)),
        "admitted": snap["scheduler"]["admitted"],
        "admission_rate": snap["scheduler"]["admitted"] / len(jobs),
        "dispatches": snap["queue"]["dispatches"],
    }


def run_load_test(n_jobs: int = 16, rate: float = 150.0, waves: int = 8,
                  wave_ms: float = 25.0, seed: int = 0,
                  scheduler: str = "both", engine_kind: str = "stub",
                  max_batch: int = 4) -> dict:
    """Run the requested scheduler leg(s) over one seeded arrival
    schedule; returns ``{"legs": [...], "speedup": float | None}``."""
    jobs = make_jobs(n_jobs)
    offsets = poisson_offsets(n_jobs, rate, seed)
    # equal budget across legs: same settings object, same arrival
    # schedule, fresh engine+queue per leg
    if engine_kind == "stub":
        settings = PortfolioSettings(backends=("sa", "sobol"),
                                     total_evals=64, rungs=max(1, waves // 2),
                                     seed=seed)

        def fresh_engine():
            return RungSimEngine(waves=waves, wave_s=wave_ms / 1e3)
    elif engine_kind == "real":
        from repro.core import ExplorationEngine
        settings = PortfolioSettings(backends=("sa", "sobol"),
                                     total_evals=64, rungs=4, seed=seed)

        def fresh_engine():
            return ExplorationEngine()
    else:
        raise ValueError(f"unknown engine kind {engine_kind!r}")

    legs = []
    wanted = ("continuous", "window") if scheduler == "both" \
        else (scheduler,)
    for name in wanted:
        legs.append(run_leg(name, jobs, offsets, settings, fresh_engine(),
                            max_batch=max_batch))
    by = {leg["scheduler"]: leg for leg in legs}
    speedup = None
    if "continuous" in by and "window" in by:
        speedup = by["continuous"]["jobs_per_s"] / by["window"]["jobs_per_s"]
    return {"legs": legs, "speedup": speedup}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--jobs", type=int, default=16,
                    help="total submissions in the arrival stream")
    ap.add_argument("--rate", type=float, default=150.0,
                    help="Poisson arrival rate, jobs/second (default "
                         "saturates the lane cap)")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="per-dispatch / per-admission lane cap "
                         "(QueueConfig.max_batch_jobs, both legs)")
    ap.add_argument("--waves", type=int, default=8,
                    help="bandit waves per job (stub engine)")
    ap.add_argument("--wave-ms", type=float, default=25.0,
                    help="wall-clock cost of one batched wave (stub)")
    ap.add_argument("--seed", type=int, default=0,
                    help="arrival-schedule RNG seed")
    ap.add_argument("--scheduler", default="both",
                    choices=("both", "continuous", "window"))
    ap.add_argument("--engine", default="stub", choices=("stub", "real"),
                    dest="engine_kind")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="exit 1 unless continuous/window jobs/sec "
                         "ratio reaches this (needs --scheduler both)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer jobs, shorter waves)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.jobs, args.waves, args.wave_ms = 12, 8, 25.0

    out = run_load_test(args.jobs, args.rate, args.waves, args.wave_ms,
                        args.seed, args.scheduler, args.engine_kind,
                        args.max_batch)
    for leg in out["legs"]:
        print(f"load_test/{leg['scheduler']}/us_per_job,"
              f"{1e6 / leg['jobs_per_s']:.1f},"
              f"jobs_per_s={leg['jobs_per_s']:.2f} "
              f"p50_s={leg['p50_s']:.3f} p95_s={leg['p95_s']:.3f} "
              f"admission_rate={leg['admission_rate']:.2f} "
              f"dispatches={leg['dispatches']} failed={leg['failed']}",
              flush=True)
        if leg["failed"]:
            print(f"# FAIL: {leg['failed']} submissions errored",
                  flush=True)
            return 1
    if out["speedup"] is not None:
        print(f"# continuous vs window speedup: {out['speedup']:.2f}x",
              flush=True)
    if args.min_speedup is not None:
        if out["speedup"] is None:
            print("# --min-speedup needs --scheduler both", flush=True)
            return 2
        if out["speedup"] < args.min_speedup:
            print(f"# FAIL: speedup {out['speedup']:.2f}x < "
                  f"{args.min_speedup}x", flush=True)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
