"""Paper Fig. 2(b): matrix-multiplication latency across compute/storage
proportions and mapping strategies on the CIM template.

Sweep: fixed ~5 mm^2 budget, trade macro-grid size (compute) against SCR +
IS size (storage); evaluate the same matmul under input-priority vs
weight-priority updates.  Reproduces both claims: (1) >4x latency spread
across hardware proportions, (2) IP and WP curves differ qualitatively on
the same hardware."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import csv_line, timed
from repro.core import AcceleratorConfig, get_macro
from repro.core.cost_model import workload_cost_core, _STRAT_BITS
from repro.core.ir import MatmulOp, Workload
from repro.core.strategies import ALL_STRATEGIES
from repro.core.template import accelerator_area_mm2

# compute-heavy ......................................... storage-heavy
SWEEP = [
    AcceleratorConfig(6, 6, 1, 4, 4),
    AcceleratorConfig(4, 6, 2, 8, 8),
    AcceleratorConfig(4, 4, 4, 16, 16),
    AcceleratorConfig(3, 4, 8, 32, 16),
    AcceleratorConfig(2, 4, 8, 64, 32),
    AcceleratorConfig(2, 2, 16, 128, 64),
    AcceleratorConfig(1, 2, 32, 256, 64),
    AcceleratorConfig(1, 1, 64, 512, 128),
]

OP = MatmulOp(512, 4096, 4096, name="gemm")


def _latency(cfg: AcceleratorConfig, temporal: str, macro) -> float:
    ops = Workload("one", (OP,)).as_arrays()
    mask = jnp.array([
        1.0 if s.temporal == temporal and s.spatial == "NR"
        and s.tiling == "AF" else 0.0 for s in ALL_STRATEGIES])
    cfg_row = jnp.asarray([cfg.mr, cfg.mc, cfg.scr, cfg.is_kb, cfg.os_kb,
                           cfg.bw], dtype=float)
    lat, _en, _ = workload_cost_core(
        jnp.asarray(ops), cfg_row, _STRAT_BITS, mask, macro,
        objective="th")
    return float(lat)


def run() -> list[str]:
    macro = get_macro("vanilla-dcim")
    lines = []

    def sweep():
        out = {}
        for temporal in ("IP", "WP"):
            out[temporal] = [
                (cfg.as_tuple(), accelerator_area_mm2(cfg, macro),
                 _latency(cfg, temporal, macro))
                for cfg in SWEEP]
        return out

    out, dt = timed(sweep)
    for temporal, rows in out.items():
        lats = [r[2] for r in rows]
        feas = [l for l in lats if l < 1e29]     # WP infeasible on tiny IS
        spread = max(feas) / min(feas)
        best_i = lats.index(min(lats))
        curve = ";".join(f"{t[0]}x{t[1]}xSCR{t[2]}:{l:.3g}"
                         for (t, _a, l) in rows)
        lines.append(csv_line(
            f"fig2_{temporal}", dt * 1e6 / 2,
            f"latency_spread={spread:.2f}x best_idx={best_i} {curve}"))
    # the two temporal schedules must prefer different hardware points
    ip_best = min(range(len(SWEEP)), key=lambda i: out["IP"][i][2])
    wp_best = min(range(len(SWEEP)), key=lambda i: out["WP"][i][2])
    lines.append(csv_line(
        "fig2_strategies_differ", 0.0,
        f"ip_best_idx={ip_best} wp_best_idx={wp_best} "
        f"differ={ip_best != wp_best}"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
