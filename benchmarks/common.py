"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time

from repro.configs import get_arch
from repro.core.ir import Workload, bert_large_workload

#: the seven evaluation networks of Fig. 7 / Fig. 9 (the paper does not name
#: them; we use Bert-large + six assigned architectures' operator mixes)
SEVEN_WORKLOADS = (
    "bert-large", "yi-6b", "gemma-7b", "falcon-mamba-7b",
    "granite-moe-3b-a800m", "mixtral-8x7b", "whisper-small",
)


def get_workload(name: str, seq: int = 512) -> Workload:
    if name == "bert-large":
        return bert_large_workload(seq)
    return get_arch(name).workload(seq=seq)


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def geomean(xs):
    import math
    xs = list(xs)
    return math.exp(sum(math.log(max(x, 1e-12)) for x in xs) / len(xs))
