"""Paper Table II: CIM-Tuner applied to two SOTA accelerators (TranCIM [10],
TP-DCIM [16]) on Bert-Large with the area budget fixed at the baseline area;
co-exploration re-balances (MR, MC, SCR, IS, OS) for energy efficiency (EE.)
and throughput (Th.) separately.  Other hardware parameters (macro, BW) are
fixed, as in the paper.

The four (macro x objective) explorations run as ONE engine batch: macro
constants are per-job arrays inside a shared compiled executable."""
from __future__ import annotations

from benchmarks.common import csv_line, timed
from repro.core import (
    AcceleratorConfig,
    ExplorationEngine,
    ExploreJob,
    evaluate_config,
)
from repro.core.ir import bert_large_workload
from repro.core.macro import TPDCIM_MACRO, TRANCIM_MACRO
from repro.core.template import accelerator_area_mm2

BASELINES = {
    "TranCIM": (TRANCIM_MACRO, AcceleratorConfig(3, 1, 1, 64, 128),
                {"ee": 2.54, "th": 1002.3, "area": 3.52,
                 "ee_gain": 1.34, "th_gain": 1.03}),
    "TP-DCIM": (TPDCIM_MACRO, AcceleratorConfig(2, 4, 1, 16, 16),
                {"ee": 1.89, "th": 460.9, "area": 2.23,
                 "ee_gain": 2.31, "th_gain": 2.88}),
}


def run() -> list[str]:
    wl = bert_large_workload()
    engine = ExplorationEngine()

    jobs, budgets = [], {}
    for name, (macro, base_cfg, _paper) in BASELINES.items():
        budget = accelerator_area_mm2(base_cfg, macro)
        budgets[name] = budget
        for obj in ("ee", "th"):
            jobs.append(ExploreJob(macro, wl, budget, objective=obj))
    explored, dt = timed(engine.run, jobs, method="exhaustive")
    by_key = {(name, obj): r
              for (name, obj), r in zip(
                  [(n, o) for n in BASELINES for o in ("ee", "th")],
                  explored)}

    lines = []
    for name, (macro, base_cfg, paper) in BASELINES.items():
        budget = budgets[name]
        base = evaluate_config(macro, base_cfg, wl)
        ee, th = by_key[(name, "ee")], by_key[(name, "th")]
        ee_gain = ee.metrics["tops_w"] / base["tops_w"]
        th_gain = th.metrics["gops"] / base["gops"]
        lines.append(csv_line(
            f"table2_{name}_base", dt * 1e6 / len(BASELINES),
            f"cfg={base_cfg.as_tuple()} EE={base['tops_w']:.2f} TOPS/W "
            f"(paper {paper['ee']}) Th={base['gops']:.0f} GOPS "
            f"(paper {paper['th']}) area={budget:.2f} (paper {paper['area']})"))
        lines.append(csv_line(
            f"table2_{name}_EE", 0.0,
            f"cfg={ee.config.as_tuple()} EE={ee.metrics['tops_w']:.2f} TOPS/W "
            f"area={ee.metrics['area_mm2']:.2f} gain=x{ee_gain:.2f} "
            f"(paper x{paper['ee_gain']})"))
        lines.append(csv_line(
            f"table2_{name}_Th", 0.0,
            f"cfg={th.config.as_tuple()} Th={th.metrics['gops']:.0f} GOPS "
            f"area={th.metrics['area_mm2']:.2f} gain=x{th_gain:.2f} "
            f"(paper x{paper['th_gain']})"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
