"""Paper Table II: CIM-Tuner applied to two SOTA accelerators (TranCIM [10],
TP-DCIM [16]) on Bert-Large with the area budget fixed at the baseline area;
co-exploration re-balances (MR, MC, SCR, IS, OS) for energy efficiency (EE.)
and throughput (Th.) separately.  Other hardware parameters (macro, BW) are
fixed, as in the paper.

The four (macro x objective) explorations go through the async DSE service;
``run()`` is a generator that yields each accelerator's three rows (base,
EE., Th.) as soon as both of its explorations complete -- the first
accelerator's results print while the second is still sweeping."""
from __future__ import annotations

import time
import typing

from benchmarks.common import csv_line
from repro.core import (
    AcceleratorConfig,
    ExplorationEngine,
    ExploreJob,
    evaluate_config,
)
from repro.core.ir import bert_large_workload
from repro.core.macro import TPDCIM_MACRO, TRANCIM_MACRO
from repro.core.template import accelerator_area_mm2
from repro.service import ServiceClient, as_completed

STREAM_TIMEOUT_S = 1800.0

BASELINES = {
    "TranCIM": (TRANCIM_MACRO, AcceleratorConfig(3, 1, 1, 64, 128),
                {"ee": 2.54, "th": 1002.3, "area": 3.52,
                 "ee_gain": 1.34, "th_gain": 1.03}),
    "TP-DCIM": (TPDCIM_MACRO, AcceleratorConfig(2, 4, 1, 16, 16),
                {"ee": 1.89, "th": 460.9, "area": 2.23,
                 "ee_gain": 2.31, "th_gain": 2.88}),
}


def run() -> typing.Iterator[str]:
    wl = bert_large_workload()
    svc = ServiceClient(engine=ExplorationEngine())
    try:
        jobs, metas, budgets = [], [], {}
        for name, (macro, base_cfg, _paper) in BASELINES.items():
            budget = accelerator_area_mm2(base_cfg, macro)
            budgets[name] = budget
            for obj in ("ee", "th"):
                jobs.append(ExploreJob(macro, wl, budget, objective=obj))
                metas.append((name, obj))
        t0 = time.perf_counter()
        futures = svc.submit_many(jobs, method="exhaustive", metas=metas)

        explored: dict[str, dict] = {name: {} for name in BASELINES}
        t_last = t0
        for fut in as_completed(futures, timeout=STREAM_TIMEOUT_S):
            name, obj = fut.meta
            explored[name][obj] = fut.result()
            if len(explored[name]) < 2:
                continue
            macro, base_cfg, paper = BASELINES[name]
            budget = budgets[name]
            # marginal wall-clock to produce this accelerator's rows
            t_now = time.perf_counter()
            dt_row, t_last = t_now - t_last, t_now
            base = evaluate_config(macro, base_cfg, wl)
            ee, th = explored[name]["ee"], explored[name]["th"]
            ee_gain = ee.metrics["tops_w"] / base["tops_w"]
            th_gain = th.metrics["gops"] / base["gops"]
            yield csv_line(
                f"table2_{name}_base", dt_row * 1e6,
                f"cfg={base_cfg.as_tuple()} EE={base['tops_w']:.2f} TOPS/W "
                f"(paper {paper['ee']}) Th={base['gops']:.0f} GOPS "
                f"(paper {paper['th']}) area={budget:.2f} "
                f"(paper {paper['area']})")
            yield csv_line(
                f"table2_{name}_EE", 0.0,
                f"cfg={ee.config.as_tuple()} "
                f"EE={ee.metrics['tops_w']:.2f} TOPS/W "
                f"area={ee.metrics['area_mm2']:.2f} gain=x{ee_gain:.2f} "
                f"(paper x{paper['ee_gain']})")
            yield csv_line(
                f"table2_{name}_Th", 0.0,
                f"cfg={th.config.as_tuple()} "
                f"Th={th.metrics['gops']:.0f} GOPS "
                f"area={th.metrics['area_mm2']:.2f} gain=x{th_gain:.2f} "
                f"(paper x{paper['th_gain']})")
    finally:
        svc.close()


if __name__ == "__main__":
    for line in run():
        print(line, flush=True)
