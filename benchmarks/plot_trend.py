"""Trend report over nightly ``results.jsonl`` benchmark artifacts.

``benchmarks/run.py --jsonl`` writes one record per figure/table (module,
status, elapsed_s, parsed rows); the nightly CI job uploads it as a 90-day
artifact.  This script ingests one or more of those files -- downloaded
artifacts, local runs, whatever -- and prints the per-module timing trend
plus the largest per-row ``us_per_call`` regressions between the oldest
and newest artifact.  With ``--plot`` it also renders a PNG (matplotlib
optional; the textual report never needs it).

    PYTHONPATH=src python -m benchmarks.plot_trend night1.jsonl night2.jsonl
    PYTHONPATH=src python -m benchmarks.plot_trend *.jsonl --plot trend.png
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load_artifact(path: str) -> dict:
    """One results.jsonl -> {label, created_s, modules: {name: record}}."""
    modules: dict[str, dict] = {}
    created = None
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("module") == "_summary":
                created = rec.get("created_s")
            else:
                modules[rec["module"]] = rec
    if created is None:
        created = os.path.getmtime(path)
    return {"label": os.path.basename(path), "created_s": created,
            "modules": modules}


def _fmt(v) -> str:
    return f"{v:9.1f}" if isinstance(v, (int, float)) else f"{'-':>9}"


def module_trend_lines(artifacts: list[dict]) -> list[str]:
    """Per-module elapsed_s across artifacts (oldest -> newest)."""
    names: list[str] = []
    for a in artifacts:
        for m in a["modules"]:
            if m not in names:
                names.append(m)
    head = f"{'module':24}" + "".join(
        f"{a['label'][:16]:>18}" for a in artifacts) + "   trend"
    out = [head, "-" * len(head)]
    for m in names:
        cells, vals = [], []
        for a in artifacts:
            rec = a["modules"].get(m)
            ok = rec is not None and rec.get("status") == "ok"
            el = rec.get("elapsed_s") if ok else None
            vals.append(el)
            cell = f"{el:.1f}s" if el is not None else (
                "FAILED" if rec is not None else "-")
            cells.append(f"{cell:>18}")
        known = [v for v in vals if v is not None]
        trend = ""
        if len(known) >= 2 and known[0]:
            trend = f"x{known[-1] / known[0]:.2f}"
        out.append(f"{m:24}" + "".join(cells) + f"   {trend}")
    return out


def _rows_of(a: dict) -> dict[str, float]:
    out = {}
    for rec in a["modules"].values():
        for row in rec.get("rows", []):
            if isinstance(row.get("us_per_call"), (int, float)) \
                    and row["us_per_call"] > 0:
                out[row["name"]] = row["us_per_call"]
    return out


def row_regression_lines(artifacts: list[dict], top: int = 10) -> list[str]:
    """Largest us_per_call ratios between the oldest and newest artifact."""
    if len(artifacts) < 2:
        return []
    old, new = artifacts[0], artifacts[-1]
    o, n = _rows_of(old), _rows_of(new)
    shared = sorted(set(o) & set(n), key=lambda k: n[k] / o[k], reverse=True)
    if not shared:
        return []
    out = [f"top row-level changes ({old['label']} -> {new['label']}):"]
    for k in shared[:top]:
        out.append(f"  {k:40} {o[k]:12.1f} -> {n[k]:12.1f} us  "
                   f"x{n[k] / o[k]:.2f}")
    return out


def regression_gate(artifacts: list[dict],
                    threshold: float) -> tuple[list[str], list[str]]:
    """The nightly regression gate: a full per-row delta table between the
    oldest and newest artifact (markdown, for the CI job summary) plus the
    rows whose ``us_per_call`` ratio breaches ``threshold`` (the job fails
    when any do).  Rows present in only one artifact are reported but
    never gate -- module sets change across PRs."""
    if len(artifacts) < 2:
        return [], []
    old, new = artifacts[0], artifacts[-1]
    o, n = _rows_of(old), _rows_of(new)
    table = [f"| row | {old['label']} (us) | {new['label']} (us) "
             "| ratio | status |",
             "|---|---:|---:|---:|---|"]
    breaches: list[str] = []
    for k in sorted(set(o) | set(n)):
        if k in o and k in n:
            ratio = n[k] / o[k]
            bad = ratio > threshold
            status = f"REGRESSED (> x{threshold:g})" if bad else "ok"
            table.append(f"| {k} | {o[k]:.1f} | {n[k]:.1f} "
                         f"| x{ratio:.2f} | {status} |")
            if bad:
                breaches.append(f"{k}: {o[k]:.1f} -> {n[k]:.1f} us "
                                f"(x{ratio:.2f} > x{threshold:g})")
        elif k in n:
            table.append(f"| {k} | - | {n[k]:.1f} | - | new |")
        else:
            table.append(f"| {k} | {o[k]:.1f} | - | - | removed |")
    return table, breaches


def maybe_plot(artifacts: list[dict], path: str) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("matplotlib not installed; skipping --plot", file=sys.stderr)
        return False
    names = sorted({m for a in artifacts for m in a["modules"]})
    xs = list(range(len(artifacts)))
    fig, ax = plt.subplots(figsize=(8, 4.5))
    for m in names:
        ys = [a["modules"].get(m, {}).get("elapsed_s") for a in artifacts]
        ax.plot(xs, ys, marker="o", label=m)
    ax.set_xticks(xs, [a["label"][:16] for a in artifacts],
                  rotation=30, ha="right")
    ax.set_ylabel("elapsed_s")
    ax.set_title("benchmark timing trend")
    ax.legend(fontsize=7)
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    return True


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", help="results.jsonl artifacts")
    ap.add_argument("--plot", default=None, metavar="PNG",
                    help="also render a timing-trend plot")
    ap.add_argument("--top", type=int, default=10,
                    help="row-level regressions to show")
    ap.add_argument("--fail-threshold", type=float, default=None,
                    metavar="RATIO",
                    help="exit 1 when any shared row's us_per_call ratio "
                         "(newest / oldest) exceeds RATIO; prints the "
                         "full per-row delta table (markdown)")
    ap.add_argument("--summary", default=None, metavar="MD",
                    help="with --fail-threshold: also write the markdown "
                         "delta table to this file (for CI job summaries)")
    args = ap.parse_args(argv)

    artifacts = sorted((load_artifact(p) for p in args.files),
                       key=lambda a: a["created_s"])
    for line in module_trend_lines(artifacts):
        print(line)
    reg = row_regression_lines(artifacts, args.top)
    if reg:
        print()
        for line in reg:
            print(line)
    breaches: list[str] = []
    if args.fail_threshold is not None:
        table, breaches = regression_gate(artifacts, args.fail_threshold)
        if table:
            print()
            for line in table:
                print(line)
        if args.summary and table:
            verdict = (f"{len(breaches)} row(s) beyond x"
                       f"{args.fail_threshold:g}" if breaches
                       else f"no row beyond x{args.fail_threshold:g}")
            with open(args.summary, "w") as f:
                f.write(f"## Benchmark trend gate: {verdict}\n\n")
                f.write("\n".join(table) + "\n")
        for b in breaches:
            print(f"REGRESSION: {b}", file=sys.stderr)
    if args.plot and maybe_plot(artifacts, args.plot):
        print(f"\nwrote {args.plot}")
    return 1 if breaches else 0


if __name__ == "__main__":
    sys.exit(main())
