"""Paper Fig. 7: CIM-Tuner's scheduling+tiling (ST) space vs the spatial-
only (SO) mapping of [19], under the SAME hardware-mapping co-exploration
with a 5 mm^2 budget, across the seven evaluation networks.

Paper claims: average 1.58x energy efficiency and 2.11x throughput."""
from __future__ import annotations

from benchmarks.common import SEVEN_WORKLOADS, csv_line, geomean, get_workload, timed
from repro.core import DesignSpace, co_explore, get_macro

BUDGET = 5.0


def one_network(name: str, macro) -> dict:
    wl = get_workload(name)
    out = {}
    for sset in ("so", "st"):
        ee = co_explore(macro, wl, BUDGET, objective="ee",
                        strategy_set=sset, method="exhaustive")
        th = co_explore(macro, wl, BUDGET, objective="th",
                        strategy_set=sset, method="exhaustive")
        out[sset] = {"tops_w": ee.metrics["tops_w"],
                     "gops": th.metrics["gops"],
                     "ee_cfg": ee.config.as_tuple(),
                     "th_cfg": th.config.as_tuple()}
    out["ee_gain"] = out["st"]["tops_w"] / out["so"]["tops_w"]
    out["th_gain"] = out["st"]["gops"] / out["so"]["gops"]
    return out


def run() -> list[str]:
    macro = get_macro("vanilla-dcim")
    lines = []
    ee_gains, th_gains = [], []
    for name in SEVEN_WORKLOADS:
        res, dt = timed(one_network, name, macro)
        ee_gains.append(res["ee_gain"])
        th_gains.append(res["th_gain"])
        lines.append(csv_line(
            f"fig7_{name}", dt * 1e6,
            f"EE {res['so']['tops_w']:.2f}->{res['st']['tops_w']:.2f} "
            f"TOPS/W (x{res['ee_gain']:.2f})  "
            f"Th {res['so']['gops']:.0f}->{res['st']['gops']:.0f} GOPS "
            f"(x{res['th_gain']:.2f})"))
    lines.append(csv_line(
        "fig7_average", 0.0,
        f"EE_gain_geomean=x{geomean(ee_gains):.2f} (paper x1.58)  "
        f"Th_gain_geomean=x{geomean(th_gains):.2f} (paper x2.11)"))
    return lines


if __name__ == "__main__":
    print("\n".join(run()))
